"""Training step builders (surrogate finetune / detector training / the
assigned ``train_4k`` shape).

``build_train_step`` returns a jittable ``step(state, batch) → (state,
metrics)`` closed over (ModelConfig, RunConfig).  Sharding is carried by
the logical-axis hints inside the model plus the in/out shardings the
launcher attaches at ``jax.jit`` time.

Cross-pod gradient compression (int8 + error feedback) is wired through
``repro.distributed.compression``: the loss/grad is computed per pod under
a partial-manual ``shard_map`` (manual over ``pod``, auto over
data/model), the pod reduction is the compressed collective, and the
optimizer update runs replicated.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.distributed.compression import ErrorFeedback, make_cross_pod_allreduce
from repro.models.transformer import forward_lm, lm_loss
from repro.train.optimizer import AdamWConfig, AdamWState, apply_adamw, init_adamw


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState
    ef: Optional[ErrorFeedback]   # gradient-compression residuals (or None)
    step: jax.Array


def make_adamw_config(run: RunConfig) -> AdamWConfig:
    return AdamWConfig(
        learning_rate=run.learning_rate,
        weight_decay=run.weight_decay,
        grad_clip=run.grad_clip,
        quantize_state=run.adam_8bit,
    )


def init_train_state(
    params: dict, run: RunConfig, *, with_ef: bool = False
) -> TrainState:
    opt = init_adamw(params, make_adamw_config(run))
    ef = None
    if with_ef and run.grad_compression:
        from repro.distributed.compression import init_error_feedback

        ef = init_error_feedback(params)
    return TrainState(params=params, opt=opt, ef=ef, step=jnp.zeros((), jnp.int32))


def loss_fn(
    params: dict, batch: dict, cfg: ModelConfig, run: RunConfig, *, moe_groups: int
) -> jax.Array:
    if run.stacked:
        from repro.models.stacked import forward_lm_stacked as fwd
    else:
        fwd = forward_lm
    logits = fwd(params, batch, cfg, run, mode="train", moe_groups=moe_groups)
    labels = batch["labels"]
    if cfg.family == "vlm":
        # image patches carry no LM loss; logits cover [patches | tokens]
        logits = logits[:, cfg.num_patches :]
    return lm_loss(logits, labels)


def microbatch_grad(params: dict, mb: dict, cfg: ModelConfig, run: RunConfig,
                    *, moe_groups: int):
    """Loss + grads of ONE microbatch (the scan body; also lowered alone by
    the dry-run for scan-corrected FLOP accounting — DESIGN.md §6)."""
    return jax.value_and_grad(
        lambda p: loss_fn(p, mb, cfg, run, moe_groups=moe_groups)
    )(params)


def build_train_step(
    cfg: ModelConfig,
    run: RunConfig,
    *,
    moe_groups: int = 1,
    mesh=None,
) -> Callable[[TrainState, dict], tuple[TrainState, dict]]:
    adamw_cfg = make_adamw_config(run)
    cross_pod = (
        make_cross_pod_allreduce(mesh, compress=run.grad_compression)
        if (mesh is not None and run.grad_compression)
        else None
    )
    k = max(run.microbatches, 1)

    def grads_of(params: dict, batch: dict):
        if k == 1:
            return microbatch_grad(params, batch, cfg, run, moe_groups=moe_groups)
        # gradient accumulation: scan over k microbatches (leading batch dim
        # split), f32 accumulators sharded like the params.
        def split(x):
            b = x.shape[0]
            return x.reshape(k, b // k, *x.shape[1:])

        mbs = {key: split(v) for key, v in batch.items()}
        acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, g = microbatch_grad(params, mb, cfg, run, moe_groups=moe_groups)
            g_acc = jax.tree.map(
                lambda a, x: a + x.astype(jnp.float32), g_acc, g
            )
            return (loss_acc + loss, g_acc), None

        (loss_sum, g_sum), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), acc0), mbs)
        inv = 1.0 / k
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, g_sum)

    def step(state: TrainState, batch: dict):
        loss, grads = grads_of(state.params, batch)
        ef = state.ef
        if cross_pod is not None:
            grads, ef = cross_pod(grads, ef)
        params, opt, om = apply_adamw(state.params, grads, state.opt, adamw_cfg)
        metrics = {"loss": loss, **om}
        return TrainState(params=params, opt=opt, ef=ef, step=state.step + 1), metrics

    return step


# --------------------------------------------------------------------------
# surrogate training (BlazeIt baseline substrate)
# --------------------------------------------------------------------------

def build_surrogate_train_step(lr: float = 1e-3):
    """SGD-with-momentum step for the cheap scorer (tiny model — plain f32)."""
    from repro.models.detection import surrogate_loss

    def step(params, momentum, emb, labels):
        loss, grads = jax.value_and_grad(surrogate_loss)(params, emb, labels)
        momentum = jax.tree.map(lambda m, g: 0.9 * m + g, momentum, grads)
        params = jax.tree.map(lambda p, m: p - lr * m, params, momentum)
        return params, momentum, loss

    return jax.jit(step)
