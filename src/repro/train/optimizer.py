"""AdamW with optional block-wise 8-bit moment quantization.

The ≥100 B-parameter assigned models (dbrx-132b, jamba-1.5-398b) cannot
hold fp32 Adam moments on a 256-chip v5e pod (398 B × 8 B / 256 = 12.4 GB
just for m+v).  Block-wise int8 moments with fp32 absmax scales (à la
bitsandbytes, arXiv:2110.02861) cut that to ~2.1 GB with no measurable
loss-curve drift at this scale class.  The quantizer is error-compensated
per step by construction: moments are dequantized, updated, re-quantized —
quantization error enters the *moment*, not the weight, and decays with β.

States are plain pytrees; everything shards like the parameters do
(optimizer state inherits each param's PartitionSpec with the block axis
appended — "ZeRO by sharding").
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantize_state: bool = False
    q_block: int = 256
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QTensor:
    """Block-wise int8 tensor, blocked along the LAST dim when it divides
    the block size, flat otherwise.

    The layout choice is a *distributed* requirement, not cosmetics: q and
    scale keep the parameter's dimensionality so they can shard with the
    parameter's own PartitionSpec.  A flat-sharded state is misaligned
    with 2-D-sharded params and forces a full-parameter all-gather (f32!)
    into the optimizer each step — measured at 5.6 TB/step on the
    jamba-398B train cell (EXPERIMENTS.md §Perf, iteration 3).
    """

    q: jax.Array        # i8, same shape as data (blocked) or i8[n] (flat)
    scale: jax.Array    # f32[..., last/block] (blocked) or f32[nblocks] (flat)
    shape: tuple = dataclasses.field(metadata=dict(static=True))
    block: int = dataclasses.field(metadata=dict(static=True))

    @property
    def blocked(self) -> bool:
        return self.q.shape == self.shape


def quantize_blockwise(x: jax.Array, block: int) -> QTensor:
    shape = tuple(x.shape)
    last = shape[-1] if shape else 0
    if shape and last % block == 0:
        nb = last // block
        blocks = x.astype(jnp.float32).reshape(*shape[:-1], nb, block)
        scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=-1) / 127.0, 1e-12)
        q = jnp.clip(jnp.round(blocks / scale[..., None]), -127, 127)
        return QTensor(
            q=q.astype(jnp.int8).reshape(shape), scale=scale, shape=shape,
            block=block,
        )
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return QTensor(q=q.reshape(-1), scale=scale, shape=shape, block=block)


def dequantize_blockwise(t: QTensor) -> jax.Array:
    if t.blocked:
        nb = t.shape[-1] // t.block
        blocks = t.q.astype(jnp.float32).reshape(*t.shape[:-1], nb, t.block)
        return (blocks * t.scale[..., None]).reshape(t.shape)
    blocks = t.q.reshape(-1, t.block).astype(jnp.float32) * t.scale[:, None]
    n = 1
    for s in t.shape:
        n *= s
    return blocks.reshape(-1)[:n].reshape(t.shape)


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict             # fp32 tree or QTensor tree
    v: dict


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.decay_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def _zeros_like_state(p: jax.Array, cfg: AdamWConfig):
    if cfg.quantize_state:
        return quantize_blockwise(jnp.zeros_like(p, jnp.float32), cfg.q_block)
    return jnp.zeros_like(p, jnp.float32)


def init_adamw(params: dict, cfg: AdamWConfig) -> AdamWState:
    mk = lambda: jax.tree.map(lambda p: _zeros_like_state(p, cfg), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=mk(), v=mk())


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def apply_adamw(
    params: dict,
    grads: dict,
    state: AdamWState,
    cfg: AdamWConfig,
) -> tuple[dict, AdamWState, dict]:
    """One optimizer step.  Returns (params, state, metrics)."""
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_f = dequantize_blockwise(m) if isinstance(m, QTensor) else m
        # v is stored in the sqrt domain when quantized: linear int8 on raw
        # v (which spans many orders of magnitude) corrupts the Adam
        # denominator (~35% trajectory drift measured); sqrt halves the
        # dynamic range and bounds the *relative* error of √v, which is the
        # quantity the update actually divides by.
        v_f = dequantize_blockwise(v) ** 2 if isinstance(v, QTensor) else v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        u = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + cfg.eps)
        new_p = p.astype(jnp.float32) - lr * (u + cfg.weight_decay * p.astype(jnp.float32))
        if isinstance(m, QTensor):
            m_o = quantize_blockwise(m_f, cfg.q_block)
            v_o = quantize_blockwise(jnp.sqrt(v_f), cfg.q_block)
        else:
            m_o, v_o = m_f, v_f
        return new_p.astype(p.dtype), m_o, v_o

    is_q = lambda x: isinstance(x, QTensor)
    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_m = jax.tree_util.tree_flatten(state.m, is_leaf=is_q)[0]
    flat_v = jax.tree_util.tree_flatten(state.v, is_leaf=is_q)[0]
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, AdamWState(step=step, m=new_m, v=new_v), metrics


def state_bytes(state: AdamWState) -> int:
    total = 0
    for leaf in jax.tree.leaves(state, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            total += leaf.q.size + leaf.scale.size * 4
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total
