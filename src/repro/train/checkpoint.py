"""Checkpoint manager: sharded, resumable, CRC-verified (DESIGN.md §5).

Layout:  <dir>/step_<N>/
             manifest.json        — step, tree structure, leaf metadata, CRCs
             shard_<host>.npz     — this host's leaf payloads

Every pytree leaf (params, optimizer moments incl. QTensors, sampler
state, data-pipeline cursors, PRNG key) is saved.  Restore is bit-exact;
the manifest CRC gates torn writes (a crashed host leaves a missing/
mismatched shard and the previous step directory is used instead —
``latest_step`` only returns directories whose manifest verifies).

On multi-host deployments each host writes the leaves it owns
(process-local addressable shards); this container is single-host so
host 0 writes everything — the format is identical.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optimizer import QTensor

_QT_MARKER = "__qtensor__"


def _flatten(tree):
    return jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, QTensor)
    )


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def save_checkpoint(
    directory: str,
    step: int,
    tree: Any,
    *,
    host: int = 0,
    extra: Optional[dict] = None,
) -> str:
    """Atomically write ``tree`` under <dir>/step_<step>."""
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    flat, _ = _flatten(tree)
    payload: dict[str, np.ndarray] = {}
    manifest_leaves = {}
    for path, leaf in flat:
        name = _path_str(path)
        if isinstance(leaf, QTensor):
            payload[name + "/q"] = np.asarray(leaf.q)
            payload[name + "/scale"] = np.asarray(leaf.scale)
            manifest_leaves[name] = {
                _QT_MARKER: True,
                "shape": list(leaf.shape),
                "block": leaf.block,
            }
        else:
            arr = np.asarray(leaf)
            payload[name] = arr
            manifest_leaves[name] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
    shard_path = os.path.join(tmp, f"shard_{host}.npz")
    np.savez(shard_path, **payload)
    with open(shard_path, "rb") as f:
        crc = zlib.crc32(f.read())
    manifest = {
        "step": step,
        "leaves": manifest_leaves,
        "shards": {str(host): {"crc32": crc}},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def _verify(step_dir: str) -> bool:
    try:
        with open(os.path.join(step_dir, "manifest.json")) as f:
            manifest = json.load(f)
        for host, meta in manifest["shards"].items():
            p = os.path.join(step_dir, f"shard_{host}.npz")
            with open(p, "rb") as fh:
                if zlib.crc32(fh.read()) != meta["crc32"]:
                    return False
        return True
    except (OSError, json.JSONDecodeError, KeyError):
        return False


def latest_step(directory: str) -> Optional[int]:
    """Newest step whose manifest + shard CRCs verify."""
    if not os.path.isdir(directory):
        return None
    steps = sorted(
        (
            int(d.split("_", 1)[1])
            for d in os.listdir(directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        ),
        reverse=True,
    )
    for s in steps:
        if _verify(os.path.join(directory, f"step_{s}")):
            return s
    return None


def restore_checkpoint(directory: str, step: int, tree_like: Any, *, host: int = 0):
    """Restore into the structure of ``tree_like`` (bit-exact).

    Returns (tree, extra).
    """
    step_dir = os.path.join(directory, f"step_{step}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(step_dir, f"shard_{host}.npz"))

    flat, treedef = _flatten(tree_like)
    leaves = []
    for path, leaf in flat:
        name = _path_str(path)
        meta = manifest["leaves"][name]
        if meta.get(_QT_MARKER):
            leaves.append(
                QTensor(
                    q=jnp.asarray(data[name + "/q"]),
                    scale=jnp.asarray(data[name + "/scale"]),
                    shape=tuple(meta["shape"]),
                    block=int(meta["block"]),
                )
            )
        else:
            leaves.append(jnp.asarray(data[name]))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest.get("extra", {})


@dataclasses.dataclass
class CheckpointManager:
    """Keep-last-k rotation + resume discovery + async-safe atomic writes."""

    directory: str
    keep: int = 3
    host: int = 0

    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> str:
        path = save_checkpoint(
            self.directory, step, tree, host=self.host, extra=extra
        )
        self._gc()
        return path

    def restore_latest(self, tree_like: Any):
        s = latest_step(self.directory)
        if s is None:
            return None
        tree, extra = restore_checkpoint(self.directory, s, tree_like, host=self.host)
        return s, tree, extra

    def _gc(self) -> None:
        steps = sorted(
            (
                int(d.split("_", 1)[1])
                for d in os.listdir(self.directory)
                if d.startswith("step_") and not d.endswith(".tmp")
            ),
            reverse=True,
        )
        for s in steps[self.keep :]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s}"), ignore_errors=True
            )
