"""Per-chunk, per-class Thompson priors accumulated across searches.

ExSample's estimator is per-query: every search starts from a uniform
prior and spends its first rounds rediscovering which chunks are dense
(paper §3).  Focus (PAPERS.md) shows the repository itself can carry that
knowledge — accumulate each finished query's per-chunk evidence (and any
ingest-time proxy scores) and inject it into the NEXT query's alphas.

The injection contract is the load-bearing part.  ``gamma_params`` reads
``alpha = n1 + alpha0`` and ``beta = n + beta0``, but ``n`` ALSO seeds the
random+ rank base (which frame of a chunk is sampled next) and the
exhaustion predicate (``n >= frames``).  Priors therefore touch ONLY
``n1`` — the sampled-frame sequence, exhaustion behaviour and every other
piece of machinery stay bit-identical; only the Thompson scores shift.
With ``prior_weight == 0`` (or no accumulated evidence for the class) the
sampler state is returned UNCHANGED — the object itself, not a copy — so
the cold path is bit-identical by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# class key used for class-less (batch-path) evidence
_NONE_KEY = -1


def _key(class_id: Optional[int]) -> int:
    return _NONE_KEY if class_id is None else int(class_id)


class ChunkPriors:
    """Accumulated per-chunk evidence, one ``(n1_acc, n_acc)`` pair of
    float64 ``[M]`` arrays per query class (``None`` = class-agnostic).

    ``n1_acc`` sums new-result counts per chunk, ``n_acc`` sums frames
    sampled per chunk, across every recorded search.  ``warm_sampler``
    converts the accumulated hit RATE into pseudo-successes scaled by the
    caller's ``prior_weight`` knob.
    """

    def __init__(self):
        self._n1: dict[int, np.ndarray] = {}
        self._n: dict[int, np.ndarray] = {}

    def __len__(self) -> int:
        return len(self._n1)

    def classes(self) -> list[Optional[int]]:
        return [None if k == _NONE_KEY else k for k in sorted(self._n1)]

    # ---- accumulate --------------------------------------------------------

    def record(self, class_id: Optional[int], n1_delta, n_delta) -> None:
        """Fold one search's per-chunk deltas into the class accumulator.

        ``n1_delta``/``n_delta`` are ``[M]`` (or ``[Q, M]``, summed over
        the leading axes — the batch multi-query paths record the whole
        carry at once).  Deltas, not totals: callers subtract the state
        they started the search from, including any warm-start boost, so
        injected priors are never re-recorded as fresh evidence.
        """
        k = _key(class_id)
        n1 = np.asarray(n1_delta, np.float64)
        n = np.asarray(n_delta, np.float64)
        n1 = n1.reshape(-1, n1.shape[-1]).sum(axis=0)
        n = n.reshape(-1, n.shape[-1]).sum(axis=0)
        if k in self._n1:
            if self._n1[k].shape != n1.shape:
                raise ValueError(
                    f"chunk-count mismatch for class {class_id}: recorded "
                    f"{self._n1[k].shape[0]} chunks, got {n1.shape[0]}"
                )
            self._n1[k] += n1
            self._n[k] += n
        else:
            self._n1[k] = n1.copy()
            self._n[k] = n.copy()

    def ingest(
        self, class_id: Optional[int], proxy_scores, weight: float = 1.0
    ) -> None:
        """Ingest-time proxy evidence (Focus-style cheap scorer): a
        ``[M]`` per-chunk score in [0, 1] enters the SAME accumulators as
        real evidence — ``weight`` pseudo-frames per chunk of which
        ``score × weight`` were pseudo-results."""
        scores = np.clip(np.asarray(proxy_scores, np.float64), 0.0, 1.0)
        self.record(class_id, scores * weight, np.full_like(scores, weight))

    # ---- inject ------------------------------------------------------------

    def warm_alphas(
        self, class_id: Optional[int], num_chunks: int, prior_weight: float
    ) -> Optional[np.ndarray]:
        """``f64[M]`` pseudo-success boost for ``n1`` (or None when there
        is nothing to inject): ``prior_weight × rate_j`` on chunks with
        evidence, where ``rate_j`` is the accumulated per-chunk hit rate.
        ``prior_weight`` is therefore "how many already-sampled frames of
        past experience each chunk's prior is worth"."""
        if prior_weight <= 0:
            return None
        k = _key(class_id)
        if k not in self._n1:
            return None
        n1a, na = self._n1[k], self._n[k]
        if n1a.shape[0] != num_chunks:
            return None   # geometry mismatch (different repository): no warm
        rate = np.clip(n1a, 0.0, None) / np.maximum(na, 1.0)
        return prior_weight * rate * (na > 0)

    def warm_sampler(self, state, class_id: Optional[int],
                     prior_weight: float):
        """Inject the class prior into a ``SamplerState``; returns
        ``(state', equivalent_frames)``.  Only ``n1`` moves (see module
        docstring); ``equivalent_frames`` is the total pseudo-evidence
        injected — the frames of warm-up a cold search would have spent
        gathering it.  When there is nothing to inject the INPUT state is
        returned unchanged (bit-identical cold path)."""
        import jax.numpy as jnp

        boost = self.warm_alphas(
            class_id, int(state.n1.shape[-1]), prior_weight
        )
        if boost is None or not float(boost.sum()) > 0.0:
            return state, 0.0
        new_n1 = state.n1 + jnp.asarray(boost, state.n1.dtype)
        return dataclasses.replace(state, n1=new_n1), float(boost.sum())

    # ---- serde (npz payload inside the RepositoryIndex snapshot) -----------

    def to_arrays(self) -> dict[str, np.ndarray]:
        out: dict[str, np.ndarray] = {}
        for k in sorted(self._n1):
            out[f"n1_{k}"] = self._n1[k]
            out[f"n_{k}"] = self._n[k]
        return out

    @classmethod
    def from_arrays(cls, arrays) -> "ChunkPriors":
        p = cls()
        for name in arrays:
            if name.startswith("n1_"):
                k = int(name[len("n1_"):])
                p._n1[k] = np.asarray(arrays[name], np.float64)
                p._n[k] = np.asarray(arrays[f"n_{k}"], np.float64)
        return p
