"""RepositoryIndex: the DetectionCache generalized into a durable tiered
store (DESIGN.md §13).

Three tiers, exact at every level:

* **device** — the existing direct-mapped
  :class:`~repro.serve.batcher.DetectionCache` a search carries through
  its rounds; ``warm()`` preloads it from the host tier before the search
  starts, ``publish_cache()`` folds its final contents back afterwards.
* **host** — an exact dict keyed by ``(frame_id, detector_version)``
  holding raw detector output as numpy leaves.  A detector upgrade is a
  clean miss: a new ``detector_version`` reads an empty tier while the old
  version's detections stay addressable.
* **disk** — an npz + json-manifest snapshot (``save()`` / auto-load on
  construction) so the repository's knowledge survives the process.

Correctness contract: a hit at a matching ``detector_version`` replays the
EXACT leaves a fresh (deterministic) detector call would produce — the
index changes WHICH detector invocations happen, never the values a query
consumes — and an EMPTY index warms a cache bit-identical to
``init_detection_cache``, so the cold path costs nothing and changes
nothing.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import numpy as np

_FORMAT = 1
_MANIFEST = "manifest.json"
_PRIORS = "priors.npz"


class RepositoryIndex:
    """Durable detections + priors shared across searches (and tenants).

    One instance may back many sequential searches and many concurrent
    tenants of a :class:`~repro.serve.service.SearchService` — the host
    tier and priors are plain host state mutated under the caller's
    serialization (the executor runs searches sequentially; the service
    publishes from its reap loop).
    """

    def __init__(
        self,
        path: Optional[str] = None,
        *,
        detector_version: str = "v0",
        read_only: bool = False,
        prior_weight: float = 0.0,
    ):
        if not detector_version:
            raise ValueError("detector_version must be a non-empty string")
        self.path = path
        self.detector_version = detector_version
        self.read_only = read_only
        self.prior_weight = prior_weight
        # version -> {frame_id -> tuple of numpy leaves (detection pytree)}
        self._tiers: dict[str, dict[int, tuple]] = {}
        from repro.index.priors import ChunkPriors

        self.priors = ChunkPriors()
        self.stats = {"published": 0, "duplicates": 0, "loaded": 0}
        if path is not None and os.path.exists(
            os.path.join(path, _MANIFEST)
        ):
            self._load(path)

    @classmethod
    def open(cls, spec) -> "RepositoryIndex":
        """Construct from a plan-level ``IndexSpec``."""
        return cls(
            spec.path,
            detector_version=spec.detector_version,
            read_only=spec.read_only,
            prior_weight=spec.prior_weight,
        )

    # ---- host tier ---------------------------------------------------------

    def entries(self, version: Optional[str] = None) -> int:
        return len(self._tiers.get(version or self.detector_version, {}))

    def __len__(self) -> int:
        return self.entries()

    def lookup(self, frame_id: int, version: Optional[str] = None):
        """Exact host-tier probe: the stored leaf tuple, or None on miss
        (unknown frame OR mismatched detector version)."""
        tier = self._tiers.get(version or self.detector_version, {})
        return tier.get(int(frame_id))

    def publish(self, frame_ids, dets: Any, mask=None) -> int:
        """Fold a batch of detections (pytree with leading [B] leaves)
        into the current version's host tier; returns how many NEW frames
        were persisted.  Existing frames are skipped (first write wins —
        a deterministic detector re-produces identical leaves anyway) and
        sentinel ids (< 0) never publish.  No-op when ``read_only``."""
        if self.read_only:
            return 0
        import jax

        leaves, _ = jax.tree.flatten(dets)
        fids, mask_h, leaves_h = jax.device_get(
            (frame_ids, mask, tuple(leaves))
        )
        fids = np.atleast_1d(np.asarray(fids))
        tier = self._tiers.setdefault(self.detector_version, {})
        added = 0
        for i, f in enumerate(fids):
            f = int(f)
            if f < 0 or (mask_h is not None and not mask_h[i]):
                continue
            if f in tier:
                self.stats["duplicates"] += 1
                continue
            tier[f] = tuple(np.asarray(leaf[i]) for leaf in leaves_h)
            added += 1
        self.stats["published"] += added
        return added

    def publish_cache(self, cache) -> int:
        """Persist every occupied slot of a search's final
        :class:`DetectionCache` (one device→host sync for the whole
        cache); returns the count of newly persisted frames."""
        if cache is None:
            return 0
        return self.publish(cache.tag, cache.store, cache.tag >= 0)

    # ---- device tier -------------------------------------------------------

    def warm(self, det_struct: Any, capacity: int):
        """Preload a device cache from the host tier; returns
        ``(DetectionCache, warm_frames)`` where ``warm_frames`` is the
        frozenset of frame ids actually resident after the preload.

        Deterministic fill: frames map to ``frame % capacity`` in
        ascending frame-id order, first occupant of a slot wins (so a
        smaller-than-repository capacity degrades gracefully instead of
        depending on dict order).  An EMPTY tier produces a cache
        bit-identical to ``init_detection_cache(det_struct, capacity)``.
        """
        import jax
        import jax.numpy as jnp

        from repro.serve.batcher import DetectionCache

        leaves_s, treedef = jax.tree.flatten(det_struct)
        tag = np.full((capacity,), -1, np.int32)
        stores = [
            np.zeros((capacity,) + tuple(s.shape), s.dtype)
            for s in leaves_s
        ]
        warm_frames = set()
        tier = self._tiers.get(self.detector_version, {})
        for f in sorted(tier):
            slot = f % capacity
            if tag[slot] != -1:
                continue
            tag[slot] = f
            for k, leaf in enumerate(tier[f]):
                stores[k][slot] = leaf
            warm_frames.add(f)
        store = jax.tree.unflatten(
            treedef, [jnp.asarray(s) for s in stores]
        )
        return (
            DetectionCache(tag=jnp.asarray(tag), store=store),
            frozenset(warm_frames),
        )

    # ---- disk tier ---------------------------------------------------------

    def save(self, path: Optional[str] = None) -> str:
        """Snapshot every version tier + priors to ``path`` (defaults to
        the construction path): one ``detections_<i>.npz`` per version
        (``frame_ids`` + stacked ``leaf_<k>`` arrays), ``priors.npz``,
        and a ``manifest.json`` written LAST so a torn snapshot never
        parses as a complete one."""
        path = path or self.path
        if path is None:
            raise ValueError("no snapshot path: pass path= or construct "
                             "the index with one")
        if self.read_only:
            raise ValueError("read_only index refuses to save()")
        os.makedirs(path, exist_ok=True)
        versions = {}
        for i, (version, tier) in enumerate(sorted(self._tiers.items())):
            fname = f"detections_{i}.npz"
            fids = np.asarray(sorted(tier), np.int64)
            arrays = {"frame_ids": fids}
            if len(fids):
                n_leaves = len(tier[int(fids[0])])
                for k in range(n_leaves):
                    arrays[f"leaf_{k}"] = np.stack(
                        [tier[int(f)][k] for f in fids]
                    )
            np.savez(os.path.join(path, fname), **arrays)
            versions[version] = {"file": fname, "entries": len(fids)}
        np.savez(os.path.join(path, _PRIORS), **self.priors.to_arrays())
        manifest = {
            "format": _FORMAT,
            "detector_version": self.detector_version,
            "versions": versions,
            "priors_file": _PRIORS,
        }
        with open(os.path.join(path, _MANIFEST), "w") as fh:
            json.dump(manifest, fh, indent=1)
        # The manifest is the commit point: anything in the snapshot dir it
        # does not reference is an orphan from an earlier (larger or
        # differently-ordered) version set and would otherwise live forever
        # (ROADMAP item 5, compaction).  Deleting only after the manifest
        # lands keeps torn intermediates loadable: a crash before this
        # point leaves extra files, never missing ones.
        referenced = {_MANIFEST, _PRIORS}
        referenced.update(meta["file"] for meta in versions.values())
        for name in os.listdir(path):
            if name in referenced or not (
                name.endswith(".npz") or name == _MANIFEST
            ):
                continue
            try:
                os.remove(os.path.join(path, name))
            except OSError:
                pass  # best-effort: a stale file is a leak, not corruption
        return path

    def _load(self, path: str) -> None:
        from repro.index.priors import ChunkPriors

        with open(os.path.join(path, _MANIFEST)) as fh:
            manifest = json.load(fh)
        if manifest.get("format") != _FORMAT:
            raise ValueError(
                f"index snapshot format {manifest.get('format')!r} != "
                f"{_FORMAT} (incompatible snapshot at {path})"
            )
        for version, meta in manifest["versions"].items():
            with np.load(os.path.join(path, meta["file"])) as z:
                fids = z["frame_ids"]
                n_leaves = sum(1 for n in z.files if n.startswith("leaf_"))
                leaves = [z[f"leaf_{k}"] for k in range(n_leaves)]
                tier = {
                    int(f): tuple(leaf[i] for leaf in leaves)
                    for i, f in enumerate(fids)
                }
            self._tiers[version] = tier
            self.stats["loaded"] += len(tier)
        pfile = os.path.join(path, manifest.get("priors_file") or _PRIORS)
        if os.path.exists(pfile):
            with np.load(pfile) as z:
                self.priors = ChunkPriors.from_arrays(z)
