"""Persistent cross-query repository index (DESIGN.md §13).

Detections and per-chunk statistics outlive the process: a
:class:`~repro.index.store.RepositoryIndex` is the
:class:`~repro.serve.batcher.DetectionCache` generalized into a tiered
store (device tier + exact host tier + disk snapshot, keyed by
``(frame_id, detector_version)``), and
:class:`~repro.index.priors.ChunkPriors` accumulates per-chunk, per-class
Thompson evidence across past searches so a repeat query's first rounds
start focused instead of uniform.
"""
from repro.index.priors import ChunkPriors
from repro.index.store import RepositoryIndex

__all__ = ["ChunkPriors", "RepositoryIndex"]
