"""Launchers: mesh construction, dry-run, train/serve/search drivers."""
