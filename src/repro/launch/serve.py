"""Serving launcher: prefill + autoregressive decode for any --arch.

Reduced configs on CPU; full configs lower on the pod meshes (dry-run
proves it).  Demonstrates the production decode loop with the sharded KV
cache layout and greedy sampling.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-7b --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, RunConfig, scale_down
from repro.models.transformer import init_decode_cache, init_params
from repro.serve.serve_step import build_decode_step, build_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-7b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced or jax.default_backend() == "cpu":
        cfg = scale_down(cfg)
    run = RunConfig(param_dtype="float32", block_q=16, block_kv=16,
                    unroll=False, remat=False, sequence_parallel=False)
    params = init_params(cfg, jax.random.PRNGKey(0))

    b, s = args.batch, args.prompt_len
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch = {
            "tokens": batch["tokens"][:, : s - cfg.num_patches],
            "patches": jnp.zeros((b, cfg.num_patches, cfg.patch_dim), jnp.float32),
        }
    if cfg.encoder_layers:
        batch["frames"] = jnp.zeros((b, s, cfg.d_model), jnp.float32)

    prefill = jax.jit(build_prefill_step(cfg, run))
    decode = jax.jit(build_decode_step(cfg, run))

    t0 = time.time()
    logits = prefill(params, batch)
    print(f"prefill [{b}×{s}] → logits {logits.shape} in {time.time()-t0:.2f}s")

    cache = init_decode_cache(cfg, b, s + args.tokens + 1, jnp.float32)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out = [tok]
    t0 = time.time()
    for _ in range(args.tokens):
        tok, _, cache = decode(params, tok, cache)
        out.append(tok)
    dt = time.time() - t0
    seq = jnp.concatenate(out, axis=1)
    print(f"decoded {args.tokens} tokens/seq in {dt:.2f}s "
          f"({args.tokens * b / dt:.1f} tok/s on CPU)")
    print("sample:", seq[0].tolist())


if __name__ == "__main__":
    main()
