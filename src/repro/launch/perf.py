import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""§Perf hillclimb driver: run named RunConfig variants on one cell and log
hypothesis → change → before → after (EXPERIMENTS.md §Perf).

  PYTHONPATH=src python -m repro.launch.perf --cell jamba-1.5-large-398b:train_4k \
      --variants baseline,fsdp,fsdp_k4
"""
import argparse
import dataclasses
import json
import sys

from repro.configs import ARCHS, SHAPES_BY_NAME
from repro.configs.base import RunConfig
from repro.launch.dryrun import run_cell
from repro.launch.mesh import make_production_mesh

ART = os.path.join(os.path.dirname(__file__), "../../../artifacts/perf")


def base_run(shape) -> RunConfig:
    return RunConfig(
        unroll=True,
        block_q=2048 if shape.kind == "train" else 8192,
        block_kv=2048 if shape.kind == "train" else 8192,
        causal_block_skip=False,
        sequence_parallel=False,
        remat=shape.kind == "train",
        adam_8bit=True,
        microbatches=0,  # 0 ⇒ auto (choose_microbatches)
    )


VARIANTS = {
    # name: (description, transform(run, shape) -> run)
    "baseline": ("paper-faithful baseline", lambda r, s: r),
    "causal_skip": (
        "triangular block enumeration (skip above-diagonal KV tiles)",
        lambda r, s: dataclasses.replace(r, causal_block_skip=True),
    ),
    "fsdp": (
        "weights FSDP over data (gather-on-use) instead of TP activation psums",
        lambda r, s: dataclasses.replace(r, fsdp_params=True),
    ),
    "fsdp_k4": (
        "FSDP + cap gradient-accumulation at 4 µbatches (fewer weight gathers)",
        lambda r, s: dataclasses.replace(r, fsdp_params=True, microbatches=4),
    ),
    "fsdp_k2": (
        "FSDP + 2 µbatches",
        lambda r, s: dataclasses.replace(r, fsdp_params=True, microbatches=2),
    ),
    "fsdp_k1": (
        "FSDP + no accumulation (1 µbatch)",
        lambda r, s: dataclasses.replace(r, fsdp_params=True, microbatches=1),
    ),
    "skip_bq4k": (
        "causal skip + 4096 attention blocks (more diagonal granularity)",
        lambda r, s: dataclasses.replace(
            r, causal_block_skip=True, block_q=4096, block_kv=4096
        ),
    ),
    "skip_bq2k": (
        "causal skip + 2048 blocks (diminishing diagonal-waste returns)",
        lambda r, s: dataclasses.replace(
            r, causal_block_skip=True, block_q=2048, block_kv=2048
        ),
    ),
    "skip_bq16k": (
        "causal skip + 16384 attention blocks (fewer KV re-reads)",
        lambda r, s: dataclasses.replace(
            r, causal_block_skip=True, block_q=16384, block_kv=16384
        ),
    ),
    "skip_pbf16": (
        "causal skip + bf16 attention probabilities (halve tile traffic)",
        lambda r, s: dataclasses.replace(
            r, causal_block_skip=True, probs_bf16=True
        ),
    ),
    "skip_pbf16_bq4k": (
        "causal skip + bf16 probs + 4096 blocks",
        lambda r, s: dataclasses.replace(
            r, causal_block_skip=True, probs_bf16=True, block_q=4096,
            block_kv=4096,
        ),
    ),
    "skip_sp": (
        "causal skip + sequence-parallel residuals",
        lambda r, s: dataclasses.replace(
            r, causal_block_skip=True, sequence_parallel=True
        ),
    ),
    "sp_k2": (
        "sequence-parallel saved residuals enable 2 µbatches (8x fewer "
        "weight-touching collectives than k=16)",
        lambda r, s: dataclasses.replace(
            r, sequence_parallel=True, microbatches=2
        ),
    ),
    "sp_k2_tokex": (
        "SP + k=2 + token-exchange EP (no expert-weight gathers)",
        lambda r, s: dataclasses.replace(
            r, sequence_parallel=True, microbatches=2, moe_token_exchange=True
        ),
    ),
    "sp_k4_tokex": (
        "SP + k=4 + token-exchange EP",
        lambda r, s: dataclasses.replace(
            r, sequence_parallel=True, microbatches=4, moe_token_exchange=True
        ),
    ),
    "sp_k2_fsdp": (
        "SP + k=2 + dense-weight FSDP (state shrinks; gathers cheap at k=2)",
        lambda r, s: dataclasses.replace(
            r, sequence_parallel=True, microbatches=2, fsdp_params=True
        ),
    ),
    "sp_k4_fsdp": (
        "SP + k=4 + dense-weight FSDP",
        lambda r, s: dataclasses.replace(
            r, sequence_parallel=True, microbatches=4, fsdp_params=True
        ),
    ),
    "sp_k1": (
        "sequence-parallel residuals + single batch (no accumulation)",
        lambda r, s: dataclasses.replace(
            r, sequence_parallel=True, microbatches=1
        ),
    ),
    "k4": (
        "cap gradient accumulation at 4 µbatches",
        lambda r, s: dataclasses.replace(r, microbatches=4),
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="<arch>:<shape>")
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args()
    arch, shape_name = args.cell.split(":")
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    tag = ("multi_pod_2x16x16" if args.mesh == "multi" else "single_pod_16x16")

    os.makedirs(ART, exist_ok=True)
    results = {}
    for v in args.variants.split(","):
        desc, fn = VARIANTS[v]
        run = fn(base_run(shape), shape)
        if run.microbatches == 0:
            run = dataclasses.replace(run, microbatches=0)
            # let build_cell auto-choose: signal via None run? build_cell
            # auto-chooses only when run is None; emulate by explicit call
            from repro.launch.specs import choose_microbatches
            from repro.models.transformer import pad_heads, pad_vocab

            cfg = pad_vocab(pad_heads(ARCHS[arch], 16), 16)
            run = dataclasses.replace(
                run, microbatches=choose_microbatches(cfg, shape, mesh)
                if shape.kind == "train" else 1,
            )
        print(f"\n--- variant {v}: {desc} (µb={run.microbatches}, "
              f"fsdp={run.fsdp_params}, skip={run.causal_block_skip}, "
              f"sp={run.sequence_parallel})", flush=True)
        rec = run_cell(arch, shape_name, mesh, tag + f"_perf_{v}",
                       run_cfg=run, save=False)
        results[v] = rec
        with open(os.path.join(ART, f"{arch}__{shape_name}__{v}.json"), "w") as f:
            json.dump(rec, f, indent=1)

    print("\nvariant,t_compute,t_memory,t_collective,bottleneck,step_time,"
          "mfu,hbm_tpu_GiB,fits")
    for v, r in results.items():
        print(f"{v},{r['t_compute_s']:.3f},{r['t_memory_s']:.3f},"
              f"{r['t_collective_s']:.3f},{r['bottleneck']},"
              f"{r['step_time_s']:.3f},{r['mfu_at_roofline']:.4f},"
              f"{r['analytic_hbm_bytes']/2**30:.2f},{r['fits_hbm']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
