"""Search service front: JSON requests over stdin → one live driver.

Boots a :class:`~repro.serve.service.SearchService` around a simulated
repository and serves line-delimited JSON requests on stdin (the thin-RPC
transport every orchestration layer can speak — a real deployment would
mount :func:`handle_request` behind HTTP; the protocol is the same dict in,
dict out):

  {"op": "submit", "tenant": "a", "class": 0, "seed": 1,
   "plan": {"result_limit": 10, "max_steps": 4000, "cohorts": 4,
            "execution": {"queries_axis": true,
                          "service": {"slo_latency_s": 30.0}}}}
  {"op": "stats"}
  {"op": "drain"}

One JSON response per request line on stdout.  EOF implies ``drain`` —
the front never exits with admitted work unfinished.  Example:

  printf '%s\\n' '{"op": "submit", ...}' '{"op": "stats"}' | \\
      PYTHONPATH=src python -m repro.launch.serve_search --budget-s 500

Tenants bind their predicate by query CLASS: the service holds ONE
class-agnostic detector and one ``class_select`` over the repository's
whole class universe, and a tenant's ``class`` rides the driver's
``select_id`` routing — admission never recompiles anything
(DESIGN.md §12).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.exsample_paper import bdd, dashcam
from repro.core import init_carry_multi, init_matcher, init_state
from repro.core.plan import PlanError, SearchPlan
from repro.serve.service import SearchService
from repro.sim import generate
from repro.sim.costmodel import CostRates
from repro.sim.oracle import class_select, oracle_detect


def build_service(args) -> SearchService:
    """World + class-agnostic detector + universe ``class_select`` + an
    empty-pool service under the CLI's cost budget."""
    setup = (dashcam if args.dataset == "dashcam" else bdd)(
        seed=args.seed, scale=args.scale
    )
    repo, chunks = generate(setup.repo)
    num_classes = int(jnp.max(repo.inst_class)) + 1
    detector = lambda key, frame: oracle_detect(
        repo, frame, query_class=None
    )
    select = class_select(repo, list(range(num_classes)))
    proto = init_carry_multi(
        init_state(chunks.length),
        init_matcher(max_results=args.max_results),
        jnp.stack([jax.random.PRNGKey(0)]),
    )
    index = None
    index_path = getattr(args, "index", None)
    if index_path:
        from repro.index.store import RepositoryIndex

        index = RepositoryIndex(
            index_path,
            detector_version=getattr(args, "detector_version", "v0"),
            prior_weight=getattr(args, "prior_weight", 0.0),
        )
    service = SearchService(
        proto, chunks, detector,
        select=select,
        budget_s=args.budget_s,
        rates=CostRates(),
        cohorts=args.cohorts,
        num_workers=args.workers,
        max_steps=args.max_steps,
        cache_frames=chunks.total_frames if args.cache else 0,
        slots_per_batch=args.slots_per_batch,
        index=index,
    )
    service.num_classes = num_classes
    print(
        f"service: {args.dataset} {chunks.total_frames:,} frames / "
        f"{num_classes} classes / budget {args.budget_s:.0f}s / "
        f"cohorts {args.cohorts} x {args.workers} workers",
        file=sys.stderr,
    )
    return service


def handle_request(service: SearchService, obj: dict) -> dict:
    """One request dict → one response dict (transport-agnostic; the
    stdin loop and the tests both call this)."""
    op = obj.get("op")
    try:
        if op == "submit":
            plan = SearchPlan.from_dict(obj["plan"])
            tenant = service.submit(
                str(obj["tenant"]),
                plan,
                seed=int(obj.get("seed", 0)),
                select_id=(
                    int(obj["class"]) if obj.get("class") is not None
                    else None
                ),
            )
            return {"ok": True, **tenant.to_dict()}
        if op == "stats":
            return {"ok": True, **service.stats()}
        if op == "drain":
            service.drain(deadline_s=float(obj.get("deadline_s", 120.0)))
            return {"ok": True, **service.stats()}
        return {"ok": False, "error": f"unknown op {op!r} "
                                      "(submit | stats | drain)"}
    except PlanError as e:
        return {"ok": False, "error": str(e), "field": e.field}
    except (KeyError, ValueError, TimeoutError) as e:
        return {"ok": False, "error": f"{type(e).__name__}: {e}"}


def _print_tenant_summary(service: SearchService) -> None:
    for tid, t in service.stats()["tenants"].items():
        line = f"  tenant {tid}: {t['state']}"
        if "results" in t:
            line += (
                f" — {t['results']} results / {t['steps']:,} frames / "
                f"{t['detector_invocations']:,} fresh detections "
                f"({t['cache_hits']:,} cache hits)"
            )
            if t.get("ttfr_s") is not None:
                met = t.get("slo_met")
                line += f", first result {t['ttfr_s']:.2f}s" + (
                    "" if met is None else f" (SLO {'met' if met else 'MISSED'})"
                )
        elif t["state"] == "rejected":
            line += f" — {t['reason']}"
        print(line, file=sys.stderr)


def build_parser(ap: Optional[argparse.ArgumentParser] = None
                 ) -> argparse.ArgumentParser:
    """The service's CLI surface, reusable by other transports (the HTTP
    front extends this same parser with its bind address)."""
    if ap is None:
        ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="dashcam", choices=["dashcam", "bdd"])
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--budget-s", type=float, default=float("inf"),
                    help="total priced GPU-time budget the admission "
                         "controller enforces (CostRates pricing)")
    ap.add_argument("--cohorts", type=int, default=4)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--max-steps", type=int, default=100_000,
                    help="pool-level frame-budget ceiling")
    ap.add_argument("--max-results", type=int, default=512)
    ap.add_argument("--slots-per-batch", type=int, default=4)
    ap.add_argument("--cache", action="store_true", default=True)
    ap.add_argument("--no-cache", dest="cache", action="store_false")
    ap.add_argument("--index", default=None,
                    help="directory for the persistent RepositoryIndex "
                         "(DESIGN.md §13); loaded if a snapshot exists, "
                         "saved at every tenant retirement")
    ap.add_argument("--detector-version", default="v0",
                    help="detector version key — a mismatch against a "
                         "snapshot is a clean miss")
    ap.add_argument("--prior-weight", dest="prior_weight", type=float,
                    default=0.0,
                    help="default Thompson warm-start weight for tenants "
                         "whose plans don't set execution.index")
    return ap


def main() -> None:
    args = build_parser().parse_args()

    service = build_service(args)
    service.start()
    try:
        for line in sys.stdin:
            line = line.strip()
            if not line:
                continue
            resp = handle_request(service, json.loads(line))
            print(json.dumps(resp), flush=True)
        if service.busy():
            service.drain()   # EOF implies drain: no admitted work is lost
    finally:
        service.stop()
    _print_tenant_summary(service)
    print("service: clean drain", file=sys.stderr)


if __name__ == "__main__":
    main()
