"""Training launcher for the assigned architectures.

On this CPU container it runs reduced configs; the same driver lowers the
full config on a pod (the dry-run proves the sharding).  Handles: config
selection (--arch), deterministic data, µbatching, checkpoint/restart with
the RestartPolicy, and the 8-bit/compressed options.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-32b --steps 50 --reduced
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCHS, RunConfig, scale_down
from repro.data.pipeline import DeterministicTokenPipeline, TrainBatchSpec
from repro.distributed.fault_tolerance import RestartPolicy
from repro.models.transformer import init_params
from repro.train.checkpoint import CheckpointManager
from repro.train.train_step import build_train_step, init_train_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--adam-8bit", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale config (default on this container)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced or jax.default_backend() == "cpu":
        cfg = scale_down(cfg, layers=4, d_model=128, heads=4, d_ff=256, vocab=512)
    run = RunConfig(
        param_dtype="float32", block_q=32, block_kv=32, unroll=False,
        remat=False, sequence_parallel=False, learning_rate=args.lr,
        microbatches=args.microbatches, adam_8bit=args.adam_8bit,
    )
    pipe = DeterministicTokenPipeline(
        TrainBatchSpec(args.batch, args.seq, cfg.vocab), seed=0
    )
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    policy = RestartPolicy(checkpoint_every_steps=args.ckpt_every)

    params = init_params(cfg, jax.random.PRNGKey(0))
    state = init_train_state(params, run)
    start = 0
    resumed = mgr.restore_latest(state)
    if resumed:
        start, state, _ = resumed
        print(f"resumed from step {start} (lose_at_most="
              f"{policy.lose_at_most_steps} steps by construction)")
    step_fn = jax.jit(build_train_step(cfg, run))
    t0 = time.time()
    for step in range(start, args.steps):
        state, metrics = step_fn(state, pipe.batch_at(step))
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"({(time.time()-t0)/max(step-start+1,1):.2f}s/step)",
                  flush=True)
        if step and step % policy.checkpoint_every_steps == 0:
            mgr.save(step, state, extra={"arch": args.arch})


if __name__ == "__main__":
    main()
