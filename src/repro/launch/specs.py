"""Cell builders: (arch × shape × mesh) → lowered-ready step + specs.

``build_cell`` produces everything the dry-run / launchers need:
  * the step function (train_step / prefill / decode),
  * ShapeDtypeStruct stand-ins for every input (no allocation),
  * in_shardings resolved from the logical rules,
  * the analytic MODEL_FLOPS for the roofline's useful-compute ratio.

Shape-dependent sharding decisions (DESIGN.md §5) live here:
  * batch shards over dp axes when divisible, else replicates (long_500k);
  * decode KV caches seq-shard over ``model`` (and additionally over
    ``data`` when batch can't use it);
  * MoE group count = dp shard count;
  * head counts pad to the model-axis size (pad_heads).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.distributed.sharding import ShardingRules, param_shardings
from repro.models import mamba2
from repro.models.transformer import (
    DecodeCache,
    KVCache,
    backbone_schema,
    forward_decode,
    init_decode_cache,
    pad_heads,
    pad_vocab,
)
from repro.models.layers import ParamSpec, Schema, np_prod
from repro.serve.serve_step import build_decode_step, build_prefill_step
from repro.train.optimizer import QTensor
from repro.train.train_step import TrainState, build_train_step, init_train_state


class Cell(NamedTuple):
    name: str
    step_fn: Callable
    args: tuple                 # ShapeDtypeStruct pytrees
    in_shardings: tuple
    cfg: ModelConfig            # possibly head-padded
    run: RunConfig
    model_flops: float          # analytic useful FLOPs per step (global)
    decode_tokens: int          # tokens produced per step (decode) else 0
    # scan correction (train with microbatches>1): the µbatch grad body is
    # lowered separately; totals = full + (k-1)·body (DESIGN.md §6)
    body_fn: Optional[Callable] = None
    body_args: Optional[tuple] = None
    body_in_shardings: Optional[tuple] = None
    scan_repeats: int = 1
    out_shardings: Any = None


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _dp_size(mesh: Mesh) -> int:
    n = 1
    for a in _dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def _batch_spec(mesh: Mesh, batch: int, rank: int) -> P:
    """Shard the batch dim over dp axes when divisible."""
    dp = _dp_axes(mesh)
    if batch % _dp_size(mesh) == 0:
        lead = dp if len(dp) > 1 else dp[0]
        return P(lead, *([None] * (rank - 1)))
    # try data only
    if "data" in mesh.axis_names and batch % mesh.shape["data"] == 0:
        return P("data", *([None] * (rank - 1)))
    return P(*([None] * rank))


def batch_inputs(cfg: ModelConfig, shape: ShapeConfig, *, with_labels: bool):
    """ShapeDtypeStructs for the non-cache inputs of one step."""
    b, s = shape.global_batch, shape.seq_len
    out: dict[str, Any] = {}
    if cfg.family == "vlm":
        out["tokens"] = _sds((b, s - cfg.num_patches), jnp.int32)
        out["patches"] = _sds((b, cfg.num_patches, cfg.patch_dim), jnp.bfloat16)
    else:
        out["tokens"] = _sds((b, s), jnp.int32)
    if cfg.encoder_layers:
        out["frames"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
    if with_labels:
        n_lab = s - cfg.num_patches if cfg.family == "vlm" else s
        out["labels"] = _sds((b, n_lab), jnp.int32)
    return out


def batch_shardings(batch: dict, mesh: Mesh, global_batch: int):
    return {
        k: NamedSharding(mesh, _batch_spec(mesh, global_batch, v.ndim))
        for k, v in batch.items()
    }


# --------------------------------------------------------------------------
# optimizer-state sharding (ZeRO-style)
# --------------------------------------------------------------------------

def _flat_spec(mesh: Mesh, n: int) -> P:
    """Spec for a flat 1-D buffer: shard over every axis whose product
    divides n (maximally sharded), else replicate."""
    axes = [a for a in ("pod", "data", "model") if a in mesh.axis_names]
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    if n % total == 0:
        return P(tuple(axes))
    if "model" in mesh.axis_names and n % mesh.shape["model"] == 0:
        return P("model")
    return P(None)


def opt_state_shardings(params_shardings, mesh: Mesh, state: TrainState):
    """ZeRO-style optimizer sharding: moments inherit the param spec PLUS a
    ``data``-axis shard on the largest still-replicated dim (so fp32 state
    spreads over data × model, not model alone); QTensors shard flat over
    every dividing axis; scalars replicate."""
    data_n = mesh.shape.get("data", 1)

    def zero_spec(ps: NamedSharding, shape: tuple) -> NamedSharding:
        spec = list(ps.spec) + [None] * (len(shape) - len(ps.spec))
        if "data" in mesh.axis_names and not any(
            (ax == "data" or (isinstance(ax, tuple) and "data" in ax))
            for ax in spec if ax
        ):
            # largest replicated dim divisible by |data|
            cands = [
                (shape[i], i) for i in range(len(shape))
                if spec[i] is None and shape[i] % data_n == 0
            ]
            if cands:
                _, i = max(cands)
                spec[i] = "data"
        return NamedSharding(mesh, P(*spec))

    def _axis_size(ax) -> int:
        n = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            n *= mesh.shape[a]
        return n

    def moment(ps_tree, m_tree):
        def leaf(ps, m):
            if isinstance(m, QTensor):
                if m.blocked:
                    # CONGRUENT sharding: q/scale carry the param's own spec
                    # so the optimizer update stays fully local (flat-sharded
                    # state forced full-param all-gathers — §Perf iter 3).
                    qs = zero_spec(ps, m.q.shape)
                    sspec = list(qs.spec) + [None] * (
                        len(m.scale.shape) - len(qs.spec)
                    )
                    # scale's last dim is blocks-of-last: drop its axis if
                    # the block count doesn't divide
                    if len(sspec) >= 1 and sspec[len(m.scale.shape) - 1]:
                        ax = sspec[len(m.scale.shape) - 1]
                        if m.scale.shape[-1] % _axis_size(ax):
                            sspec[len(m.scale.shape) - 1] = None
                    return QTensor(
                        q=qs,
                        scale=NamedSharding(mesh, P(*sspec[: len(m.scale.shape)])),
                        shape=m.shape,
                        block=m.block,
                    )
                return QTensor(
                    q=NamedSharding(mesh, _flat_spec(mesh, m.q.shape[0])),
                    scale=NamedSharding(mesh, _flat_spec(mesh, m.scale.shape[0])),
                    shape=m.shape,
                    block=m.block,
                )
            return zero_spec(ps, m.shape)
        return jax.tree.map(
            leaf, ps_tree, m_tree, is_leaf=lambda x: isinstance(x, QTensor)
        )

    rep = NamedSharding(mesh, P())
    return TrainState(
        params=params_shardings,
        opt=type(state.opt)(
            step=rep,
            m=moment(params_shardings, state.opt.m),
            v=moment(params_shardings, state.opt.v),
        ),
        ef=None if state.ef is None else jax.tree.map(lambda _: rep, state.ef),
        step=rep,
    )


# --------------------------------------------------------------------------
# decode-cache sharding
# --------------------------------------------------------------------------

def decode_cache_shardings(cfg: ModelConfig, cache: DecodeCache, mesh: Mesh,
                           batch: int):
    """KV: [B, T, KV, hd] → batch over data (if divisible), T over model
    (plus data when batch is 1 — long_500k).  Mamba: heads over model."""
    dp_ok = "data" in mesh.axis_names and batch % mesh.shape["data"] == 0
    b_ax = "data" if dp_ok else None
    seq_axes = ("model",) if dp_ok else tuple(
        a for a in ("pod", "data", "model") if a in mesh.axis_names
    )
    def kv_spec(t: int) -> P:
        n_seq = 1
        for a in seq_axes:
            n_seq *= mesh.shape[a]
        seq = tuple(seq_axes) if t % n_seq == 0 else (
            "model" if t % mesh.shape["model"] == 0 else None
        )
        return P(b_ax, seq, None, None)

    layers = []
    for lc in cache.layers:
        if isinstance(lc, KVCache):
            sp = NamedSharding(mesh, kv_spec(lc.k.shape[1]))
            layers.append(KVCache(k=sp, v=sp))
        else:  # MambaCache
            layers.append(
                mamba2.MambaCache(
                    conv=NamedSharding(mesh, P(b_ax, None, "model")),
                    ssm=NamedSharding(mesh, P(b_ax, "model", None, None)),
                )
            )
    cross = []
    for cc in cache.cross:
        if cc is None:
            cross.append(None)
        else:
            sp = NamedSharding(mesh, P(b_ax, None, None, None))
            cross.append(KVCache(k=sp, v=sp))
    return DecodeCache(
        layers=tuple(layers),
        cross=tuple(cross),
        pos=NamedSharding(mesh, P()),
    )


# --------------------------------------------------------------------------
# analytic MODEL_FLOPS
# --------------------------------------------------------------------------

def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N_active·tokens convention (backward ×3 included for train)."""
    n_active = active_params(cfg)
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0
    flops = mult * n_active * tokens
    # attention core (not in 6ND): causal-optimal qk+pv
    attn_layers = sum(1 for i in range(cfg.num_layers) if cfg.is_attn_layer(i))
    if attn_layers and cfg.num_heads:
        hd = cfg.resolved_head_dim
        kv_len = shape.seq_len
        per_tok = 2.0 * kv_len * cfg.num_heads * hd * 2.0
        if not shape.is_decode:
            per_tok /= 2.0   # causal triangle
        core = attn_layers * tokens * per_tok
        if cfg.encoder_layers:
            core += cfg.encoder_layers * tokens * 2.0 * kv_len * cfg.num_heads * hd * 2.0
        if shape.kind == "train":
            core *= 3.0
        flops += core
    return flops


def active_params(cfg: ModelConfig) -> float:
    """Parameters touched per token (MoE counts top_k experts once)."""
    schema = backbone_schema(cfg)
    total = 0.0
    flat, _ = jax.tree_util.tree_flatten_with_path(
        schema, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    for path, spec in flat:
        parts = [str(getattr(p, "key", p)) for p in path]
        n = float(np_prod(spec.shape))
        if "moe" in parts and parts[-1] in ("w_gate", "w_up", "w_down"):
            n *= cfg.moe.top_k / cfg.moe.num_experts
        if parts[-1] == "table":
            continue  # embedding gather isn't a matmul; unembed counted below
        total += n
    # unembed matmul
    total += cfg.vocab * cfg.d_model
    return total


# --------------------------------------------------------------------------
# cell construction
# --------------------------------------------------------------------------

def choose_microbatches(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> int:
    """Pick the gradient-accumulation factor so the per-µbatch activation
    footprint (saved residuals + logits + backward transients) stays well
    under HBM.  Budget 4 GiB of residual checkpoints per device."""
    dp = _dp_size(mesh)
    local_batch = max(shape.global_batch // dp, 1)
    saved = cfg.num_layers * local_batch * shape.seq_len * cfg.d_model * 2
    budget = 4 * 1024**3
    k = 1
    while saved / k > budget and k < local_batch and local_batch % (k * 2) == 0:
        k *= 2
    return k


def build_cell(
    arch_cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    *,
    run: Optional[RunConfig] = None,
) -> Cell:
    model_shards = mesh.shape.get("model", 1)
    cfg = pad_heads(arch_cfg, model_shards) if arch_cfg.num_heads else arch_cfg
    cfg = pad_vocab(cfg, model_shards)
    dp = _dp_size(mesh)
    moe_groups = max(
        min(dp, shape.global_batch * (1 if shape.is_decode else shape.seq_len)), 1
    )
    if run is None:
        run = RunConfig(
            unroll=True,
            block_q=2048 if shape.kind == "train" else 8192,
            block_kv=2048 if shape.kind == "train" else 8192,
            causal_block_skip=False,      # paper-faithful baseline; perf pass flips
            sequence_parallel=False,      # µbatching is the default memory lever
            remat=shape.kind == "train",
            microbatches=choose_microbatches(cfg, shape, mesh)
            if shape.kind == "train"
            else 1,
            adam_8bit=param_count(cfg) > 6e10,
        )
    if run.microbatches == 0:
        run = dataclasses.replace(
            run,
            microbatches=choose_microbatches(cfg, shape, mesh)
            if shape.kind == "train"
            else 1,
        )
    rules = ShardingRules.for_mesh(mesh, fsdp_params=run.fsdp_params)
    schema = backbone_schema(cfg)
    p_shardings = param_shardings(schema, rules)
    p_abstract = jax.tree.map(
        lambda s: _sds(s.shape, run.dtype()),
        schema,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )
    flops = model_flops(arch_cfg, shape)     # useful flops exclude head padding
    name = f"{arch_cfg.name}:{shape.name}"

    if shape.kind == "train":
        step = build_train_step(cfg, run, moe_groups=moe_groups, mesh=mesh)
        batch = batch_inputs(cfg, shape, with_labels=True)
        b_shard = batch_shardings(batch, mesh, shape.global_batch)
        state_abs = jax.eval_shape(
            lambda p: init_train_state(p, run), p_abstract
        )
        state_shardings = opt_state_shardings(p_shardings, mesh, state_abs)
        body_fn = body_args = body_sh = None
        k = max(run.microbatches, 1)
        if k > 1:
            from repro.train.train_step import microbatch_grad

            mb = {
                key: _sds((v.shape[0] // k,) + v.shape[1:], v.dtype)
                for key, v in batch.items()
            }
            mb_sh = batch_shardings(mb, mesh, shape.global_batch // k)
            body_fn = lambda p, b: microbatch_grad(
                p, b, cfg, run, moe_groups=moe_groups
            )
            body_args = (p_abstract, mb)
            body_sh = (p_shardings, mb_sh)
        return Cell(
            name=name,
            step_fn=step,
            args=(state_abs, batch),
            in_shardings=(state_shardings, b_shard),
            cfg=cfg,
            run=run,
            model_flops=flops,
            decode_tokens=0,
            body_fn=body_fn,
            body_args=body_args,
            body_in_shardings=body_sh,
            scan_repeats=k,
        )

    if shape.kind == "prefill":
        step = build_prefill_step(cfg, run, moe_groups=moe_groups)
        batch = batch_inputs(cfg, shape, with_labels=False)
        b_shard = batch_shardings(batch, mesh, shape.global_batch)
        return Cell(
            name=name,
            step_fn=step,
            args=(p_abstract, batch),
            in_shardings=(p_shardings, b_shard),
            cfg=cfg,
            run=run,
            model_flops=flops,
            decode_tokens=0,
        )

    # decode
    step = build_decode_step(cfg, run, moe_groups=moe_groups)
    b = shape.global_batch
    cache_abs = jax.eval_shape(
        lambda: init_decode_cache(cfg, b, shape.seq_len, run.dtype())
    )
    cache_sh = decode_cache_shardings(cfg, cache_abs, mesh, b)
    token = _sds((b, 1), jnp.int32)
    token_sh = NamedSharding(mesh, _batch_spec(mesh, b, 2))
    return Cell(
        name=name,
        step_fn=step,
        args=(p_abstract, token, cache_abs),
        in_shardings=(p_shardings, token_sh, cache_sh),
        cfg=cfg,
        run=run,
        model_flops=flops,
        decode_tokens=b,
    )


def param_count(cfg: ModelConfig) -> float:
    from repro.models.layers import count_params

    return float(count_params(backbone_schema(cfg)))


def _sharded_bytes(tree, shardings) -> float:
    """Per-device bytes of a pytree given its NamedShardings (exact)."""
    total = 0.0
    for leaf, sh in zip(jax.tree.leaves(tree), jax.tree.leaves(shardings)):
        n = float(np_prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
        if hasattr(sh, "num_devices") and sh.num_devices:
            # shard factor = product of mesh axes used by the spec
            used = 1
            for ax in jax.tree.leaves(tuple(sh.spec)):
                if ax is not None:
                    used *= sh.mesh.shape[ax]
            n /= used
        total += n
    return total


def analytic_hbm(cell: Cell, mesh: Mesh, shape: ShapeConfig) -> dict:
    """TPU-side per-device HBM estimate (DESIGN.md §6).

    XLA:CPU's memory_analysis over-reports by 2-4× on these graphs: bf16
    scatter/psum/select are wrapped in f32 on CPU and elementwise chains
    don't fuse, so each layer's residual appears as O(10) f32 copies
    (evidence in EXPERIMENTS.md §Dry-run).  This model counts what a TPU
    actually holds: exact sharded state bytes + the dominant transients.
    """
    cfg, run = cell.cfg, cell.run
    dp = _dp_size(mesh)
    mp = mesh.shape.get("model", 1)
    state_bytes = _sharded_bytes(cell.args, cell.in_shardings)
    k = max(run.microbatches, 1)
    tokens_local = shape.global_batch * (
        1 if shape.is_decode else shape.seq_len
    ) / dp / k
    act = 0.0
    if shape.kind == "train":
        # saved layer-boundary residuals (bf16; seq-sharded under SP) +
        # logits + a live transient window
        sp = 1.0 / mp if run.sequence_parallel else 1.0
        act += cfg.num_layers * tokens_local * cfg.d_model * 2 * sp
        act += tokens_local * (cfg.vocab / mp) * 4          # logits f32
        act += 6 * tokens_local * cfg.d_model * 4           # live window
        if cfg.d_ff:
            act += 2 * tokens_local * (cfg.d_ff / mp) * 4
    elif shape.kind == "prefill":
        seq_factor = 1.0 / mp if run.sequence_parallel else 1.0
        act += 4 * tokens_local * cfg.d_model * 2 * seq_factor
        act += 2 * tokens_local * cfg.d_model * 2           # attn gather live
        act += run.block_q * run.block_kv * 4 * 3           # score tiles f32
        if cfg.d_ff:
            act += tokens_local * (cfg.d_ff / mp) * 4
    else:
        act += 2 * tokens_local * cfg.vocab / mp * 4        # decode logits
        act += 16 * tokens_local * cfg.d_model * 4
    if cfg.moe is not None and not shape.is_decode:
        t_g = shape.global_batch * shape.seq_len / dp / k
        act += 2 * (t_g + 1) * cfg.d_model * 2              # combine slabs
        c_cap = max(
            int(t_g * cfg.moe.top_k * cfg.moe.capacity_factor / cfg.moe.num_experts), 1
        )
        e_local = max(cfg.moe.num_experts // mp, 1)
        act += 3 * e_local * c_cap * max(cfg.moe.d_ff / dp, 1) * 4
        act += e_local * c_cap * cfg.d_model * 2 * 2        # xe + ye
    total = state_bytes + act
    return {
        "analytic_state_bytes": state_bytes,
        "analytic_activation_bytes": act,
        "analytic_hbm_bytes": total * 1.15,                 # fragmentation slack
        "analytic_fits_hbm": total * 1.15 <= 16 * 1024**3,
    }


def build_mem_cell(
    arch_cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    *,
    run: Optional[RunConfig] = None,
) -> Optional[Cell]:
    """Memory-fidelity variant: scan-over-layers (stacked params) so
    ``memory_analysis`` reflects buffer reuse.  Returns None for decode
    shapes (per-layer transients are small; the cost config's memory
    analysis is already faithful there)."""
    if shape.is_decode:
        return None
    model_shards = mesh.shape.get("model", 1)
    cfg = pad_heads(arch_cfg, model_shards) if arch_cfg.num_heads else arch_cfg
    cfg = pad_vocab(cfg, model_shards)
    dp = _dp_size(mesh)
    moe_groups = max(min(dp, shape.global_batch * shape.seq_len), 1)
    base = run or RunConfig()
    run = dataclasses.replace(
        base,
        stacked=True,
        unroll=True,
        block_q=2048 if shape.kind == "train" else 8192,
        block_kv=2048 if shape.kind == "train" else 8192,
        causal_block_skip=base.causal_block_skip,
        # prefill residuals at 32k tokens/dev don't fit without SP; train
        # fits via µbatching and avoids SP's gather traffic
        sequence_parallel=(
            base.sequence_parallel if run is not None and shape.kind == "train"
            else shape.kind == "prefill"
        ),
        remat=shape.kind == "train",
        microbatches=(
            base.microbatches
            if (run is not None and base.microbatches >= 1 and shape.kind == "train")
            else (
                choose_microbatches(cfg, shape, mesh)
                if shape.kind == "train"
                else 1
            )
        ),
        adam_8bit=param_count(cfg) > 6e10,
    )
    from repro.models.stacked import stack_schema

    schema, _, _ = stack_schema(cfg)
    rules = ShardingRules.for_mesh(mesh, fsdp_params=run.fsdp_params)
    p_shardings = param_shardings(schema, rules)
    p_abstract = jax.tree.map(
        lambda s: _sds(s.shape, run.dtype()),
        schema,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )
    name = f"{arch_cfg.name}:{shape.name}:mem"
    if shape.kind == "train":
        step = build_train_step(cfg, run, moe_groups=moe_groups, mesh=mesh)
        batch = batch_inputs(cfg, shape, with_labels=True)
        b_shard = batch_shardings(batch, mesh, shape.global_batch)
        state_abs = jax.eval_shape(lambda p: init_train_state(p, run), p_abstract)
        state_shardings = opt_state_shardings(p_shardings, mesh, state_abs)
        rep = NamedSharding(mesh, P())
        metric_sh = {"loss": rep, "lr": rep, "grad_norm": rep}
        return Cell(
            name=name, step_fn=step, args=(state_abs, batch),
            in_shardings=(state_shardings, b_shard), cfg=cfg, run=run,
            model_flops=0.0, decode_tokens=0,
            out_shardings=(state_shardings, metric_sh),
        )
    step = build_prefill_step(cfg, run, moe_groups=moe_groups)
    batch = batch_inputs(cfg, shape, with_labels=False)
    b_shard = batch_shardings(batch, mesh, shape.global_batch)
    return Cell(
        name=name, step_fn=step, args=(p_abstract, batch),
        in_shardings=(p_shardings, b_shard), cfg=cfg, run=run,
        model_flops=0.0, decode_tokens=0,
    )
