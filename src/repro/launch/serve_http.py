"""HTTP front for the search service: JSON POST → one live driver.

Mounts :func:`repro.launch.serve_search.handle_request` — the same
dict-in/dict-out protocol the stdin front speaks — behind a stdlib
``ThreadingHTTPServer``, completing the transport story sketched in that
module's docstring ("a real deployment would mount handle_request behind
HTTP").  No new dependency: ``http.server`` ships with CPython.

  POST /            {"op": "submit", "tenant": "a", "plan": {...}}
  POST /            {"op": "stats"} | {"op": "drain"}
  GET  /stats       convenience alias for {"op": "stats"}

One JSON body per request, one JSON response (HTTP 200 even for
``{"ok": false}`` protocol errors — transport status is reserved for
transport problems: 400 malformed JSON, 404 unknown path, 405 bad
method).  Shutdown drains: admitted work is never lost.

  PYTHONPATH=src python -m repro.launch.serve_http --port 8080 &
  curl -d '{"op": "submit", "tenant": "a", "class": 0, \
            "plan": {"result_limit": 5, "execution": \
                     {"queries_axis": true}}}' localhost:8080
"""
from __future__ import annotations

import json
import sys
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.launch.serve_search import (
    build_parser,
    build_service,
    handle_request,
)
from repro.serve.service import SearchService


def make_server(
    service: SearchService, host: str = "127.0.0.1", port: int = 0
) -> ThreadingHTTPServer:
    """Bind a threaded HTTP server over ``service`` (``port=0`` picks a
    free port — read it back from ``server.server_address``).  The caller
    owns the service lifecycle: ``service.start()`` before serving,
    ``drain()``/``stop()`` after ``server.shutdown()``."""

    class Handler(BaseHTTPRequestHandler):
        def _reply(self, code: int, payload: dict) -> None:
            body = json.dumps(payload).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def do_POST(self) -> None:  # noqa: N802 (stdlib naming)
            length = int(self.headers.get("Content-Length") or 0)
            raw = self.rfile.read(length)
            try:
                obj = json.loads(raw.decode() or "null")
            except (json.JSONDecodeError, UnicodeDecodeError) as e:
                self._reply(400, {"ok": False, "error": f"bad JSON: {e}"})
                return
            if not isinstance(obj, dict):
                self._reply(
                    400, {"ok": False,
                          "error": "request body must be a JSON object"})
                return
            self._reply(200, handle_request(service, obj))

        def do_GET(self) -> None:  # noqa: N802 (stdlib naming)
            if self.path.rstrip("/") in ("", "/stats"):
                self._reply(200, handle_request(service, {"op": "stats"}))
            else:
                self._reply(
                    404, {"ok": False,
                          "error": f"unknown path {self.path!r}"})

        def log_message(self, fmt, *args) -> None:
            pass   # quiet: the service prints its own summary on stderr

    return ThreadingHTTPServer((host, port), Handler)


def main() -> None:
    # serve_search's full CLI surface (dataset/budget/cache/index/...)
    # plus the bind address — one parser, one source of truth
    ap = build_parser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    args = ap.parse_args()

    service = build_service(args)
    server = make_server(service, host=args.host, port=args.port)
    host, port = server.server_address[:2]
    print(f"service: http://{host}:{port} (POST JSON ops; GET /stats)",
          file=sys.stderr)
    service.start()
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.shutdown()
        server.server_close()
        if service.busy():
            service.drain()   # shutdown implies drain, like the stdin EOF
        service.stop()
    print("service: clean drain", file=sys.stderr)


if __name__ == "__main__":
    main()
