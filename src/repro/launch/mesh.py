"""Production mesh construction (dry-run contract, DESIGN.md §6).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state; the dry-run sets the 512-device XLA flag before
any jax import and only then calls it.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax.sharding.AxisType (and make_mesh's axis_types kwarg) only exist in
    # newer JAX; every axis here is Auto — the default — so a plain Mesh is
    # semantically identical on older versions.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                shape, axes, axis_types=(axis_type.Auto,) * len(axes)
            )
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (requires xla_force_host_platform_device_count)."""
    return _make_mesh(shape, axes)


def make_data_mesh(num_shards: int):
    """1-D ``("data",)`` mesh over the first ``num_shards`` local devices —
    the sharded search driver's layout (``run_search_sharded``).  Built
    from an explicit device subset so a search can use fewer shards than
    the host exposes (``jax.make_mesh`` insists on all of them)."""
    import numpy as np

    devices = jax.devices()
    if len(devices) < num_shards:
        raise ValueError(
            f"need {num_shards} devices for a {num_shards}-way data mesh, "
            f"have {len(devices)} (set --xla_force_host_platform_device_count)"
        )
    return jax.sharding.Mesh(
        np.asarray(devices[:num_shards]).reshape(num_shards), ("data",)
    )


def ensure_host_devices(num_shards: int, *, argv=None) -> None:
    """Make sure this process sees ≥ ``num_shards`` devices, re-execing a
    child with ``--xla_force_host_platform_device_count`` when it doesn't
    (the flag must precede the child's first jax import, which is why this
    re-execs instead of mutating flags in place).

    Safety properties every ad-hoc copy of this logic kept getting wrong:
    the child pins ``JAX_PLATFORMS=cpu`` (the device-count flag only
    affects the CPU platform, so a GPU host would otherwise re-exec
    forever), existing ``XLA_FLAGS`` are appended to rather than
    clobbered, and a device-count flag already present acts as the repeat
    guard — the caller's mesh construction then raises a clear error
    instead of spawning another child.  ``argv`` overrides the child
    command line (e.g. ``[sys.executable, "-m", "pkg.mod", ...]`` for
    ``-m`` entry points); default re-runs ``sys.argv`` as a script.
    Returns normally iff enough devices are available in THIS process.
    """
    import os
    import subprocess
    import sys

    if len(jax.devices()) >= num_shards:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "device_count" in flags:
        return  # already forced and still short: let make_data_mesh raise
    env = dict(os.environ)
    env["XLA_FLAGS"] = (flags + " " if flags else "") + (
        f"--xla_force_host_platform_device_count={num_shards}"
    )
    env.setdefault("JAX_PLATFORMS", "cpu")
    sys.exit(subprocess.call(argv or [sys.executable] + sys.argv, env=env))


def describe(mesh) -> str:
    return f"mesh{tuple(mesh.devices.shape)} axes={mesh.axis_names}"
