"""Production mesh construction (dry-run contract, DESIGN.md §6).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state; the dry-run sets the 512-device XLA flag before
any jax import and only then calls it.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax.sharding.AxisType (and make_mesh's axis_types kwarg) only exist in
    # newer JAX; every axis here is Auto — the default — so a plain Mesh is
    # semantically identical on older versions.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                shape, axes, axis_types=(axis_type.Auto,) * len(axes)
            )
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (requires xla_force_host_platform_device_count)."""
    return _make_mesh(shape, axes)


def describe(mesh) -> str:
    return f"mesh{tuple(mesh.devices.shape)} axes={mesh.axis_names}"
