"""Production search driver: ExSample distinct-object query end-to-end.

Wires together: simulated repository (or any FrameStore), a detector
(oracle or noisy), the ExSample core behind ONE ``SearchPlan`` (DESIGN.md
§10), the cost model and the checkpoint manager — the full Algorithm 1
deployment loop with resumable state.

  PYTHONPATH=src python -m repro.launch.search --limit 50 --cohorts 16
  PYTHONPATH=src python -m repro.launch.search \\
      --plan '{"result_limit": 50, "max_steps": 50000, "cohorts": 16}'
  PYTHONPATH=src python -m repro.launch.search \\
      --plan '{"queries": 4, "result_limit": 20, "max_steps": 50000,
               "cohorts": 8, "execution": {"queries_axis": true,
               "shards": 8, "cache": -1}}'

``--plan`` takes a JSON ``SearchPlan.to_dict()`` document (or ``@file``)
and is the canonical path: the planner validates option compatibility and
lowers to one device-resident driver — host loop, scanned, mesh-sharded,
Q-batched, async, or the composed Q×shards driver the legacy flags could
never combine.  The legacy flag combinations (``--mesh/--sync-every``,
``--queries/--cache-frames``, ``--driver``) still work but are deprecated:
they are translated into the equivalent plan and a ``DeprecationWarning``
is emitted.  When the plan needs more devices than the host exposes,
``main()`` re-execs into a child with simulated host devices
(``launch.mesh.ensure_host_devices``).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import warnings

import jax
import jax.numpy as jnp

from repro.configs.exsample_paper import bdd, dashcam
from repro.core import (
    Execution,
    SearchPlan,
    init_carry,
    init_carry_multi,
    init_matcher,
    init_state,
)
from repro.core.baselines import FrameSchedule, run_schedule
from repro.sim import generate
from repro.sim.costmodel import CostRates, sampling_cost
from repro.sim.oracle import class_select, noisy_detect, oracle_detect
from repro.train.checkpoint import CheckpointManager


def build_plan(args) -> SearchPlan:
    """``--plan`` JSON (inline or ``@file``) or the deprecated legacy flag
    translation — both end in one validated :class:`SearchPlan`."""
    if args.plan:
        text = args.plan
        if text.startswith("@"):
            with open(text[1:]) as f:
                text = f.read()
        return SearchPlan.from_dict(json.loads(text))

    legacy = []
    if args.mesh > 1 or args.sync_every != 1:
        legacy.append("--mesh/--sync-every")
    if args.queries:
        legacy.append("--queries/--cache-frames")
    if args.driver != "scan":
        legacy.append("--driver")
    if legacy:
        warnings.warn(
            f"{', '.join(legacy)} are deprecated: pass the equivalent "
            "--plan '<json>' (SearchPlan.to_dict schema, DESIGN.md §10)",
            DeprecationWarning,
            stacklevel=2,
        )

    shards = args.mesh if args.mesh > 1 else 1
    # the legacy CLI silently ignored --sync-every without --mesh; keep
    # that contract rather than letting the planner reject the combination
    sync_every = args.sync_every if shards > 1 else 1
    if args.sync_every != 1 and shards == 1:
        print(f"--sync-every {args.sync_every} ignored without --mesh "
              "(merge schedule is a mesh-lowering option)")
    cohorts = args.cohorts
    if shards > 1 and cohorts % shards:
        cohorts = cohorts - cohorts % shards or shards
        print(f"--cohorts {args.cohorts} → {cohorts} "
              f"(must be a multiple of --mesh {shards})")
    if args.queries:
        cache = args.cache_frames if args.cache_frames != 0 else None
        return SearchPlan(
            queries=len(args.queries), result_limit=args.limit,
            max_steps=args.max_steps, cohorts=cohorts, trace_every=256,
            execution=Execution(
                queries_axis=True, shards=shards,
                sync_every=sync_every, cache=cache,
            ),
        )
    strategy = "host" if (args.driver == "host" and shards == 1) else "auto"
    if args.driver == "host" and shards > 1:
        print(f"--driver host ignored: --mesh {shards} selects the sharded "
              "lowering (DESIGN.md §8)")
    return SearchPlan(
        result_limit=args.limit, max_steps=args.max_steps, cohorts=cohorts,
        trace_every=256,
        execution=Execution(
            strategy=strategy, shards=shards, sync_every=sync_every,
        ),
    )


def _print_result(res, args, wall: float) -> None:
    rates = CostRates()
    st = res.stats
    if res.num_queries > 1:
        for q in range(res.num_queries):
            print(f"  query {q}: {res.results[q]} results / "
                  f"{res.steps[q]:,} frames")
    cost = sampling_cost(st.detector_invocations, rates)
    line = (f"ExSample[{res.kind}]: {sum(res.results)} results / "
            f"{st.frames_sampled:,} frames sampled / "
            f"{st.detector_invocations:,} detector invocations")
    if st.cache_hits or res.num_queries > 1:
        line += (f" ({st.cache_hits:,} cache hits, "
                 f"hit rate {st.cache_hit_rate:.2f}, "
                 f"{st.amortization:.2f}x amortization)")
    print(line + f" / est. {cost.total_s:.0f} gpu·s "
          f"(driver wall {wall:.1f}s)")
    if st.merges:
        print(f"  merges: {st.merges} windows, ring high-water "
              f"{st.merge_high_water}/{st.matcher_capacity}"
              + (f", {st.results_spilled} results spilled to host log"
                 if st.results_spilled else "")
              + (" OVERFLOW" if st.merge_overflow else ""))


def _run_elastic_smoke(plan, carry, chunks, det, select, args) -> None:
    """--kill-worker path: drive the plan through ElasticShardedRunner on a
    synthetic boundary clock, silencing the listed workers after
    ``--kill-after-windows`` windows; the monitor's dead verdict lands two
    boundaries later and the search finishes on the shrunken mesh."""
    import numpy as np

    from repro.core.runtime import ElasticShardedRunner
    from repro.distributed.fault_tolerance import HeartbeatMonitor

    ex = plan.execution
    cache = ex.cache if ex.cache is not None else 0
    if cache == -1:
        cache = chunks.total_frames
    t = [0.0]

    def clock():
        t[0] += 100.0
        return t[0]

    runner = ElasticShardedRunner(
        carry, chunks, detector=det, result_limits=plan.result_limit,
        max_steps=plan.max_steps, num_shards=ex.shards,
        cohorts=plan.cohorts, sync_every=ex.sync_every, select=select,
        cache_frames=cache,
        monitor=HeartbeatMonitor(suspect_after_s=50.0, dead_after_s=150.0),
        clock=clock, sync_windows=1,
    )
    t0 = time.time()
    windows = 0
    while True:
        alive = runner.step()
        windows += 1
        if windows == args.kill_after_windows:
            for w in args.kill_worker:
                print(f"elastic: worker {w} silenced after window {windows}")
                runner.kill_worker(w)
        if not alive:
            break
    wall = time.time() - t0
    out, stats = runner.carry, runner.stats
    for ev in stats["reshard_events"]:
        print(f"elastic: reshard @window {ev['window']}: "
              f"{ev['from_shards']} -> {ev['to_shards']} shards "
              f"(dead={ev['dead']})")
    results = np.asarray(out.results).tolist()
    print(f"elastic: finished on {runner.num_shards} shards: "
          f"{sum(results)} results / "
          f"{int(np.asarray(out.step).sum()):,} frames sampled / "
          f"{stats['detector_invocations']:,} detector invocations "
          f"({stats['cache_hits']:,} cache hits) "
          f"(driver wall {wall:.1f}s)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--plan", default="",
                    help="SearchPlan JSON (or @file) — the canonical path "
                         "(DESIGN.md §10); overrides the deprecated "
                         "driver-shaping flags below")
    ap.add_argument("--dataset", default="dashcam", choices=["dashcam", "bdd"])
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--query-class", type=int, default=0)
    ap.add_argument("--limit", type=int, default=50)
    ap.add_argument("--cohorts", type=int, default=16)
    ap.add_argument("--max-steps", type=int, default=50_000)
    ap.add_argument("--detector", default="oracle", choices=["oracle", "noisy"])
    ap.add_argument("--driver", default="scan", choices=["scan", "host"],
                    help="[deprecated: use --plan] scan = device-resident "
                         "driver; host = per-step reference loop")
    ap.add_argument("--mesh", type=int, default=1,
                    help="[deprecated: use --plan] N>1 shards the search "
                         "over an N-way data mesh (DESIGN.md §8); simulated "
                         "host devices are forced automatically")
    ap.add_argument("--sync-every", type=int, default=1,
                    help="[deprecated: use --plan] rounds between "
                         "sampler/matcher merges on the mesh lowerings")
    ap.add_argument("--queries", type=int, nargs="+", default=None,
                    metavar="CLASS",
                    help="[deprecated: use --plan] one concurrent search per "
                         "listed query class through the Q-axis lowering "
                         "(DESIGN.md §9); with --plan, lists the per-query "
                         "classes (default 0..Q-1)")
    ap.add_argument("--cache-frames", type=int, default=-1,
                    help="[deprecated: use --plan] detection-cache capacity "
                         "for --queries (-1 = one slot per repository "
                         "frame, 0 = off)")
    ap.add_argument("--kill-worker", type=int, action="append", default=[],
                    metavar="W",
                    help="elastic-shrink smoke (multi-sharded plans only): "
                         "silence worker W mid-run and recover on the "
                         "survivors via ElasticShardedRunner (repeatable)")
    ap.add_argument("--kill-after-windows", type=int, default=2,
                    help="sync windows to run before the --kill-worker "
                         "workers go silent")
    ap.add_argument("--baseline", action="store_true",
                    help="also run random+ for comparison")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    plan = build_plan(args)
    lowered = plan.lower()   # validate BEFORE re-exec / data generation

    if plan.execution.shards > 1:
        from repro.launch.mesh import ensure_host_devices

        ensure_host_devices(
            plan.execution.shards,
            argv=[sys.executable, "-m", "repro.launch.search"] + sys.argv[1:],
        )

    setup = (dashcam if args.dataset == "dashcam" else bdd)(
        seed=args.seed, scale=args.scale
    )
    repo, chunks = generate(setup.repo)
    print(f"{args.dataset}: {chunks.total_frames:,} frames / "
          f"{chunks.num_chunks} chunks / {repo.num_instances} instances")
    print(f"plan: lowering={lowered.kind} method={lowered.method} "
          f"{json.dumps(plan.to_dict())}")

    q_n = plan.queries
    multi = lowered.kind in ("multi", "multi_sharded", "async_multi")
    select = None
    if multi:
        classes = args.queries if args.queries else list(range(q_n))
        if len(classes) != q_n:
            raise SystemExit(
                f"--queries lists {len(classes)} classes for a "
                f"{q_n}-query plan")
        if args.detector == "oracle":
            det = lambda key, frame: oracle_detect(
                repo, frame, query_class=None)
        else:
            det = lambda key, frame: noisy_detect(
                key, repo, frame, query_class=None)
        select = class_select(repo, classes)
        keys = jnp.stack([
            jax.random.fold_in(jax.random.PRNGKey(args.seed), q)
            for q in range(q_n)
        ])
        carry = init_carry_multi(
            init_state(chunks.length), init_matcher(max_results=8192), keys
        )
    else:
        if args.detector == "oracle":
            det = lambda key, frame: oracle_detect(
                repo, frame, query_class=args.query_class)
        else:
            det = lambda key, frame: noisy_detect(
                key, repo, frame, query_class=args.query_class)
        carry = init_carry(
            init_state(chunks.length), init_matcher(max_results=8192),
            jax.random.PRNGKey(args.seed),
        )

    if args.kill_worker:
        if lowered.kind != "multi_sharded":
            raise SystemExit(
                "--kill-worker needs a queries_axis + shards>1 plan "
                f"(multi_sharded lowering, got {lowered.kind})")
        _run_elastic_smoke(plan, carry, chunks, det, select, args)
        return

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    t0 = time.time()
    res = lowered.run(carry, chunks, detector=det, select=select)
    wall = time.time() - t0
    _print_result(res, args, wall)
    if mgr:
        mgr.save(res.stats.frames_sampled, res.carry,
                 extra={"plan": plan.to_dict()})
        print(f"state checkpointed to {args.ckpt_dir}")
    if args.baseline and not multi:
        base = init_carry(
            init_state(chunks.length), init_matcher(max_results=8192),
            jax.random.PRNGKey(args.seed),
        )
        rp, _ = run_schedule(
            base, chunks,
            FrameSchedule.randomplus(chunks.total_frames, plan.max_steps),
            detector=det, result_limit=res.plan.result_limit
            if isinstance(res.plan.result_limit, int) else args.limit,
        )
        ex_steps = max(res.stats.frames_sampled, 1)
        print(f"random+: {int(rp.results)} results / {int(rp.step):,} frames "
              f"→ savings {int(rp.step) / ex_steps:.2f}x")


if __name__ == "__main__":
    main()
