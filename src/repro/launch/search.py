"""Production search driver: ExSample distinct-object query end-to-end.

Wires together: simulated repository (or any FrameStore), the batcher, a
detector (oracle or neural backbone), the ExSample core, the cost model
and the checkpoint manager — the full Algorithm 1 deployment loop with
resumable state.

  PYTHONPATH=src python -m repro.launch.search --limit 50 --cohorts 16
  PYTHONPATH=src python -m repro.launch.search --limit 50 --mesh 4
  PYTHONPATH=src python -m repro.launch.search --limit 20 --queries 0 1 2 3

``--mesh N`` runs the sharded device-resident driver
(``run_search_sharded``, DESIGN.md §8) on an N-way ``data`` mesh.  When
the host exposes fewer devices, ``main()`` re-execs into a child with
simulated host devices (``launch.mesh.ensure_host_devices``).

``--queries c0 c1 …`` runs one concurrent search per listed query class
through ``run_search_multi`` (DESIGN.md §9): a single class-agnostic
detector pass per round is deduplicated and cached across the queries,
and each query filters the shared detections to its own class.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.exsample_paper import bdd, dashcam
from repro.core import (
    init_carry,
    init_carry_multi,
    init_matcher,
    init_state,
    run_search,
    run_search_multi,
    run_search_scan,
    run_search_sharded,
)
from repro.core.baselines import FrameSchedule, run_schedule
from repro.sim import generate
from repro.sim.costmodel import CostRates, sampling_cost
from repro.sim.oracle import class_select, noisy_detect, oracle_detect
from repro.train.checkpoint import CheckpointManager


def _run_multi(args, repo, chunks) -> None:
    """--queries path: Q concurrent class searches through one shared,
    deduplicated + cached detector pass per round (DESIGN.md §9)."""
    q_n = len(args.queries)
    if args.detector == "oracle":
        det = lambda key, frame: oracle_detect(repo, frame, query_class=None)
    else:
        det = lambda key, frame: noisy_detect(key, repo, frame, query_class=None)
    select = class_select(repo, args.queries)

    keys = jnp.stack([
        jax.random.fold_in(jax.random.PRNGKey(args.seed), q) for q in range(q_n)
    ])
    carries = init_carry_multi(
        init_state(chunks.length), init_matcher(max_results=8192), keys
    )
    cache = args.cache_frames if args.cache_frames >= 0 else chunks.total_frames
    t0 = time.time()
    out, traces, stats = run_search_multi(
        carries, chunks, detector=det, select=select,
        result_limits=args.limit, max_steps=args.max_steps,
        cohorts=args.cohorts, trace_every=256, cache_frames=cache,
    )
    wall = time.time() - t0
    steps = [int(s) for s in out.step]
    results = [int(r) for r in out.results]
    for q in range(q_n):
        print(f"  query class {args.queries[q]}: {results[q]} results / "
              f"{steps[q]:,} frames")
    inv = stats["detector_invocations"]
    rates = CostRates()
    print(f"ExSample multi-query (Q={q_n}): {sum(results)} results / "
          f"{stats['frames_sampled']:,} frames sampled / {inv:,} detector "
          f"invocations ({stats['cache_hits']:,} cache hits, "
          f"{stats['frames_sampled'] / max(inv, 1):.2f}x amortization) / "
          f"est. {sampling_cost(inv, rates).total_s:.0f} gpu·s "
          f"(driver wall {wall:.1f}s)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="dashcam", choices=["dashcam", "bdd"])
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--query-class", type=int, default=0)
    ap.add_argument("--limit", type=int, default=50)
    ap.add_argument("--cohorts", type=int, default=16)
    ap.add_argument("--max-steps", type=int, default=50_000)
    ap.add_argument("--detector", default="oracle", choices=["oracle", "noisy"])
    ap.add_argument("--driver", default="scan", choices=["scan", "host"],
                    help="scan = device-resident lax.while_loop driver "
                         "(DESIGN.md §7); host = per-step reference loop")
    ap.add_argument("--mesh", type=int, default=1,
                    help="N>1 runs run_search_sharded on an N-way data mesh "
                         "(DESIGN.md §8); simulated host devices are forced "
                         "automatically")
    ap.add_argument("--sync-every", type=int, default=1,
                    help="rounds between sampler/matcher merges on the "
                         "sharded driver (eventual-consistency Thompson)")
    ap.add_argument("--queries", type=int, nargs="+", default=None,
                    metavar="CLASS",
                    help="multi-query mode (DESIGN.md §9): one concurrent "
                         "search per listed query class, sharing a single "
                         "deduplicated+cached class-agnostic detector pass "
                         "per round (run_search_multi)")
    ap.add_argument("--cache-frames", type=int, default=-1,
                    help="detection-cache capacity for --queries "
                         "(-1 = one slot per repository frame, 0 = off)")
    ap.add_argument("--baseline", action="store_true",
                    help="also run random+ for comparison")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    if args.mesh > 1:
        from repro.launch.mesh import ensure_host_devices

        ensure_host_devices(
            args.mesh,
            argv=[sys.executable, "-m", "repro.launch.search"] + sys.argv[1:],
        )

    setup = (dashcam if args.dataset == "dashcam" else bdd)(
        seed=args.seed, scale=args.scale
    )
    repo, chunks = generate(setup.repo)
    print(f"{args.dataset}: {chunks.total_frames:,} frames / "
          f"{chunks.num_chunks} chunks / {repo.num_instances} instances")

    if args.queries:
        _run_multi(args, repo, chunks)
        return

    if args.detector == "oracle":
        det = lambda key, frame: oracle_detect(
            repo, frame, query_class=args.query_class
        )
    else:
        det = lambda key, frame: noisy_detect(
            key, repo, frame, query_class=args.query_class
        )

    carry = init_carry(
        init_state(chunks.length),
        init_matcher(max_results=8192),
        jax.random.PRNGKey(args.seed),
    )
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    t0 = time.time()
    if args.mesh > 1:
        from repro.launch.mesh import make_data_mesh

        cohorts = args.cohorts - args.cohorts % args.mesh or args.mesh
        if cohorts != args.cohorts:
            print(f"--cohorts {args.cohorts} → {cohorts} "
                  f"(must be a multiple of --mesh {args.mesh})")
        if args.driver != "scan":
            print(f"--driver {args.driver} ignored: --mesh {args.mesh} "
                  "selects the sharded driver (DESIGN.md §8)")
        carry, trace = run_search_sharded(
            carry, chunks, mesh=make_data_mesh(args.mesh), detector=det,
            result_limit=args.limit, max_steps=args.max_steps,
            cohorts=cohorts, sync_every=args.sync_every,
        )
    else:
        driver = run_search_scan if args.driver == "scan" else run_search
        carry, trace = driver(
            carry, chunks, detector=det, result_limit=args.limit,
            max_steps=args.max_steps, cohorts=args.cohorts, trace_every=256,
        )
    wall = time.time() - t0
    rates = CostRates()
    cost = sampling_cost(int(carry.step), rates)
    print(f"ExSample: {int(carry.results)} results / {int(carry.step):,} frames "
          f"/ est. {cost.total_s:.0f} gpu·s (driver wall {wall:.1f}s)")
    if mgr:
        mgr.save(int(carry.step), carry, extra={"query": args.query_class})
        print(f"state checkpointed to {args.ckpt_dir}")
    if args.baseline:
        base = init_carry(
            init_state(chunks.length), init_matcher(max_results=8192),
            jax.random.PRNGKey(args.seed),
        )
        rp, _ = run_schedule(
            base, chunks,
            FrameSchedule.randomplus(chunks.total_frames, args.max_steps),
            detector=det, result_limit=args.limit,
        )
        print(f"random+: {int(rp.results)} results / {int(rp.step):,} frames "
              f"→ savings {int(rp.step) / max(int(carry.step), 1):.2f}x")


if __name__ == "__main__":
    main()
