import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be the process entry (``python -m repro.launch.dryrun``) — the XLA
flag above executes before any jax import so the host platform exposes
512 placeholder devices for the production meshes.

For each cell: ``jax.jit(step, in_shardings=…).lower(*specs).compile()``,
then record memory_analysis / cost_analysis / collective schedule into
``artifacts/dryrun/<mesh>/<arch>__<shape>.json`` (consumed by the roofline
table + EXPERIMENTS.md §Dry-run).

Usage:
  python -m repro.launch.dryrun                       # all cells, both meshes
  python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
  python -m repro.launch.dryrun --mesh single         # 16×16 only
  python -m repro.launch.dryrun --optimized           # perf-pass RunConfig
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.analysis.roofline import from_compiled
from repro.configs import ARCHS, SHAPES_BY_NAME, cells
from repro.configs.base import RunConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "../../../artifacts/dryrun")


def optimized_run(shape) -> RunConfig:
    """Uniform beyond-paper configuration (EXPERIMENTS.md §Perf): the
    across-the-board winners from the hillclimbs — triangular causal block
    enumeration at 2048 blocks + congruent 8-bit optimizer state.  The
    per-cell tuned variants (SP/µbatch/FSDP points) are reported in §Perf."""
    return RunConfig(
        unroll=True,
        block_q=2048,
        block_kv=2048,
        causal_block_skip=True,
        sequence_parallel=False,
        remat=shape.kind == "train",
        microbatches=0,     # auto via build_cell default path
        adam_8bit=True,
    )


def _lower_compile(fn, in_shardings, args, mesh, *, donate=(), out_shardings=None,
                   rules=None):
    from repro.distributed.sharding import ShardingRules, use_rules

    if rules is None:
        rules = ShardingRules.for_mesh(mesh)
    kw = {}
    if out_shardings is not None:
        kw["out_shardings"] = out_shardings
    with mesh:
        with use_rules(rules):
            lowered = jax.jit(
                fn, in_shardings=in_shardings, donate_argnums=donate, **kw
            ).lower(*args)
            compiled = lowered.compile()
    return lowered, compiled


def run_cell(arch: str, shape_name: str, mesh, mesh_tag: str, *,
             run_cfg=None, save: bool = True, verbose: bool = True) -> dict:
    """Two-config lowering (DESIGN.md §6):
      cost config — unrolled layers, lower-only: ``lowered.cost_analysis``
        gives exact global FLOPs/bytes without a backend compile (for
        µbatched train, the grad body × k);
      exec config — scan-over-layers + µbatch scan, fully compiled: buffer-
        reusing ``memory_analysis`` + the SPMD collective schedule, scaled
        by while trip counts.  Decode cells compile their step directly
        (small graphs)."""
    from repro.analysis.hlo import collective_bytes_scaled
    from repro.launch.specs import build_mem_cell

    cfg = ARCHS[arch]
    shape = SHAPES_BY_NAME[shape_name]
    cell = build_cell(cfg, shape, mesh, run=run_cfg)
    chips = mesh.devices.size
    k = cell.scan_repeats
    t0 = time.time()

    # ---- cost config (lower only — no backend compile) ---------------------
    from repro.distributed.sharding import ShardingRules, use_rules

    cell_rules = ShardingRules.for_mesh(mesh, fsdp_params=cell.run.fsdp_params)
    with mesh:
        with use_rules(cell_rules):
            if cell.body_fn is not None:      # µbatched train: body × k
                lowered_cost = jax.jit(
                    cell.body_fn, in_shardings=cell.body_in_shardings
                ).lower(*cell.body_args)
                scale = float(k)
                cost_scope = f"grad_body x{k} (lowered)"
            else:
                lowered_cost = jax.jit(
                    cell.step_fn, in_shardings=cell.in_shardings
                ).lower(*cell.args)
                scale = 1.0
                cost_scope = "full_step (lowered)"
    ca = lowered_cost.cost_analysis()
    flops_global = float(ca.get("flops", 0.0)) * scale
    bytes_global = float(ca.get("bytes accessed", 0.0)) * scale
    t_cost = time.time() - t0

    # ---- exec config: compiled (memory + collectives) ----------------------
    t1 = time.time()
    mem_cell = build_mem_cell(cfg, shape, mesh, run=run_cfg)
    if mem_cell is not None:
        donate = (0,) if shape.kind == "train" else ()   # state is donated
        _, compiled_mem = _lower_compile(
            mem_cell.step_fn, mem_cell.in_shardings, mem_cell.args, mesh,
            donate=donate, out_shardings=mem_cell.out_shardings,
            rules=ShardingRules.for_mesh(
                mesh, fsdp_params=mem_cell.run.fsdp_params),
        )
    else:
        _, compiled_mem = _lower_compile(
            cell.step_fn, cell.in_shardings, cell.args, mesh, rules=cell_rules
        )
    mem_stats = compiled_mem.memory_analysis()
    coll = collective_bytes_scaled(compiled_mem.as_text())
    t_mem = time.time() - t1

    # ---- merge ------------------------------------------------------------
    from repro.analysis.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

    rec = {
        "name": cell.name,
        "mesh": mesh_tag,
        "chips": chips,
        "model_flops": cell.model_flops,
    }
    rec["hlo_flops_global"] = flops_global
    rec["hlo_flops_per_dev"] = flops_global / chips
    rec["hlo_bytes_per_dev"] = bytes_global / chips
    rec["collective"] = coll
    rec["arg_bytes"] = float(mem_stats.argument_size_in_bytes)
    rec["temp_bytes"] = float(mem_stats.temp_size_in_bytes)
    rec["out_bytes"] = float(mem_stats.output_size_in_bytes)
    rec["alias_bytes"] = float(mem_stats.alias_size_in_bytes)
    rec["t_compute_s"] = rec["hlo_flops_per_dev"] / PEAK_FLOPS
    rec["t_memory_s"] = rec["hlo_bytes_per_dev"] / HBM_BW
    rec["t_collective_s"] = rec["collective"]["total_bytes"] / ICI_BW
    terms = {
        "compute": rec["t_compute_s"],
        "memory": rec["t_memory_s"],
        "collective": rec["t_collective_s"],
    }
    rec["bottleneck"] = max(terms, key=terms.get)
    rec["step_time_s"] = max(terms.values())
    total = rec["hlo_flops_per_dev"] * chips
    rec["useful_flops_ratio"] = rec["model_flops"] / total if total else 0.0
    rec["mfu_at_roofline"] = (
        rec["model_flops"] / (rec["step_time_s"] * chips * PEAK_FLOPS)
        if rec["step_time_s"]
        else 0.0
    )
    rec["hbm_footprint_bytes"] = (
        rec["arg_bytes"] + rec["temp_bytes"] + rec["out_bytes"]
        - rec["alias_bytes"]
    )
    rec["fits_hbm_cpu_analysis"] = rec["hbm_footprint_bytes"] <= 16 * 1024**3
    from repro.launch.specs import analytic_hbm

    rec.update(analytic_hbm(cell, mesh, shape))
    rec["fits_hbm"] = rec["analytic_fits_hbm"]
    rec["scan_repeats"] = k
    rec["cost_scope"] = cost_scope
    mem = mem_stats
    rec["t_mem_config_s"] = t_mem
    rec["t_cost_config_s"] = t_cost
    rec["decode_tokens"] = cell.decode_tokens
    if verbose:
        print(
            f"[{mesh_tag}] {cell.name:45s} ok  "
            f"flops/dev={rec['hlo_flops_per_dev']:.3e} "
            f"bytes/dev={rec['hlo_bytes_per_dev']:.3e} "
            f"coll={rec['collective']['total_bytes']:.3e} "
            f"hbm_cpu={rec['hbm_footprint_bytes']/2**30:.2f}GiB "
            f"hbm_tpu~{rec['analytic_hbm_bytes']/2**30:.2f}GiB "
            f"fits={rec['fits_hbm']} "
            f"bottleneck={rec['bottleneck']} "
            f"t={t_cost:.1f}+{t_mem:.1f}s",
            flush=True,
        )
        # the two mandated prints:
        print(f"  memory_analysis: {mem}", flush=True)
        print(f"  cost_analysis: flops={flops_global:.4g} (global, scaled x{scale:.0f})",
              flush=True)
    if save:
        d = os.path.join(ARTIFACT_DIR, mesh_tag)
        os.makedirs(d, exist_ok=True)
        fname = f"{arch.replace('/', '_')}__{shape_name}.json"
        with open(os.path.join(d, fname), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch id")
    ap.add_argument("--shape", default=None, help="single shape name")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--optimized", action="store_true",
                    help="use the perf-pass RunConfig (separate artifact tag)")
    ap.add_argument("--no-save", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    todo = []
    for arch, shape, skipped in cells(include_skipped=True):
        if args.arch and arch != args.arch:
            continue
        if args.shape and shape.name != args.shape:
            continue
        todo.append((arch, shape, skipped))

    failures = []
    for mesh_tag, mesh in meshes:
        tag = mesh_tag + ("_optimized" if args.optimized else "")
        for arch, shape, skipped in todo:
            if skipped:
                print(f"[{tag}] {arch}:{shape.name:12s} SKIP (full attention at 524288 — see DESIGN.md §4)",
                      flush=True)
                if not args.no_save:
                    d = os.path.join(ARTIFACT_DIR, tag)
                    os.makedirs(d, exist_ok=True)
                    with open(os.path.join(d, f"{arch}__{shape.name}.json"), "w") as f:
                        json.dump({"name": f"{arch}:{shape.name}", "mesh": tag,
                                   "skipped": "full-attention arch at 500k decode"}, f)
                continue
            try:
                rc = optimized_run(shape) if args.optimized else None
                run_cell(arch, shape.name, mesh, tag, run_cfg=rc,
                         save=not args.no_save)
            except Exception as e:  # noqa: BLE001 — report all failures at end
                failures.append((tag, arch, shape.name, repr(e)))
                print(f"[{tag}] {arch}:{shape.name} FAILED: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", *f)
        return 1
    print("\nall dry-run cells compiled OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
