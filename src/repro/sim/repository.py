"""Synthetic video repository (paper §3.3.2 generalized with locality).

The paper validates ExSample on (a) simulation with lognormal-skewed
instance durations and (b) dashcam datasets whose key property is *temporal
locality* (traffic lights cluster in city driving, §3.5).  This module
generates repositories with both properties and an *oracle detector* so the
whole search loop is measurable without real video:

  * N instances; duration (in frames) ~ LogNormal(μ, σ), clipped to the
    video;  each instance occupies one contiguous interval.
  * instance *placement* is drawn from a per-chunk intensity vector with
    Dirichlet-controlled skew — `locality=0` scatters uniformly (random
    sampling ≈ ExSample), larger values concentrate instances in few chunks
    (ExSample's favourable regime, §3.5).
  * every instance has a ground-truth box track (linear drift) and a stable
    appearance feature — the oracle emits noisy detections (misses, false
    positives, box jitter) with a fixed detection-slot budget D so the
    pipeline is statically shaped.

Everything the device needs is packed into ``Repository`` (a pytree of
dense arrays) so detection lookup jits and shards.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chunks import ChunkIndex, build_chunks


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Repository:
    """Dense ground truth for a synthetic repository (N instances)."""

    # instance intervals, global frame coordinates
    inst_video: jax.Array    # i32[N]
    inst_start: jax.Array    # i32[N]
    inst_end: jax.Array      # i32[N]  (exclusive)
    # box track: box(t) = base + (t - start) * drift   (normalized coords)
    inst_box: jax.Array      # f32[N, 4]
    inst_drift: jax.Array    # f32[N, 4]
    inst_feat: jax.Array     # f32[N, F]
    inst_class: jax.Array    # i32[N]  — query class of the instance
    # frame geometry
    video_of_frame: jax.Array  # i32[T] — owning video per global frame
    total_frames: int = dataclasses.field(metadata=dict(static=True), default=0)
    num_videos: int = dataclasses.field(metadata=dict(static=True), default=0)

    @property
    def num_instances(self) -> int:
        return self.inst_video.shape[0]


@dataclasses.dataclass(frozen=True)
class RepoSpec:
    """Generation parameters."""

    video_lengths: Sequence[int]
    num_instances: int = 500
    num_classes: int = 4
    duration_mu: float = 5.0          # lognormal mean of log-frames (~150f)
    duration_sigma: float = 1.5       # heavy skew, as in §3.3.2
    locality: float = 3.0             # Dirichlet concentration skew; 0 = uniform
    feat_dim: int = 8
    chunk_frames: int = 54_000        # 30 min @ 30 fps
    seed: int = 0


def generate(spec: RepoSpec) -> tuple[Repository, ChunkIndex]:
    rng = np.random.default_rng(spec.seed)
    lengths = np.asarray(spec.video_lengths, np.int64)
    total = int(lengths.sum())
    starts = np.concatenate([[0], np.cumsum(lengths)[:-1]])
    chunks = build_chunks(
        [int(l) for l in lengths], chunk_frames=spec.chunk_frames, seed=spec.seed
    )
    c_start = np.asarray(chunks.start)
    c_len = np.asarray(chunks.length)
    M = len(c_start)

    # --- placement: per-chunk intensity with controllable skew -------------
    if spec.locality > 0:
        # small alpha ⇒ mass concentrates on few chunks ⇒ high locality
        alpha = np.full(M, 1.0 / spec.locality)
        intensity = rng.dirichlet(alpha)
    else:
        intensity = np.full(M, 1.0 / M)
    inst_chunk = rng.choice(M, size=spec.num_instances, p=intensity)

    # --- durations: lognormal frames, clipped to chunk+video ---------------
    dur = np.exp(rng.normal(spec.duration_mu, spec.duration_sigma, spec.num_instances))
    dur = np.clip(dur, 1, None).astype(np.int64)

    inst_start = np.empty(spec.num_instances, np.int64)
    inst_end = np.empty(spec.num_instances, np.int64)
    inst_video = np.empty(spec.num_instances, np.int64)
    vid_of_chunk = np.asarray(chunks.video_id)
    for i in range(spec.num_instances):
        c = inst_chunk[i]
        v = vid_of_chunk[c]
        vlo, vhi = starts[v], starts[v] + lengths[v]
        # anchor uniformly inside the chunk; clip interval to the video
        anchor = c_start[c] + rng.integers(0, c_len[c])
        s = max(vlo, anchor - dur[i] // 2)
        e = min(vhi, s + dur[i])
        inst_start[i], inst_end[i], inst_video[i] = s, e, v

    boxes = rng.uniform(0.05, 0.75, (spec.num_instances, 2))
    sizes = rng.uniform(0.05, 0.2, (spec.num_instances, 2))
    base = np.concatenate([boxes, boxes + sizes], axis=1).astype(np.float32)
    drift = rng.normal(0, 1e-4, (spec.num_instances, 4)).astype(np.float32)
    feats = rng.normal(0, 1, (spec.num_instances, spec.feat_dim)).astype(np.float32)
    feats /= np.linalg.norm(feats, axis=1, keepdims=True)
    classes = rng.integers(0, spec.num_classes, spec.num_instances)

    video_of_frame = np.repeat(np.arange(len(lengths)), lengths)
    repo = Repository(
        inst_video=jnp.asarray(inst_video, jnp.int32),
        inst_start=jnp.asarray(inst_start, jnp.int32),
        inst_end=jnp.asarray(inst_end, jnp.int32),
        inst_box=jnp.asarray(base),
        inst_drift=jnp.asarray(drift),
        inst_feat=jnp.asarray(feats),
        inst_class=jnp.asarray(classes, jnp.int32),
        video_of_frame=jnp.asarray(video_of_frame, jnp.int32),
        total_frames=total,
        num_videos=len(lengths),
    )
    return repo, chunks


def instances_visible(repo: Repository, frame: jax.Array) -> jax.Array:
    """bool[N] — ground-truth visibility of each instance in ``frame``."""
    return (repo.inst_start <= frame) & (frame < repo.inst_end)


def duration_probabilities(repo: Repository, chunks: ChunkIndex) -> jax.Array:
    """p_i of the paper: probability a uniformly random frame (of the whole
    dataset) shows instance i = duration_i / total_frames."""
    dur = (repo.inst_end - repo.inst_start).astype(jnp.float32)
    return dur / float(repo.total_frames)


def chunk_hit_rates(repo: Repository, chunks: ChunkIndex) -> jax.Array:
    """f32[M] — expected NEW results per fresh frame of each chunk at n=0:
    Σ_i overlap(i, chunk)/chunk_len.  Ground truth for regret diagnostics."""
    cs = chunks.start[:, None].astype(jnp.float32)
    ce = (chunks.start + chunks.length)[:, None].astype(jnp.float32)
    s = repo.inst_start[None, :].astype(jnp.float32)
    e = repo.inst_end[None, :].astype(jnp.float32)
    overlap = jnp.maximum(jnp.minimum(ce, e) - jnp.maximum(cs, s), 0.0)
    return jnp.sum(overlap, axis=1) / jnp.maximum(
        chunks.length.astype(jnp.float32), 1.0
    )
