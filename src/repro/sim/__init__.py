"""Simulation substrate: synthetic video repositories + oracle detectors."""
from repro.sim.repository import Repository, RepoSpec, generate, chunk_hit_rates, duration_probabilities
from repro.sim.oracle import Detections, oracle_detect, noisy_detect, frame_embedding

__all__ = [
    "Repository", "RepoSpec", "generate", "chunk_hit_rates", "duration_probabilities",
    "Detections", "oracle_detect", "noisy_detect", "frame_embedding",
]
