"""Query-cost model (paper §4.6, Fig. 6).

ExSample's evaluation metric is *frames processed*, but the paper's headline
wall-clock comparison against surrogate systems hinges on the per-phase
throughput structure:

  labelling  (detector-bound)       ~ 10 fps/GPU in the paper
  training   (surrogate fit)        ~ cheap, memory-resident
  scoring    (scan-bound)           ~ 100 fps — I/O + decode dominate
  sampling   (detector-bound)       the ONLY phase ExSample/random+ pay

This module prices a query plan under configurable hardware rates so the
benchmarks can reproduce Fig. 3/4 (time savings) and Fig. 6 (phase
breakdown) without real video.  Rates are derived from the same roofline
constants used in ``repro.analysis.roofline`` when a backbone config is
given, or taken from the paper's reported numbers by default.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class CostRates:
    """Per-frame processing rates (frames/second/worker)."""

    detect_fps: float = 10.0          # full model (Faster-RCNN class)
    surrogate_fps: float = 1000.0     # cheap scorer, compute only
    scan_fps: float = 100.0           # sequential I/O + decode bound
    random_read_fps: float = 50.0     # keyframe-seek random decode
    train_examples_per_s: float = 2000.0
    workers: int = 1

    @staticmethod
    def from_backbone(flops_per_frame: float, *, peak_flops: float = 197e12,
                      mfu: float = 0.4, workers: int = 1,
                      surrogate_flops_per_frame: Optional[float] = None) -> "CostRates":
        """Derive detector/surrogate fps from model FLOPs at an assumed MFU."""
        detect = peak_flops * mfu / max(flops_per_frame, 1.0)
        sur = (
            peak_flops * mfu / max(surrogate_flops_per_frame, 1.0)
            if surrogate_flops_per_frame
            else 1000.0
        )
        return CostRates(detect_fps=detect, surrogate_fps=sur, workers=workers)


@dataclasses.dataclass(frozen=True)
class PhaseCosts:
    label_s: float = 0.0
    train_s: float = 0.0
    score_s: float = 0.0
    sample_s: float = 0.0

    @property
    def total_s(self) -> float:
        return self.label_s + self.train_s + self.score_s + self.sample_s

    @property
    def fixed_s(self) -> float:
        """Up-front cost paid before the first result can be returned."""
        return self.label_s + self.train_s + self.score_s


def sampling_cost(frames_processed: int, rates: CostRates) -> PhaseCosts:
    """Cost of a pure sampling policy (ExSample, random+, greedy):
    random-access decode + full-model inference per processed frame."""
    per_frame = 1.0 / rates.detect_fps + 1.0 / rates.random_read_fps
    return PhaseCosts(sample_s=frames_processed * per_frame / rates.workers)


def surrogate_cost(
    frames_processed: int,
    total_frames: int,
    *,
    rates: CostRates,
    label_fraction: float = 0.01,
    train_epochs: float = 2.0,
) -> PhaseCosts:
    """BlazeIt-style plan: label a fraction with the full model, fit the
    surrogate, score EVERY frame (scan-bound), then sample by score."""
    labeled = total_frames * label_fraction
    label_s = labeled * (1.0 / rates.detect_fps + 1.0 / rates.scan_fps)
    train_s = labeled * train_epochs / rates.train_examples_per_s
    # scoring is a full sequential scan; throughput min(scan, surrogate)
    score_fps = min(rates.scan_fps, rates.surrogate_fps)
    score_s = total_frames / score_fps
    sample = sampling_cost(frames_processed, rates).sample_s
    return PhaseCosts(
        label_s=label_s / rates.workers,
        train_s=train_s / rates.workers,
        score_s=score_s / rates.workers,
        sample_s=sample,
    )


def full_scan_cost(total_frames: int, rates: CostRates) -> PhaseCosts:
    """Naive plan: run the detector on every frame sequentially."""
    per_frame = 1.0 / rates.detect_fps + 1.0 / rates.scan_fps
    return PhaseCosts(sample_s=total_frames * per_frame / rates.workers)


# ---------------------------------------------------------------------------
# Service-side budget accounting (DESIGN.md §12)
# ---------------------------------------------------------------------------


def plan_projected_cost(
    plan,
    rates: CostRates,
    *,
    index=None,
    total_frames: Optional[int] = None,
) -> PhaseCosts:
    """Conservative admission-time price of a :class:`SearchPlan`: every
    query runs its full ``max_steps`` frame budget as a pure sampling
    policy.  An upper bound by construction — queries that hit their
    result limit early, and frames served from the detection cache, only
    make the realized cost cheaper — so pricing it BEFORE admission is
    race-free: the service debits the projection and credits the unspent
    remainder at retirement.

    When the plan binds an :class:`~repro.core.plan.IndexSpec` and the
    caller passes the live ``index`` plus the repository ``total_frames``,
    the detector component is discounted by the index's measured coverage
    for the plan's declared ``detector_version`` — a fully-persisted warm
    replay needs ~0 fresh detector calls, and pricing it cold rejects
    plans that cost nearly nothing.  Still an upper bound: coverage is a
    frame-population fraction (sampling without the exact hit set can only
    do better on average than the uniform discount assumes is certain),
    and the projection is clamped to ≥ the scan-only cost — every sampled
    frame pays its random-access read even when its detection replays."""
    frames = plan.queries * plan.max_steps
    cold = sampling_cost(frames, rates)
    spec = getattr(plan.execution, "index", None)
    if index is None or spec is None or not total_frames:
        return cold
    coverage = min(
        1.0, index.entries(spec.detector_version) / float(total_frames)
    )
    if coverage <= 0.0:
        return cold
    detect_s = frames * (1.0 - coverage) / rates.detect_fps
    scan_only_s = frames / rates.random_read_fps
    sample_s = max(detect_s + scan_only_s, scan_only_s) / rates.workers
    return PhaseCosts(sample_s=min(sample_s, cold.sample_s))


@dataclasses.dataclass
class CostBudget:
    """Admission-controlled spend ledger for the search service.

    ``total_s`` is the wall-clock (priced, not measured) budget the
    operator grants; ``committed_s`` holds projections of admitted,
    still-running plans; ``spent_s`` holds settled actuals.  ``debit``
    reserves a projection atomically-enough for the service's single
    admission thread; ``settle`` converts a reservation into its realized
    cost, crediting the difference back to headroom."""

    total_s: float
    committed_s: float = 0.0
    spent_s: float = 0.0

    @property
    def remaining_s(self) -> float:
        return self.total_s - self.committed_s - self.spent_s

    def admits(self, projected_s: float) -> bool:
        return projected_s <= self.remaining_s

    def debit(self, projected_s: float) -> bool:
        """Reserve ``projected_s`` of headroom; False (no state change)
        when the projection does not fit."""
        if not self.admits(projected_s):
            return False
        self.committed_s += projected_s
        return True

    def settle(self, projected_s: float, actual_s: float) -> None:
        """Release the ``projected_s`` reservation and record the realized
        ``actual_s`` spend (the projection is an upper bound, so settling
        normally credits headroom back).

        Hardened against ledger corruption: settling more than is
        committed (a double-``settle`` of the same tenant, or a credit
        that was never debited) would silently mint headroom —
        ``remaining_s`` grows past what the operator granted and later
        admissions overrun the budget.  Such a call raises instead of
        corrupting the ledger, as do negative amounts."""
        if projected_s < 0 or actual_s < 0:
            raise ValueError(
                f"settle amounts must be non-negative; got "
                f"projected_s={projected_s!r}, actual_s={actual_s!r}")
        if projected_s > self.committed_s + 1e-9:
            raise ValueError(
                f"settle({projected_s:.3f}s) exceeds the committed "
                f"reservation {self.committed_s:.3f}s — double-settle or "
                "never-debited credit would mint budget headroom")
        self.committed_s = max(0.0, self.committed_s - projected_s)
        self.spent_s += actual_s
