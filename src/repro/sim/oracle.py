"""Oracle + noisy detectors over the synthetic repository.

``oracle_detect`` returns the ground-truth detections of a frame in a fixed
number of slots D (statically shaped).  ``noisy_detect`` degrades it with
miss probability, localization jitter and false positives — modeling a real
object detector's behaviour so matcher robustness is measurable.

``neural_detect`` adapts any backbone ``serve_fn`` (frame embedding →
detection head output) into the same interface; used by the end-to-end
examples where the detector is one of the assigned architectures.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.sim.repository import Repository, instances_visible


class Detections(NamedTuple):
    boxes: jax.Array     # f32[D, 4]
    feats: jax.Array     # f32[D, F]
    valid: jax.Array     # bool[D]
    inst_id: jax.Array   # i32[D] — ground-truth id (oracle only; -1 invalid)


def _topk_slots(
    repo: Repository, frame: jax.Array, mask: jax.Array, max_dets: int
) -> Detections:
    """Pack visible instances into D slots, preferring earliest ids."""
    n = repo.num_instances
    # order: visible instances first (stable by id)
    order = jnp.argsort(jnp.where(mask, jnp.arange(n), n + jnp.arange(n)))
    take = order[:max_dets]
    valid = mask[take]
    t = (frame - repo.inst_start[take]).astype(jnp.float32)[:, None]
    boxes = repo.inst_box[take] + t * repo.inst_drift[take]
    return Detections(
        boxes=jnp.where(valid[:, None], boxes, 0.0),
        feats=jnp.where(valid[:, None], repo.inst_feat[take], 0.0),
        valid=valid,
        inst_id=jnp.where(valid, take.astype(jnp.int32), -1),
    )


def oracle_detect(
    repo: Repository, frame: jax.Array, *, query_class: int | None, max_dets: int = 16
) -> Detections:
    """Perfect detector for one query class — or, with ``query_class=None``,
    a CLASS-AGNOSTIC detector emitting every visible instance.  The latter
    is the multi-query sharing mode (DESIGN.md §9): one detector pass whose
    raw output each query filters down to its own predicate via the
    driver's ``select`` hook."""
    mask = instances_visible(repo, frame)
    if query_class is not None:
        mask = mask & (repo.inst_class == query_class)
    return _topk_slots(repo, frame, mask, max_dets)


def class_select(repo: Repository, query_classes):
    """Per-query predicate over CLASS-AGNOSTIC detections for the
    multi-query driver (DESIGN.md §9): ``select(q, dets) -> bool[D]`` keeps
    detections whose ground-truth instance belongs to ``query_classes[q]``.
    Detections without an instance id (noisy false positives, inst_id=-2)
    carry no class and are rejected by every query in multi mode
    (single-query noisy runs keep them)."""
    qclasses = jnp.asarray(query_classes, jnp.int32)
    inst_class = repo.inst_class

    def select(q, dets: Detections) -> jax.Array:
        cls = inst_class[jnp.maximum(dets.inst_id, 0)]
        return (dets.inst_id >= 0) & (cls == qclasses[q])

    return select


def filter_class(repo: Repository, dets: Detections, query_class) -> Detections:
    """``dets`` restricted to one class — the sequential-arm equivalent of
    ``class_select`` (same mask applied to ``valid``), so a per-class
    detector built from a detect-all pass matches the multi-query driver's
    ``select`` semantics exactly."""
    keep = class_select(repo, jnp.asarray([query_class], jnp.int32))(
        jnp.int32(0), dets
    )
    return dets._replace(valid=dets.valid & keep)


def noisy_detect(
    key: jax.Array,
    repo: Repository,
    frame: jax.Array,
    *,
    query_class: int | None,
    max_dets: int = 16,
    miss_rate: float = 0.1,
    fp_rate: float = 0.05,
    jitter: float = 0.01,
) -> Detections:
    """Detector with misses, box jitter and false positives
    (``query_class=None`` ⇒ class-agnostic, as in ``oracle_detect``).

    False positives get random boxes/features and inst_id = -2 so the
    benchmark can distinguish them from real results when scoring recall.
    """
    k_miss, k_jit, k_fp, k_fpbox, k_fpfeat = jax.random.split(key, 5)
    mask = instances_visible(repo, frame)
    if query_class is not None:
        mask = mask & (repo.inst_class == query_class)
    miss = jax.random.bernoulli(k_miss, miss_rate, mask.shape)
    dets = _topk_slots(repo, frame, mask & ~miss, max_dets)

    boxes = dets.boxes + jax.random.normal(k_jit, dets.boxes.shape) * jitter
    # false positives occupy trailing empty slots
    n_fp = jax.random.bernoulli(k_fp, fp_rate, (max_dets,))
    fp_slot = ~dets.valid & n_fp
    fp_xy = jax.random.uniform(k_fpbox, (max_dets, 2), minval=0.0, maxval=0.8)
    fp_wh = jax.random.uniform(k_fpbox, (max_dets, 2), minval=0.05, maxval=0.2)
    fp_boxes = jnp.concatenate([fp_xy, fp_xy + fp_wh], axis=1)
    fp_feats = jax.random.normal(k_fpfeat, dets.feats.shape)
    fp_feats = fp_feats / jnp.maximum(
        jnp.linalg.norm(fp_feats, axis=-1, keepdims=True), 1e-9
    )
    return Detections(
        boxes=jnp.where(fp_slot[:, None], fp_boxes, boxes),
        feats=jnp.where(fp_slot[:, None], fp_feats, dets.feats),
        valid=dets.valid | fp_slot,
        inst_id=jnp.where(fp_slot, -2, dets.inst_id),
    )


def frame_embedding(
    repo: Repository, frame: jax.Array, *, dim: int, patches: int = 0
) -> jax.Array:
    """Deterministic pseudo-embedding of a frame (stand-in for pixels →
    patch embeddings).  Mixes per-instance features of visible instances
    with a hash-based background so the surrogate model has real signal to
    learn — crucial for a faithful BlazeIt baseline.

    Returns f32[dim] (patches=0) or f32[patches, dim].
    """
    vis = instances_visible(repo, frame).astype(jnp.float32)
    sig = (vis @ repo.inst_feat)  # f32[F]
    f = frame.astype(jnp.float32)
    idx = jnp.arange(dim, dtype=jnp.float32)
    background = jnp.sin(f * 1e-3 + idx * 0.7) * 0.3
    base = background.at[: sig.shape[0]].add(sig)
    if patches == 0:
        return base
    p = jnp.arange(patches, dtype=jnp.float32)[:, None]
    return base[None, :] + 0.05 * jnp.sin(p * 0.13 + idx[None, :])
