"""Frame store: the random-access decode layer (paper §4.1).

The paper re-encodes videos with keyframes every 20 frames (Hwang/Scanner)
to make random reads cheap.  Our store models exactly that access pattern
over the synthetic repository: a ``fetch`` returns the frame *embedding*
(the stand-in for decoded pixels, see ``repro.sim.oracle.frame_embedding``)
plus an I/O cost in "decode units" = distance to the previous keyframe + 1.

The store is deliberately split from the pipeline so a real deployment can
swap in an actual video decoder behind the same interface; everything above
(`pipeline`, `exsample`, `serve`) is agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol

import jax
import jax.numpy as jnp

from repro.sim.oracle import frame_embedding
from repro.sim.repository import Repository


class FrameStore(Protocol):
    """Interface every frame source implements."""

    def fetch(self, frame_ids: jax.Array) -> jax.Array:
        """f32[B, ...] frame payloads for i32[B] global frame ids."""
        ...

    def decode_cost(self, frame_ids: jax.Array) -> jax.Array:
        """f32[B] decode-unit cost per fetch (for the cost model)."""
        ...


@dataclasses.dataclass(frozen=True)
class SimFrameStore:
    """Embedding-backed store over a synthetic repository."""

    repo: Repository
    embed_dim: int
    patches: int = 0
    keyframe_every: int = 20

    def fetch(self, frame_ids: jax.Array) -> jax.Array:
        fn = lambda f: frame_embedding(
            self.repo, f, dim=self.embed_dim, patches=self.patches
        )
        return jax.vmap(fn)(jnp.atleast_1d(frame_ids))

    def decode_cost(self, frame_ids: jax.Array) -> jax.Array:
        off = jnp.atleast_1d(frame_ids) % self.keyframe_every
        return (off + 1).astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class ShardedFrameStore:
    """Multi-host wrapper: each host owns a contiguous stripe of frames and
    fetches only local ids; remote ids resolve to zeros + an explicit mask
    so callers can all-gather payloads if (rarely) needed.  In the
    production layout the scheduler routes cohorts to the host owning the
    frames, so remote fetches never happen on the hot path."""

    inner: SimFrameStore
    host_id: int
    num_hosts: int

    def _local(self, frame_ids: jax.Array) -> jax.Array:
        total = self.inner.repo.total_frames
        stripe = -(-total // self.num_hosts)
        lo = self.host_id * stripe
        return (frame_ids >= lo) & (frame_ids < min(lo + stripe, total))

    def local_mask(self, frame_ids: jax.Array) -> jax.Array:
        """bool[B]: True where this host owns the frame.  The last host's
        stripe may be short (``total % num_hosts != 0``); ids past the end
        of the repository are local to no host."""
        return self._local(jnp.atleast_1d(frame_ids))

    def fetch(self, frame_ids: jax.Array):
        """``(payload, local_mask)`` — zeroed payload lanes are now
        DISTINGUISHABLE from genuinely-zero local embeddings: a remote id
        returns ``mask[i] == False``, and callers that previously relied
        on the silent zeroing can keep ``payload`` unchanged (it is
        already masked) while gaining the explicit bit."""
        payload = self.inner.fetch(frame_ids)
        mask = self._local(jnp.atleast_1d(frame_ids))
        masked = payload * mask[(...,) + (None,) * (payload.ndim - 1)]
        return masked, mask

    def decode_cost(self, frame_ids: jax.Array) -> jax.Array:
        return self.inner.decode_cost(frame_ids) * self._local(
            jnp.atleast_1d(frame_ids)
        )

    def owner_of(self, frame_ids: jax.Array) -> jax.Array:
        total = self.inner.repo.total_frames
        stripe = -(-total // self.num_hosts)
        return (jnp.atleast_1d(frame_ids) // stripe).astype(jnp.int32)
