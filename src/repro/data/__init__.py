"""Data substrate: frame stores + host pipelines."""
from repro.data.framestore import FrameStore, SimFrameStore, ShardedFrameStore
from repro.data.pipeline import (
    PrefetchPipeline,
    TrainBatchSpec,
    DeterministicTokenPipeline,
    ShuffledFramePipeline,
)

__all__ = [
    "FrameStore", "SimFrameStore", "ShardedFrameStore",
    "PrefetchPipeline", "TrainBatchSpec", "DeterministicTokenPipeline",
    "ShuffledFramePipeline",
]
