"""Host-side data pipeline: prefetch, batching, deterministic resume.

The sampling loop's I/O pattern is: Thompson cohort (device, ~µs) → fetch B
frames (host I/O) → detector batch (device, dominant).  The pipeline
overlaps the host fetch of round t+1 with the device compute of round t via
a single-slot double buffer (deeper queues add no throughput because the
detector is the bottleneck, cf. paper Fig. 6).

For training (surrogate / detector finetune) the pipeline yields fixed
(tokens, labels) batches drawn with the same bit-reversal order so a resume
from step k is bit-exact: the cursor IS the step counter — no iterator
state beyond one integer, which the checkpoint manager persists.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chunks import global_randomplus_order


@dataclasses.dataclass
class PrefetchPipeline:
    """Double-buffered fetch-ahead wrapper around a fetch callable."""

    fetch: Callable[[np.ndarray], jax.Array]
    depth: int = 2

    def __post_init__(self):
        self._q: "queue.Queue[tuple[np.ndarray, jax.Array]]" = queue.Queue(
            maxsize=self.depth
        )
        self._pending: "queue.Queue[Optional[np.ndarray]]" = queue.Queue()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            ids = self._pending.get()
            if ids is None:
                return
            self._q.put((ids, self.fetch(ids)))

    def submit(self, frame_ids: np.ndarray) -> None:
        self._pending.put(np.asarray(frame_ids))

    def next(self) -> tuple[np.ndarray, jax.Array]:
        return self._q.get()

    def close(self) -> None:
        self._pending.put(None)


@dataclasses.dataclass(frozen=True)
class TrainBatchSpec:
    global_batch: int
    seq_len: int
    vocab: int


class DeterministicTokenPipeline:
    """Synthetic-corpus token pipeline with O(1) resumable state.

    Batches are a pure function of (seed, step, data_shard): tokens are
    drawn from a hashed counter stream — statistically white, fully
    reproducible, and shardable across hosts without coordination.  This is
    the standard trick for framework bring-up and loss-curve regression
    tests; a production deployment swaps in a real tokenized corpus behind
    the same (step → batch) contract.
    """

    def __init__(
        self,
        spec: TrainBatchSpec,
        *,
        seed: int = 0,
        data_shard: int = 0,
        num_shards: int = 1,
    ):
        if spec.global_batch % num_shards:
            raise ValueError("global_batch must divide by num_shards")
        self.spec = spec
        self.seed = seed
        self.data_shard = data_shard
        self.num_shards = num_shards
        self._local_batch = spec.global_batch // num_shards

    def batch_at(self, step: int) -> dict[str, jax.Array]:
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step), self.data_shard
        )
        tokens = jax.random.randint(
            key,
            (self._local_batch, self.spec.seq_len + 1),
            0,
            self.spec.vocab,
            dtype=jnp.int32,
        )
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def __iter__(self) -> Iterator[dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class ShuffledFramePipeline:
    """Epoch-free frame scheduler for surrogate *labelling*: visits frames
    in global random+ order so a labelling budget of k frames is maximally
    stratified (matters for BlazeIt's training-set quality)."""

    def __init__(self, total_frames: int, batch: int, *, seed: int = 0):
        self.order = global_randomplus_order(total_frames, seed=seed)
        self.batch = batch
        self.cursor = 0

    def next_ids(self) -> np.ndarray:
        ids = np.take(
            self.order,
            np.arange(self.cursor, self.cursor + self.batch),
            mode="wrap",
        )
        self.cursor += self.batch
        return ids

    def state_dict(self) -> dict:
        return {"cursor": self.cursor}

    def load_state_dict(self, d: dict) -> None:
        self.cursor = int(d["cursor"])
