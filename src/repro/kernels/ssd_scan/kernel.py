"""Mamba-2 SSD chunk-scan kernel (arXiv:2405.21060, §6 of the paper).

Grid ``(batch·heads, num_chunks)`` — chunks iterate sequentially (last
grid axis) carrying the recurrent state h ∈ [P, N] in VMEM scratch.  Each
cell computes the quadratic intra-chunk term (decay-masked C·Bᵀ attention
matrix on the MXU) plus the linear inter-chunk term through h.

TPU adaptation notes (DESIGN.md §3): the CUDA SSD kernel uses warp-level
segmented scans; here the within-chunk cumulative decay is a dense
``cumsum`` on the VPU (fine for Q ≤ 256) and the cross-chunk scan is the
sequential grid axis — the idiomatic TPU substitute for grid-stride
persistent blocks.

VMEM per cell at Q=128, P=64, N=128: x (Q·P) + B,C (Q·N) + L (Q·Q f32) +
state (P·N f32) ≈ 0.3 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref,
    h_scratch,
    *, chunk: int,
):
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    x = x_ref[0].astype(jnp.float32)            # [Q, P]
    dt = dt_ref[0].astype(jnp.float32)          # [Q, 1]
    bmat = b_ref[0].astype(jnp.float32)         # [Q, N]
    cmat = c_ref[0].astype(jnp.float32)         # [Q, N]
    a_h = a_ref[0, 0]                           # scalar: -exp(A_log) per head

    a = dt[:, 0] * a_h                          # [Q] log-decay ≤ 0
    acs = jnp.cumsum(a)                         # inclusive
    # intra-chunk decay-masked scores
    rel = acs[:, None] - acs[None, :]           # [Q, Q]
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    l_mat = jnp.where(tri, jnp.exp(rel), 0.0)
    scores = jax.lax.dot_general(
        cmat, bmat, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * l_mat                                   # [Q, Q]
    xdt = x * dt                                # [Q, P]
    y = jax.lax.dot_general(
        scores, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                           # [Q, P]
    # inter-chunk: y += (C · h) * decay(0→t);  h [P, N]
    decay_out = jnp.exp(acs)[:, None]           # [Q, 1]
    y = y + jax.lax.dot_general(
        cmat, h_scratch[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * decay_out
    # state update: h ← exp(total) · h + Σ_s exp(acs_Q − acs_s) xdt_s ⊗ B_s
    decay_to_end = jnp.exp(acs[-1] - acs)[:, None]  # [Q, 1]
    h_new = jnp.exp(acs[-1]) * h_scratch[...] + jax.lax.dot_general(
        xdt * decay_to_end, bmat, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                           # [P, N]
    h_scratch[...] = h_new
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_kernel(
    x: jax.Array,       # [BH, S, P]   (already dt-independent input)
    dt: jax.Array,      # [BH, S]      (post-softplus)
    bmat: jax.Array,    # [BH, S, N]
    cmat: jax.Array,    # [BH, S, N]
    a: jax.Array,       # [BH]         (-exp(A_log) per (batch, head))
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    bh, s, p = x.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    dt2 = dt[..., None]
    a2 = a.reshape(bh, 1)

    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=q),
        grid=(bh, s // q),
        in_specs=[
            pl.BlockSpec((1, q, p), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, q, 1), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, q, n), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, q, n), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, 1), lambda i, c: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, p), lambda i, c: (i, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt2, bmat, cmat, a2)
