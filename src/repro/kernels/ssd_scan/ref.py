"""Pure-jnp oracle: delegates to the model's chunked SSD implementation."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.mamba2 import ssd_scan


def ssd_ref(x, dt, bmat, cmat, a, *, chunk: int = 128):
    """Same layout as the kernel: x [BH,S,P], dt [BH,S], B/C [BH,S,N],
    a [BH] (= -exp(A_log)).  Returns [BH,S,P] f32.

    The model-level ``ssd_scan`` keeps per-(B,H) separation via its H axis;
    here every (batch, head) pair is independent, so we reshape to B=BH,
    H=1 and give each row its own a via a_log = log(-a) per row — but
    ssd_scan takes a_log [H]; instead evaluate row-wise with vmap.
    """
    import jax

    def one(xr, dtr, br, cr, ar):
        y, _ = ssd_scan(
            xr[None, :, None, :],              # [1, S, 1, P]
            dtr[None, :, None],                # [1, S, 1]
            br[None],                          # [1, S, N]
            cr[None],                          # [1, S, N]
            jnp.log(-ar)[None],                # a_log [1]
            chunk=chunk,
        )
        return y[0, :, 0, :]

    return jax.vmap(one)(x, dt, bmat, cmat, a)
