"""Dispatching wrapper for the SSD chunk scan."""
from __future__ import annotations

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan_kernel
from repro.kernels.ssd_scan.ref import ssd_ref


def ssd(x, dt, bmat, cmat, a, *, chunk: int = 128, interpret: bool | None = None):
    on_tpu = jax.default_backend() == "tpu"
    if interpret is None:
        if not on_tpu:
            return ssd_ref(x, dt, bmat, cmat, a, chunk=chunk)
        interpret = False
    return ssd_scan_kernel(x, dt, bmat, cmat, a, chunk=chunk, interpret=interpret)
