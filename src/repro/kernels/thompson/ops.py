"""Dispatching wrapper for the Thompson choice kernel."""
from __future__ import annotations

import jax

from repro.kernels.thompson.kernel import thompson_choose, thompson_choose_batched
from repro.kernels.thompson.ref import thompson_ref


def choose(alpha, beta, z, *, block_m: int = 1024, interpret: bool | None = None):
    on_tpu = jax.default_backend() == "tpu"
    if interpret is None:
        if not on_tpu:
            return thompson_ref(alpha, beta, z)
        interpret = False
    return thompson_choose(alpha, beta, z, block_m=block_m, interpret=interpret)


def choose_batched(
    alpha, beta, z, *, block_m: int = 1024, interpret: bool | None = None
):
    """Multi-query choice: alpha/beta f32[Q, M], z f32[Q, C, M] →
    (idx i32[Q, C], val f32[Q, C]).  One batched kernel launch on TPU; the
    vmapped jnp reference elsewhere (bit-identical per query)."""
    on_tpu = jax.default_backend() == "tpu"
    if interpret is None:
        if not on_tpu:
            return jax.vmap(thompson_ref)(alpha, beta, z)
        interpret = False
    return thompson_choose_batched(
        alpha, beta, z, block_m=block_m, interpret=interpret
    )
