"""Dispatching wrapper for the Thompson choice kernel."""
from __future__ import annotations

import jax

from repro.kernels.thompson.kernel import thompson_choose
from repro.kernels.thompson.ref import thompson_ref


def choose(alpha, beta, z, *, block_m: int = 1024, interpret: bool | None = None):
    on_tpu = jax.default_backend() == "tpu"
    if interpret is None:
        if not on_tpu:
            return thompson_ref(alpha, beta, z)
        interpret = False
    return thompson_choose(alpha, beta, z, block_m=block_m, interpret=interpret)
