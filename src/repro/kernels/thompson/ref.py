"""Pure-jnp oracle for the fused Thompson choice."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.thompson import wilson_hilferty


def thompson_ref(alpha, beta, z):
    """alpha/beta f32[M] (alpha<0 ⇒ exhausted), z f32[C,M] →
    (idx i32[C], val f32[C]).

    Same clamping contract as the kernel (DESIGN.md §3): live chunks
    arrive pre-clamped by ``gamma_params`` so the 1e-6 floor never binds.
    """
    live = alpha > 0.0
    a = jnp.maximum(alpha, 1e-6)
    draw = wilson_hilferty(a[None, :], z) / jnp.maximum(beta, 1e-9)[None, :]
    score = jnp.where(live[None, :], draw, -1e30)
    idx = jnp.argmax(score, axis=-1).astype(jnp.int32)
    val = jnp.take_along_axis(score, idx[:, None], axis=-1)[:, 0]
    return idx, val
