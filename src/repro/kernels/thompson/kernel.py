"""Fused Gamma-Thompson chunk choice kernel (the paper's per-step decision).

For M chunks and C cohorts: transform standard normals through the
Wilson–Hilferty cube approximation of Γ(α, β) draws and reduce to the
per-cohort argmax — fused so chunk statistics stream through VMEM once
per cohort row, with no M-sized intermediate ever hitting HBM.

Grid ``(C, num_chunk_blocks)``, running (value, index) maximum in VMEM
scratch.  Rejection samplers (Marsaglia–Tsang) are data-dependent loops —
hostile to the VPU; WH is branch-free (DESIGN.md §3) and the consumer
only needs ordinal fidelity.  Exhausted chunks arrive with α < 0 as the
sentinel and are masked to -inf.

Clamping contract (DESIGN.md §3): callers pass ``alpha`` already clamped
by ``core.thompson.gamma_params`` (≥ α₀/2 > 0 for live chunks) with the
negative sentinel only marking exhaustion; the kernel's internal
``max(α, 1e-6)`` is pure numeric safety for the rsqrt and never binds on
live chunks, so kernel scores equal
``core.thompson.draw_scores_wilson_hilferty`` exactly (locked in by
``tests/test_thompson_parity.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _thompson_kernel(
    alpha_ref, beta_ref, z_ref, idx_ref, val_ref,
    best_scratch,
    *, block_m: int,
):
    mj = pl.program_id(1)
    nm = pl.num_programs(1)

    @pl.when(mj == 0)
    def _init():
        best_scratch[0, 0] = NEG_INF          # value
        best_scratch[0, 1] = -1.0             # index (as f32)

    alpha = alpha_ref[...].astype(jnp.float32)       # [1, bm]
    beta = beta_ref[...].astype(jnp.float32)
    z = z_ref[...].astype(jnp.float32)
    live = alpha > 0.0
    a = jnp.maximum(alpha, 1e-6)
    # Wilson-Hilferty: X ≈ α (1 − 1/9α + z/(3√α))³
    c = 1.0 - 1.0 / (9.0 * a) + z / (3.0 * jnp.sqrt(a))
    draw = a * jnp.maximum(c, 0.0) ** 3 / jnp.maximum(beta, 1e-9)
    score = jnp.where(live, draw, NEG_INF)

    loc = jnp.argmax(score[0]).astype(jnp.int32)
    val = score[0, loc]
    gidx = mj * block_m + loc

    @pl.when(val > best_scratch[0, 0])
    def _update():
        best_scratch[0, 0] = val
        best_scratch[0, 1] = gidx.astype(jnp.float32)

    @pl.when(mj == nm - 1)
    def _finalize():
        idx_ref[0, 0] = best_scratch[0, 1].astype(jnp.int32)
        val_ref[0, 0] = best_scratch[0, 0]


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def thompson_choose(
    alpha: jax.Array,     # f32[M] — N¹+α₀ per chunk; <0 ⇒ exhausted sentinel
    beta: jax.Array,      # f32[M] — n+β₀
    z: jax.Array,         # f32[C, M] — standard normals (one row per cohort)
    *,
    block_m: int = 1024,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (idx i32[C], value f32[C])."""
    c, m = z.shape
    bm = min(block_m, m)
    pad = (-m) % bm
    if pad:
        alpha = jnp.concatenate([alpha, jnp.full((pad,), -1.0, alpha.dtype)])
        beta = jnp.concatenate([beta, jnp.ones((pad,), beta.dtype)])
        z = jnp.concatenate([z, jnp.zeros((c, pad), z.dtype)], axis=1)
        m += pad

    idx, val = pl.pallas_call(
        functools.partial(_thompson_kernel, block_m=bm),
        grid=(c, m // bm),
        in_specs=[
            pl.BlockSpec((1, bm), lambda ci, mj: (0, mj)),
            pl.BlockSpec((1, bm), lambda ci, mj: (0, mj)),
            pl.BlockSpec((1, bm), lambda ci, mj: (ci, mj)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda ci, mj: (ci, 0)),
            pl.BlockSpec((1, 1), lambda ci, mj: (ci, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, 1), jnp.int32),
            jax.ShapeDtypeStruct((c, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, 2), jnp.float32)],
        interpret=interpret,
    )(alpha.reshape(1, m), beta.reshape(1, m), z)
    return idx[:, 0], val[:, 0]


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def thompson_choose_batched(
    alpha: jax.Array,     # f32[Q, M] — one statistics row per query
    beta: jax.Array,      # f32[Q, M]
    z: jax.Array,         # f32[Q, C, M] — per-query cohort normals
    *,
    block_m: int = 1024,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Multi-query variant (DESIGN.md §9): Q queries × C cohorts reduced in
    ONE pallas_call.  The cohort rows flatten to a (Q·C, M-blocks) grid and
    each row's block spec indexes its query's alpha/beta row (``r // C``),
    so the whole multi-query Thompson decision is a single kernel launch —
    never a Python loop over queries.  Returns (idx i32[Q, C], val
    f32[Q, C]); row (q, c) is bit-identical to ``thompson_choose`` on
    query q's statistics.
    """
    qn, c, m = z.shape
    bm = min(block_m, m)
    pad = (-m) % bm
    if pad:
        alpha = jnp.concatenate(
            [alpha, jnp.full((qn, pad), -1.0, alpha.dtype)], axis=1
        )
        beta = jnp.concatenate([beta, jnp.ones((qn, pad), beta.dtype)], axis=1)
        z = jnp.concatenate([z, jnp.zeros((qn, c, pad), z.dtype)], axis=2)
        m += pad

    idx, val = pl.pallas_call(
        functools.partial(_thompson_kernel, block_m=bm),
        grid=(qn * c, m // bm),
        in_specs=[
            pl.BlockSpec((1, bm), lambda r, mj: (r // c, mj)),
            pl.BlockSpec((1, bm), lambda r, mj: (r // c, mj)),
            pl.BlockSpec((1, bm), lambda r, mj: (r, mj)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda r, mj: (r, 0)),
            pl.BlockSpec((1, 1), lambda r, mj: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn * c, 1), jnp.int32),
            jax.ShapeDtypeStruct((qn * c, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, 2), jnp.float32)],
        interpret=interpret,
    )(alpha, beta, z.reshape(qn * c, m))
    return idx[:, 0].reshape(qn, c), val[:, 0].reshape(qn, c)
