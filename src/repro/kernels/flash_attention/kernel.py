"""Causal GQA flash-attention forward kernel (prefill / train fwd).

Grid ``(batch·q_heads, num_q_blocks, num_kv_blocks)`` — the last axis is
innermost-sequential on TPU, so the online-softmax accumulators live in
VMEM scratch across kv iterations of one (bh, qi) cell.  KV blocks above
the causal diagonal are skipped with ``pl.when`` (no MXU work issued —
the TPU analogue of triangular block enumeration).

GQA is handled in the index map: the kv operand block for flattened
batch·head index ``bh`` is ``(bh // H)·KV + (bh % H) // (H // KV)`` — no
materialized repeat, so HBM traffic over K/V is O(S·KV·d), not O(S·H·d).

VMEM working set per cell: q (bq·d) + k,v (bk·d each) + scores (bq·bk f32)
+ acc (bq·d f32) ≈ 2.4 MB at bq=bk=256, d=128 — comfortably inside the
16 MB v5e VMEM with double buffering.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fwd_kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_scratch, l_scratch, acc_scratch,
    *, scale: float, causal: bool, block_q: int, block_kv: int,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    run = True
    if causal:
        # kv block needed iff its first row index ≤ q block's last row index
        run = kj * block_kv <= qi * block_q + block_q - 1

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)            # [bq, d]
        k = k_ref[0].astype(jnp.float32)            # [bk, d]
        v = v_ref[0].astype(jnp.float32)            # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                   # [bq, bk]
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0
            )
            cols = kj * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1
            )
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scratch[...]
        l_prev = l_scratch[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)  # [bq, 1]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scratch[...] = acc_scratch[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scratch[...] = m_new
        l_scratch[...] = l_new

    @pl.when(kj == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scratch[...], 1e-30)
        o_ref[0] = (acc_scratch[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_kv", "interpret"),
)
def flash_attention(
    q: jax.Array,            # [B, S, H, d]
    k: jax.Array,            # [B, T, KV, d]
    v: jax.Array,            # [B, T, KV, d]
    *,
    causal: bool = True,
    block_q: int = 256,
    block_kv: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, s, h, d = q.shape
    t, kv = k.shape[1], k.shape[2]
    assert h % kv == 0, (h, kv)
    group = h // kv
    bq = min(block_q, s)
    bk = min(block_kv, t)
    assert s % bq == 0 and t % bk == 0, (s, bq, t, bk)
    scale = 1.0 / math.sqrt(d)

    # flatten (B, H) → grid rows; move head axis out for blocked indexing
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kv, t, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kv, t, d)

    def kv_index(bh, qi, kj):
        return ((bh // h) * kv + (bh % h) // group, kj, 0)

    out = pl.pallas_call(
        functools.partial(
            _fwd_kernel, scale=scale, causal=causal,
            block_q=bq, block_kv=bk,
        ),
        grid=(b * h, s // bq, t // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)
