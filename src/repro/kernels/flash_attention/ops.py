"""Dispatching wrapper: Pallas kernel on TPU, blocked-jnp fallback elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def attention(q, k, v, *, causal: bool = True, block_q: int = 256,
              block_kv: int = 256, interpret: bool | None = None):
    """Flash attention with automatic backend dispatch.

    interpret=None ⇒ kernel on TPU, reference elsewhere;
    interpret=True ⇒ kernel body interpreted (CPU validation path).
    """
    on_tpu = jax.default_backend() == "tpu"
    if interpret is None:
        if not on_tpu:
            return attention_ref(q, k, v, causal=causal)
        interpret = False
    return flash_attention(
        q, k, v, causal=causal, block_q=block_q, block_kv=block_kv,
        interpret=interpret,
    )
