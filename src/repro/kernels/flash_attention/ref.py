"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    """q [B,S,H,d]; k,v [B,T,KV,d] → [B,S,H,d] (f32 math, q.dtype out)."""
    b, s, h, d = q.shape
    t, kv = k.shape[1], k.shape[2]
    group = h // kv
    k = jnp.repeat(k, group, axis=2)
    v = jnp.repeat(v, group, axis=2)
    scores = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((s, t), bool), k=t - s)
        scores = jnp.where(mask[None, None], scores, -1e30)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
