"""Dispatching wrapper for flash decode."""
from __future__ import annotations

import jax

from repro.kernels.flash_decode.kernel import flash_decode
from repro.kernels.flash_decode.ref import decode_ref


def decode(q, k_cache, v_cache, cache_len, *, block_kv: int = 512,
           interpret: bool | None = None):
    on_tpu = jax.default_backend() == "tpu"
    if interpret is None:
        if not on_tpu:
            return decode_ref(q, k_cache, v_cache, cache_len)
        interpret = False
    return flash_decode(
        q, k_cache, v_cache, cache_len, block_kv=block_kv, interpret=interpret
    )
