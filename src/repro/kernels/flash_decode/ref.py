"""Pure-jnp oracle for flash decode (mirrors models.attention.decode_attention)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import decode_attention


def decode_ref(q, k_cache, v_cache, cache_len):
    """q [B,H,d] → [B,H,d]."""
    out = decode_attention(q[:, None], k_cache, v_cache, cache_len=cache_len)
    return out[:, 0]
