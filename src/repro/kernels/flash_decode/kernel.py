"""Split-K flash-decode kernel: one query token vs a long KV cache.

Grid ``(batch·kv_heads, num_kv_blocks)`` — each cell processes the G
grouped query heads of one kv head against one KV block, carrying the
online-softmax state (m, l, acc per q-group) in VMEM scratch across the
sequential KV axis.  This is the kernel twin of the sequence-sharded
decode path (DESIGN.md §5): on a real pod the KV axis is sharded over
``model`` and each shard runs this kernel over its local blocks, with the
cross-shard combine done by the psum in ``decode_attention``.

VMEM per cell: q (G·d) + k,v (bk·d) + s (G·bk f32) + acc (G·d f32) —
< 1 MB at G ≤ 16, bk = 512, d = 128.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    q_ref, k_ref, v_ref, len_ref, o_ref,
    m_scratch, l_scratch, acc_scratch,
    *, scale: float, block_kv: int,
):
    kj = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(kj == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q = q_ref[0].astype(jnp.float32)                # [G, d]
    k = k_ref[0].astype(jnp.float32)                # [bk, d]
    v = v_ref[0].astype(jnp.float32)                # [bk, d]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                       # [G, bk]
    # mask positions beyond the live cache length
    pos = kj * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < len_ref[0], s, NEG_INF)

    m_prev = m_scratch[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_scratch[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scratch[...] = acc_scratch[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scratch[...] = m_new
    l_scratch[...] = l_new

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[0] = (
            acc_scratch[...] / jnp.maximum(l_scratch[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_kv", "interpret"))
def flash_decode(
    q: jax.Array,            # [B, H, d]  — one token per sequence
    k_cache: jax.Array,      # [B, T, KV, d]
    v_cache: jax.Array,      # [B, T, KV, d]
    cache_len: jax.Array,    # i32[B]
    *,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, h, d = q.shape
    t, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    bk = min(block_kv, t)
    assert t % bk == 0, (t, bk)
    scale = 1.0 / math.sqrt(d)

    qf = q.reshape(b * kv, g, d)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(b * kv, t, d)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(b * kv, t, d)
    lens = jnp.repeat(cache_len, kv).reshape(b * kv, 1)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_kv=bk),
        grid=(b * kv, t // bk),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda bh, kj: (bh, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, kj: (bh, kj, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, kj: (bh, kj, 0)),
            pl.BlockSpec((1, 1), lambda bh, kj: (bh, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda bh, kj: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf, lens)
    return out.reshape(b, h, d)
