"""Pallas TPU kernels for the compute hot spots (DESIGN.md §2).

Each kernel package: ``kernel.py`` (pl.pallas_call + BlockSpec VMEM
tiling), ``ops.py`` (jit'd wrapper with pure-JAX fallback), ``ref.py``
(jnp oracle).  Validated in interpret=True mode on CPU; targeted at the
TPU v5e MXU/VPU.
"""
