"""Tiled pairwise-IoU kernel for the detection matcher.

Grid ``(num_det_blocks, num_mem_blocks)``; each cell computes a (bd × br)
IoU tile from two box blocks in VMEM — pure VPU element-wise work over
broadcasted corners, no MXU.  Crowded-scene matching is O(D·R) with
R = result-memory capacity (10³–10⁴): on host this was the matcher's hot
loop; fused on-device it disappears into the detector batch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _iou_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)          # [bd, 4]
    b = b_ref[...].astype(jnp.float32)          # [br, 4]
    area_a = jnp.maximum(a[:, 2] - a[:, 0], 0.0) * jnp.maximum(
        a[:, 3] - a[:, 1], 0.0
    )
    area_b = jnp.maximum(b[:, 2] - b[:, 0], 0.0) * jnp.maximum(
        b[:, 3] - b[:, 1], 0.0
    )
    lt_x = jnp.maximum(a[:, None, 0], b[None, :, 0])
    lt_y = jnp.maximum(a[:, None, 1], b[None, :, 1])
    rb_x = jnp.minimum(a[:, None, 2], b[None, :, 2])
    rb_y = jnp.minimum(a[:, None, 3], b[None, :, 3])
    inter = jnp.maximum(rb_x - lt_x, 0.0) * jnp.maximum(rb_y - lt_y, 0.0)
    union = area_a[:, None] + area_b[None, :] - inter
    o_ref[...] = (inter / jnp.maximum(union, 1e-9)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "block_r", "interpret"))
def iou_matrix(
    boxes_a: jax.Array,     # f32[D, 4]
    boxes_b: jax.Array,     # f32[R, 4]
    *,
    block_d: int = 128,
    block_r: int = 512,
    interpret: bool = False,
) -> jax.Array:
    d, r = boxes_a.shape[0], boxes_b.shape[0]
    bd = min(block_d, d)
    br = min(block_r, r)

    def pad_to(x, mult):
        p = (-x.shape[0]) % mult
        return jnp.pad(x, ((0, p), (0, 0))) if p else x, x.shape[0] + (
            (-x.shape[0]) % mult
        )

    a_p, dp = pad_to(boxes_a, bd)
    b_p, rp = pad_to(boxes_b, br)
    out = pl.pallas_call(
        _iou_kernel,
        grid=(dp // bd, rp // br),
        in_specs=[
            pl.BlockSpec((bd, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((br, 4), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bd, br), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dp, rp), jnp.float32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:d, :r]
