"""Pure-jnp oracle: the matcher's own pairwise_iou."""
from repro.core.matcher import pairwise_iou as iou_ref  # noqa: F401