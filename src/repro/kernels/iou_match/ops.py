"""Dispatching wrapper for the IoU kernel."""
from __future__ import annotations

import jax

from repro.kernels.iou_match.kernel import iou_matrix
from repro.kernels.iou_match.ref import iou_ref


def iou(boxes_a, boxes_b, *, interpret: bool | None = None):
    on_tpu = jax.default_backend() == "tpu"
    if interpret is None:
        if not on_tpu:
            return iou_ref(boxes_a, boxes_b)
        interpret = False
    return iou_matrix(boxes_a, boxes_b, interpret=interpret)
