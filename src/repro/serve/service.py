"""Multi-tenant search service: admission + SLO scheduling (DESIGN.md §12).

The batch planner (DESIGN.md §10) answers "run these Q queries"; a video
repository in production answers a different question: queries ARRIVE, at
any time, from different tenants, and the operator grants a finite
GPU-time budget.  :class:`SearchService` is the persistent layer between
the two — it accepts declarative :class:`~repro.core.plan.SearchPlan`\\ s
(JSON over the thin ``repro.launch.serve_search`` front) and admits them
onto free Q-axis slots of ONE long-running
:class:`~repro.core.runtime.AsyncMultiSearchDriver`:

* **Admission control** prices each plan BEFORE it runs
  (:func:`~repro.sim.costmodel.plan_projected_cost` under the operator's
  :class:`~repro.sim.costmodel.CostRates`) and debits a
  :class:`~repro.sim.costmodel.CostBudget`.  A plan whose projection
  exceeds the remaining headroom is rejected — or, with
  ``ServiceConfig.queue_on_reject``, parked in a priority queue until a
  retirement frees capacity.  Projections are upper bounds, so the ledger
  is race-free: unspent cost is credited back when the tenant retires.
* **Slot reuse**: a finished tenant's row is harvested
  (:func:`~repro.core.executor.tenant_stats_from_row`) and its slot
  ``vacate``\\ d; the next admission reuses it, so the pool's device
  footprint tracks CONCURRENCY, not tenant count.
* **SLO tracking**: each tenant's time-to-first-result is measured from
  admission against its ``ServiceConfig.slo_latency_s``.  The service
  reports attainment; it never kills a query for missing an SLO.
* **Fair detector-batch sharing**: tenants share the driver's deduplicated
  detector pass and :class:`~repro.serve.batcher.DetectionCache`; batch
  occupancy is accounted with the same ``occupancy = 1 − padding``
  convention as :class:`~repro.serve.batcher.RequestBatcher`, and detector
  economics are attributed per tenant by dedup representative.

Parity contract (tests/test_service.py): the driver's at-most-one-slot
invariant is untouched, so each admitted tenant's result stream is
bit-identical to its own solo ``run_search_scan`` run at its debited
frame budget — multi-tenancy changes WHICH detector invocations happen
(sharing), never the values any tenant consumes.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Optional

import jax

import numpy as np

from repro.core.executor import SearchStats, tenant_stats_from_row
from repro.core.plan import PlanError, SearchPlan, ServiceConfig
from repro.core.runtime import AsyncMultiSearchDriver
from repro.sim.costmodel import (
    CostBudget,
    CostRates,
    plan_projected_cost,
    sampling_cost,
)

QUEUED = "queued"
RUNNING = "running"
FINISHED = "finished"
REJECTED = "rejected"


@dataclasses.dataclass
class Tenant:
    """One submitted plan's lifecycle record (QUEUED → RUNNING → FINISHED,
    or REJECTED at admission)."""

    tenant_id: str
    plan: SearchPlan
    key: jax.Array
    select_id: Optional[int]
    service: ServiceConfig
    projected_s: float
    seq: int                         # FIFO tiebreak within a priority level
    state: str = QUEUED
    reason: str = ""                 # rejection reason (REJECTED only)
    row: Optional[int] = None        # driver slot index while RUNNING
    row_obj: object = None           # this tenant's _QueryRow, bound at
    #   admission.  The binding is by OBJECT, not slot index: ``admit``
    #   installs a fresh row per tenant and ``vacate`` returns that same
    #   object, so the reference stays valid (and reports live SLO/result
    #   state) even after the slot index is reused by a later tenant.
    actual_s: float = 0.0            # settled realized cost
    submitted_s: float = 0.0
    n1_init: object = None           # sampler n1 at admission (f64[M]) —
    #   includes any injected index prior, so _reap records only the
    #   DELTA this tenant actually observed (priors never re-recorded)

    # ---- reporting ---------------------------------------------------------

    @property
    def stats(self) -> Optional[SearchStats]:
        if self.row_obj is None:
            return None
        return tenant_stats_from_row(self.row_obj)

    def slo_report(self) -> dict:
        """Time-to-first-result against this tenant's SLO.  ``ttfr_s`` is
        None until a first result merges; ``slo_met`` is None when no SLO
        was declared (slo_latency_s == 0).  The row is bound at admission,
        so attainment is visible while the tenant is still RUNNING — the
        driver stamps ``first_result_s`` at the merge, not at reap."""
        row = self.row_obj
        ttfr = None
        if row is not None and row.first_result_s:
            ttfr = row.first_result_s - row.admitted_s
        slo = self.service.slo_latency_s
        if slo <= 0:
            met = None                     # no SLO declared
        elif ttfr is not None:
            met = ttfr <= slo
        elif self.state in (QUEUED, RUNNING) and (
            row is None or time.monotonic() - row.admitted_s <= slo
        ):
            met = None                     # undetermined: window still open
        else:
            met = False                    # no first result inside the window
        return {
            "slo_latency_s": slo,
            "ttfr_s": ttfr,
            "slo_met": met,
        }

    def to_dict(self) -> dict:
        d = {
            "tenant": self.tenant_id,
            "state": self.state,
            "projected_s": self.projected_s,
            "priority": self.service.priority,
        }
        if self.state == REJECTED:
            d["reason"] = self.reason
        if self.row_obj is not None:
            row = self.row_obj
            st = self.stats
            d.update(
                results=int(row.carry.results),
                steps=int(row.carry.step),
                spilled=len(row.log),
                detector_invocations=st.detector_invocations,
                cache_hits=st.cache_hits,
                index_hits=st.index_hits,
                warm_rounds_saved=st.warm_rounds_saved,
                actual_s=self.actual_s,
                **self.slo_report(),
            )
        if self.state == FINISHED:
            # per-tenant economics: what admission reserved vs what the
            # tenant really cost once settled (credit = headroom returned)
            d["projected_vs_settled"] = {
                "projected_s": self.projected_s,
                "settled_s": self.actual_s,
                "credited_s": self.projected_s - self.actual_s,
            }
        return d


class SearchService:
    """Persistent multi-tenant front over one elastic slot driver.

    The service owns the driver (constructed around a vacated prototype
    row, so the pool starts empty), the cost ledger and the admission
    queue.  ``submit`` is thread-safe; the pump — either the background
    thread ``start(pump=True)`` spawns or explicit ``tick()`` calls —
    merges rounds, harvests finished tenants and admits queued ones as
    capacity frees.
    """

    def __init__(
        self,
        carry_proto,
        chunks,
        detector,
        *,
        select=None,
        budget_s: float = float("inf"),
        rates: CostRates = CostRates(),
        cohorts: int = 4,
        num_workers: int = 2,
        max_steps: int = 100_000,
        cache_frames: int = 0,
        slots_per_batch: int = 4,
        index=None,
    ):
        """``carry_proto`` is a leading-[1] multi-query carry
        (``init_carry_multi``) fixing the pool's sampler/matcher geometry;
        its single row is vacated immediately and never runs.  ``index``
        is a shared :class:`~repro.index.store.RepositoryIndex`: ONE
        instance serves every tenant — the driver's device cache warms
        from it at construction, retiring tenants publish their
        detections and per-chunk evidence back, and warm-start priors
        inject at admission (keyed by the tenant's ``select_id``)."""
        self.rates = rates
        self.budget = CostBudget(total_s=budget_s)
        self.index = index
        self.total_frames = int(chunks.total_frames)
        self.driver = AsyncMultiSearchDriver(
            carry_proto, chunks, detector,
            cohorts=cohorts, num_workers=num_workers,
            result_limits=1, max_steps=max_steps, select=select,
            cache_frames=cache_frames, slots_per_batch=slots_per_batch,
            index=index,
        )
        self.driver.vacate(0)
        self.tenants: dict[str, Tenant] = {}
        self._queue: list[Tenant] = []
        self._seq = itertools.count()
        self._lock = threading.Lock()
        self._stop_evt = threading.Event()
        self._pump: Optional[threading.Thread] = None

    # ---- lifecycle ---------------------------------------------------------

    def start(self, pump: bool = True) -> None:
        self.driver.start()
        if pump and self._pump is None:
            self._stop_evt.clear()
            self._pump = threading.Thread(target=self._pump_loop, daemon=True)
            self._pump.start()

    def stop(self) -> None:
        if self._pump is not None:
            self._stop_evt.set()
            self._pump.join(timeout=10.0)
            self._pump = None
        self.driver.stop()

    def _pump_loop(self) -> None:
        while not self._stop_evt.is_set():
            self.tick(timeout=0.05)

    # ---- admission ---------------------------------------------------------

    def submit(
        self,
        tenant_id: str,
        plan: SearchPlan,
        *,
        key: Optional[jax.Array] = None,
        seed: int = 0,
        select_id: Optional[int] = None,
    ) -> Tenant:
        """Price ``plan``, then admit / queue / reject it.  One tenant =
        one Q-axis row, so service plans are single-query; ``select_id``
        binds the tenant's predicate (e.g. its query class) through the
        driver's ``select`` hook without recompiling anything."""
        plan.resolve()   # typed PlanErrors surface before any state change
        if plan.queries != 1:
            raise PlanError(
                f"service plans are single-query (one tenant = one Q-axis "
                f"slot); got queries={plan.queries} — submit one plan per "
                "query", field="queries")
        spec = plan.execution.index
        if spec is not None:
            if self.index is None and spec.prior_weight > 0:
                raise PlanError(
                    "plan requests index warm-start (prior_weight > 0) but "
                    "the service was constructed without a shared "
                    "RepositoryIndex", field="index")
            if (
                self.index is not None
                and spec.detector_version != self.index.detector_version
            ):
                raise PlanError(
                    f"plan declares index.detector_version="
                    f"{spec.detector_version!r} but the service index holds "
                    f"{self.index.detector_version!r} — a version mismatch "
                    "must be a clean miss, not a silent replay",
                    field="detector_version")
        svc = plan.execution.service or ServiceConfig()
        projected = plan_projected_cost(
            plan, self.rates, index=self.index,
            total_frames=self.total_frames,
        ).total_s
        tenant = Tenant(
            tenant_id=tenant_id,
            plan=plan,
            key=key if key is not None else jax.random.PRNGKey(seed),
            select_id=select_id,
            service=svc,
            projected_s=projected,
            seq=next(self._seq),
            submitted_s=time.monotonic(),
        )
        with self._lock:
            existing = self.tenants.get(tenant_id)
            if existing is not None and existing.state not in (
                REJECTED, FINISHED,
            ):
                raise PlanError(
                    f"tenant {tenant_id!r} already submitted", field="tenant")
            # a terminal record is replaced: a rejected tenant may resubmit
            # a smaller plan under the same id
            self.tenants[tenant_id] = tenant
            if projected > self._never_fit_bound():
                tenant.state = REJECTED
                tenant.reason = self._never_fit_reason(projected)
            elif self.budget.debit(projected):
                self._admit(tenant)
            elif svc.queue_on_reject:
                tenant.state = QUEUED
                self._queue.append(tenant)
            else:
                tenant.state = REJECTED
                tenant.reason = (
                    f"projected cost {projected:.1f}s exceeds remaining "
                    f"budget {self.budget.remaining_s:.1f}s "
                    "(set service.queue_on_reject to wait for capacity)")
        return tenant

    def _never_fit_bound(self) -> float:
        """The most headroom this budget can EVER offer again: ``total −
        spent``.  ``spent_s`` is never credited back, so the bound is
        monotonically non-increasing — a projection above it can never be
        admitted and queueing it would deadlock the drain.  Caller holds
        the lock."""
        return self.budget.total_s - self.budget.spent_s

    def _never_fit_reason(self, projected: float) -> str:
        return (
            f"projected cost {projected:.1f}s can never fit: it exceeds "
            f"the total budget {self.budget.total_s:.1f}s minus settled "
            f"spend {self.budget.spent_s:.1f}s")

    def _admit(self, tenant: Tenant) -> None:
        """Install an already-debited tenant onto the driver (caller holds
        the service lock; lock order is service → driver, never back).

        Warm start: when the shared index carries priors and the tenant's
        plan sets ``prior_weight > 0`` (or the index has a default), the
        fresh row's zeroed sampler is warmed through
        :meth:`~repro.index.priors.ChunkPriors.warm_sampler` under the
        tenant's ``select_id`` as the class key.  The warmed ``n1`` is
        stashed on the tenant so ``_reap`` records only the delta."""
        sampler_init = None
        warm_rounds_saved = 0
        if self.index is not None:
            spec = tenant.plan.execution.index
            w = (
                spec.prior_weight if spec is not None
                else self.index.prior_weight
            )
            if w > 0:
                s0 = self.driver.rows[0].carry.sampler
                fresh = dataclasses.replace(
                    s0,
                    n1=jax.numpy.zeros_like(s0.n1),
                    n=jax.numpy.zeros_like(s0.n),
                )
                warmed, equiv = self.index.priors.warm_sampler(
                    fresh, tenant.select_id, w
                )
                if equiv:
                    sampler_init = warmed
                    warm_rounds_saved = int(equiv) // max(
                        self.driver.cohorts, 1
                    )
        tenant.row = self.driver.admit(
            tenant.key,
            result_limit=int(tenant.plan.result_limit),
            base_max_steps=tenant.plan.max_steps,
            select_id=tenant.select_id,
            sampler_init=sampler_init,
            warm_rounds_saved=warm_rounds_saved,
        )
        tenant.row_obj = self.driver.rows[tenant.row]
        if self.index is not None:
            tenant.n1_init = np.asarray(
                tenant.row_obj.carry.sampler.n1, np.float64
            )
        tenant.state = RUNNING

    def _admit_queued(self) -> None:
        """Admit parked plans in (priority, FIFO) order.  Strictly: the
        head blocks the tail, so a large high-priority plan is never
        starved by small late arrivals slipping past it.  A head whose
        projection no longer fits ``total − spent`` (earlier tenants'
        settled spend shrank the ceiling since it was parked) is rejected
        rather than left to block the queue — and the drain — forever."""
        with self._lock:
            self._queue.sort(key=lambda t: (-t.service.priority, t.seq))
            while self._queue:
                head = self._queue[0]
                if self.budget.debit(head.projected_s):
                    self._queue.pop(0)
                    self._admit(head)
                    continue
                if head.projected_s > self._never_fit_bound():
                    self._queue.pop(0)
                    head.state = REJECTED
                    head.reason = self._never_fit_reason(head.projected_s)
                    continue
                break

    # ---- pump --------------------------------------------------------------

    def tick(self, timeout: float = 0.05) -> bool:
        """One service heartbeat: merge at most one driver batch, harvest
        retired tenants, admit queued plans into freed capacity."""
        merged = self.driver.service_tick(timeout=timeout)
        self._reap()
        self._admit_queued()
        return merged

    def _reap(self) -> None:
        """Harvest tenants whose row retired: vacate the slot for reuse
        and settle the budget reservation against the realized sampling
        cost.  Iterates a snapshot taken under the lock — ``submit`` (any
        thread) inserts into ``self.tenants`` concurrently, and a live
        dict iteration here would RuntimeError and kill the pump thread."""
        with self._lock:
            running = [
                t for t in self.tenants.values() if t.state == RUNNING
            ]
        reaped = 0
        for tenant in running:
            row = tenant.row_obj          # bound at admission, never moves
            if row.active or row.inflight or row.vacant:
                continue
            self.driver.vacate(tenant.row)
            tenant.actual_s = sampling_cost(
                int(row.carry.step), self.rates
            ).total_s
            with self._lock:
                self.budget.settle(tenant.projected_s, tenant.actual_s)
                tenant.state = FINISHED
                if self.index is not None and not self.index.read_only:
                    # delta against the warmed admission state, so the
                    # injected prior is never re-recorded as evidence
                    n1 = np.asarray(row.carry.sampler.n1, np.float64)
                    n = np.asarray(row.carry.sampler.n, np.float64)
                    base = (
                        tenant.n1_init
                        if tenant.n1_init is not None
                        else np.zeros_like(n1)
                    )
                    self.index.priors.record(
                        tenant.select_id, n1 - base, n
                    )
            reaped += 1
        if reaped and self.index is not None and not self.index.read_only:
            with self._lock:
                self.index.publish_cache(self.driver.cache)
                if self.index.path is not None:
                    self.index.save()

    def drain(self, deadline_s: float = 120.0) -> None:
        """Block until every queued/running tenant finishes.  With the
        background pump running this polls; without it, it ticks."""
        t0 = time.monotonic()
        while self.busy():
            if time.monotonic() - t0 > deadline_s:
                with self._lock:
                    unfinished = sum(
                        t.state in (QUEUED, RUNNING)
                        for t in self.tenants.values()
                    )
                raise TimeoutError(
                    f"drain exceeded {deadline_s}s with "
                    f"{unfinished} tenants unfinished")
            if self._pump is not None:
                time.sleep(0.01)
            else:
                self.tick()

    def busy(self) -> bool:
        with self._lock:
            return any(
                t.state in (QUEUED, RUNNING)
                for t in self.tenants.values()
            )

    def evict_terminal(self) -> int:
        """Drop FINISHED/REJECTED tenant records so a persistent service
        doesn't accumulate them without bound; returns the count evicted.
        Harvest ``stats()`` first — eviction discards the records."""
        with self._lock:
            dead = [
                tid for tid, t in self.tenants.items()
                if t.state in (FINISHED, REJECTED)
            ]
            for tid in dead:
                del self.tenants[tid]
            return len(dead)

    # ---- reporting ---------------------------------------------------------

    def padding_fraction(self) -> float:
        """RequestBatcher-convention padding over the driver's slot lanes
        (0.0 before any batch has been issued)."""
        d = self.driver.stats
        total = d["lanes_issued"] + d["lanes_padded"]
        return d["lanes_padded"] / total if total else 0.0

    @property
    def occupancy(self) -> float:
        """``1 − padding_fraction()`` — consistent by construction, like
        :attr:`repro.serve.batcher.RequestBatcher.occupancy`."""
        return 1.0 - self.padding_fraction()

    def stats(self) -> dict:
        with self._lock:
            return {
                "tenants": {
                    tid: t.to_dict() for tid, t in self.tenants.items()
                },
                "budget": {
                    "total_s": self.budget.total_s,
                    "committed_s": self.budget.committed_s,
                    "spent_s": self.budget.spent_s,
                    "remaining_s": self.budget.remaining_s,
                },
                "batch": {
                    "occupancy": self.occupancy,
                    "padding_fraction": self.padding_fraction(),
                    "lanes_issued": self.driver.stats["lanes_issued"],
                    "lanes_padded": self.driver.stats["lanes_padded"],
                },
                "driver": dict(self.driver.stats),
                "index": (
                    dict(self.index.stats, entries=len(self.index))
                    if self.index is not None else None
                ),
            }
