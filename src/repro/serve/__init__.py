"""Serving substrate: prefill/decode steps, KV caches, batching."""
