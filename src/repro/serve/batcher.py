"""Request batcher for the detector serving path.

ExSample produces cohorts of frame ids; real deployments also take ad-hoc
detection requests.  The batcher merges both into fixed-size device
batches (static shapes ⇒ one compilation), padding with sentinel frames
whose results are dropped.  It also implements the straggler policy from
DESIGN.md §5: a cohort is *never* a barrier — late frames just join a
later batch, which is sound because sampler updates commute (§3.7.1).

The device-side half of the same machinery serves the Q-axis lowerings of
``SearchPlan`` — the single-device multi-query driver (DESIGN.md §9) and,
per shard, the composed Q×shards driver (DESIGN.md §10):
``dedup_first_index`` collapses the union of several queries' cohort
frames into one detector batch without dropping any slot, and
``DetectionCache`` is a direct-mapped, device-resident cache of raw
detector output so a frame decoded+detected for one query is reused by
every later query that samples it (the Focus/EKO shared-ingest
economics).  The composed driver instantiates one cache per shard and
keeps them replicas by all-gathering each round's fresh detections, so a
frame detected on any shard hits everywhere from the next round on.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class PendingFrame:
    frame_id: int
    chunk_id: int
    cohort: int
    enqueue_round: int


@dataclasses.dataclass
class Batch:
    frame_ids: np.ndarray     # i64[B] (sentinel = -1 padding)
    chunk_ids: np.ndarray     # i64[B]
    valid: np.ndarray         # bool[B]
    cohorts: np.ndarray       # i64[B]


class RequestBatcher:
    def __init__(self, batch_size: int, *, max_wait_rounds: int = 0):
        self.batch_size = batch_size
        self.max_wait_rounds = max_wait_rounds
        self._queue: collections.deque[PendingFrame] = collections.deque()
        self._round = 0
        self.stats = {"batches": 0, "padded_slots": 0, "frames": 0}

    def submit(self, frame_ids: Iterable[int], chunk_ids: Iterable[int], cohort: int) -> None:
        for f, c in zip(frame_ids, chunk_ids):
            self._queue.append(PendingFrame(int(f), int(c), cohort, self._round))

    def ready(self) -> bool:
        if not self._queue:
            return False
        if len(self._queue) >= self.batch_size:
            return True
        oldest = self._queue[0].enqueue_round
        return (self._round - oldest) >= self.max_wait_rounds

    def next_batch(self) -> Optional[Batch]:
        """Emit up to batch_size frames, padding the remainder."""
        self._round += 1
        if not self._queue:
            return None
        take = min(self.batch_size, len(self._queue))
        items = [self._queue.popleft() for _ in range(take)]
        pad = self.batch_size - take
        self.stats["batches"] += 1
        self.stats["padded_slots"] += pad
        self.stats["frames"] += take
        return Batch(
            frame_ids=np.asarray(
                [i.frame_id for i in items] + [-1] * pad, np.int64
            ),
            chunk_ids=np.asarray(
                [i.chunk_id for i in items] + [-1] * pad, np.int64
            ),
            valid=np.asarray([True] * take + [False] * pad, bool),
            cohorts=np.asarray([i.cohort for i in items] + [-1] * pad, np.int64),
        )

    @property
    def occupancy(self) -> float:
        """Fraction of emitted device slots that carried real frames —
        defined as ``1 − padding_fraction()`` so the two ratios are
        consistent BY CONSTRUCTION, including before any batch has been
        emitted (occupancy 1.0, padding 0.0: an empty history wastes no
        slots)."""
        return 1.0 - self.padding_fraction()

    def padding_fraction(self) -> float:
        """Fraction of emitted device slots that were sentinel padding
        (0.0 before any batch has been emitted)."""
        b = self.stats["batches"]
        if not b:
            return 0.0
        return self.stats["padded_slots"] / (b * self.batch_size)


# ---------------------------------------------------------------------------
# Device-side dedup + detection cache (multi-query driver, DESIGN.md §9)
# ---------------------------------------------------------------------------


def dedup_first_index(frame_ids: jax.Array, valid: jax.Array) -> jax.Array:
    """i32[B] — for each slot, the index of the FIRST valid slot holding the
    same frame id (its dedup representative); invalid slots map to
    themselves.

    Every valid slot therefore gathers detections of exactly its own frame
    (no frame a query sampled is ever dropped), and ``first_idx[i] == i``
    marks the one representative per distinct valid frame (no frame is
    detected, or counted, twice in a batch).  O(B²) compare — B = Q·C
    cohort slots, small by construction.
    """
    b = frame_ids.shape[0]
    idx = jnp.arange(b, dtype=jnp.int32)
    same = (frame_ids[:, None] == frame_ids[None, :]) & valid[None, :]
    first = jnp.min(jnp.where(same, idx[None, :], b), axis=1).astype(jnp.int32)
    return jnp.where(valid & (first < b), first, idx)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DetectionCache:
    """Direct-mapped device-resident cache of raw detector output.

    ``tag[s]`` holds the frame id cached in slot ``s`` (-1 = empty);
    ``store`` is the detector's output pytree with a leading [capacity]
    axis.  Frames map to slots by ``frame % capacity``, so a capacity ≥
    the repository's frame count is exact while smaller capacities trade
    memory for evictions — the production knob.
    """

    tag: jax.Array   # i32[S] — cached frame id, -1 = empty
    store: Any       # detection pytree, each leaf [S, ...]

    @property
    def capacity(self) -> int:
        return self.tag.shape[0]


def init_detection_cache(det_struct: Any, capacity: int) -> DetectionCache:
    """Empty cache for a detector whose (single-frame) output shapes are
    ``det_struct`` (e.g. from ``jax.eval_shape(detector, key, frame)``)."""
    store = jax.tree.map(
        lambda s: jnp.zeros((capacity,) + tuple(s.shape), s.dtype), det_struct
    )
    return DetectionCache(tag=jnp.full((capacity,), -1, jnp.int32), store=store)


def cache_lookup(cache: DetectionCache, frame_ids: jax.Array):
    """(hit bool[B], detections pytree with leading [B]) for each frame.

    Sentinel/padding slots (``frame_ids < 0``) NEVER hit: a padded frame id
    of -1 maps to slot ``capacity-1`` and would compare equal to the
    empty-slot tag -1, reporting a phantom hit whose gathered "detections"
    are garbage (zeros or whatever real frame lives there)."""
    slot = frame_ids % cache.capacity
    hit = (frame_ids >= 0) & (cache.tag[slot] == frame_ids)
    vals = jax.tree.map(lambda x: x[slot], cache.store)
    return hit, vals


def cache_insert(
    cache: DetectionCache, frame_ids: jax.Array, dets: Any, mask: jax.Array
) -> DetectionCache:
    """Insert ``dets`` (leading [B]) for masked frames.  When two distinct
    masked frames collide on one cache slot within a batch the first wins —
    scatter order over duplicate indices is otherwise unspecified.
    Sentinel frames (``frame_ids < 0``) never insert, whatever ``mask``
    says: a -1 padding id would otherwise tag slot ``capacity-1`` with -1
    and poison every later lookup of a real frame in that slot."""
    s = cache.capacity
    slot = (frame_ids % s).astype(jnp.int32)
    valid = mask & (frame_ids >= 0)
    first = dedup_first_index(slot, valid)
    keep = valid & (first == jnp.arange(slot.shape[0], dtype=jnp.int32))
    tgt = jnp.where(keep, slot, s)
    tag = cache.tag.at[tgt].set(frame_ids, mode="drop")
    store = jax.tree.map(
        lambda st, v: st.at[tgt].set(v, mode="drop"), cache.store, dets
    )
    return DetectionCache(tag=tag, store=store)
