"""Request batcher for the detector serving path.

ExSample produces cohorts of frame ids; real deployments also take ad-hoc
detection requests.  The batcher merges both into fixed-size device
batches (static shapes ⇒ one compilation), padding with sentinel frames
whose results are dropped.  It also implements the straggler policy from
DESIGN.md §5: a cohort is *never* a barrier — late frames just join a
later batch, which is sound because sampler updates commute (§3.7.1).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Iterable, Optional

import numpy as np


@dataclasses.dataclass
class PendingFrame:
    frame_id: int
    chunk_id: int
    cohort: int
    enqueue_round: int


@dataclasses.dataclass
class Batch:
    frame_ids: np.ndarray     # i64[B] (sentinel = -1 padding)
    chunk_ids: np.ndarray     # i64[B]
    valid: np.ndarray         # bool[B]
    cohorts: np.ndarray       # i64[B]


class RequestBatcher:
    def __init__(self, batch_size: int, *, max_wait_rounds: int = 0):
        self.batch_size = batch_size
        self.max_wait_rounds = max_wait_rounds
        self._queue: collections.deque[PendingFrame] = collections.deque()
        self._round = 0
        self.stats = {"batches": 0, "padded_slots": 0, "frames": 0}

    def submit(self, frame_ids: Iterable[int], chunk_ids: Iterable[int], cohort: int) -> None:
        for f, c in zip(frame_ids, chunk_ids):
            self._queue.append(PendingFrame(int(f), int(c), cohort, self._round))

    def ready(self) -> bool:
        if not self._queue:
            return False
        if len(self._queue) >= self.batch_size:
            return True
        oldest = self._queue[0].enqueue_round
        return (self._round - oldest) >= self.max_wait_rounds

    def next_batch(self) -> Optional[Batch]:
        """Emit up to batch_size frames, padding the remainder."""
        self._round += 1
        if not self._queue:
            return None
        take = min(self.batch_size, len(self._queue))
        items = [self._queue.popleft() for _ in range(take)]
        pad = self.batch_size - take
        self.stats["batches"] += 1
        self.stats["padded_slots"] += pad
        self.stats["frames"] += take
        return Batch(
            frame_ids=np.asarray(
                [i.frame_id for i in items] + [-1] * pad, np.int64
            ),
            chunk_ids=np.asarray(
                [i.chunk_id for i in items] + [-1] * pad, np.int64
            ),
            valid=np.asarray([True] * take + [False] * pad, bool),
            cohorts=np.asarray([i.cohort for i in items] + [-1] * pad, np.int64),
        )

    @property
    def occupancy(self) -> float:
        b = self.stats["batches"]
        if not b:
            return 1.0
        return self.stats["frames"] / (b * self.batch_size)
