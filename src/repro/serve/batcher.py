"""Request batcher for the detector serving path.

ExSample produces cohorts of frame ids; real deployments also take ad-hoc
detection requests.  The batcher merges both into fixed-size device
batches (static shapes ⇒ one compilation), padding with sentinel frames
whose results are dropped.  It also implements the straggler policy from
DESIGN.md §5: a cohort is *never* a barrier — late frames just join a
later batch, which is sound because sampler updates commute (§3.7.1).

The device-side half of the same machinery serves the Q-axis lowerings of
``SearchPlan`` — the single-device multi-query driver (DESIGN.md §9) and,
per shard, the composed Q×shards driver (DESIGN.md §10):
``dedup_first_index`` collapses the union of several queries' cohort
frames into one detector batch without dropping any slot, and
``DetectionCache`` is a direct-mapped, device-resident cache of raw
detector output so a frame decoded+detected for one query is reused by
every later query that samples it (the Focus/EKO shared-ingest
economics).  The composed driver HASH-SHARDS one logical cache over the
mesh (DESIGN.md §14): frame ``f`` lives only on shard ``f % S`` at local
slot ``(f // S) % (capacity // S)``, and per-round lookups/inserts route
between requester and home shard with ``all_to_all`` collectives.  With
``capacity % S == 0`` that placement is a pure transposition of the
direct-mapped slot map, so contents, evictions, and hit/miss outcomes are
bit-identical to a single direct-mapped cache of the same capacity —
``shard_cache_layout`` / ``unshard_cache_layout`` are the two sides of
that bijection, and ``sharded_cache_lookup`` / ``sharded_cache_insert``
are the per-shard halves the drivers run inside ``shard_map``.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class PendingFrame:
    frame_id: int
    chunk_id: int
    cohort: int
    enqueue_round: int


@dataclasses.dataclass
class Batch:
    frame_ids: np.ndarray     # i64[B] (sentinel = -1 padding)
    chunk_ids: np.ndarray     # i64[B]
    valid: np.ndarray         # bool[B]
    cohorts: np.ndarray       # i64[B]


class RequestBatcher:
    def __init__(self, batch_size: int, *, max_wait_rounds: int = 0):
        self.batch_size = batch_size
        self.max_wait_rounds = max_wait_rounds
        self._queue: collections.deque[PendingFrame] = collections.deque()
        self._round = 0
        self.stats = {"batches": 0, "padded_slots": 0, "frames": 0}

    def submit(self, frame_ids: Iterable[int], chunk_ids: Iterable[int], cohort: int) -> None:
        for f, c in zip(frame_ids, chunk_ids):
            self._queue.append(PendingFrame(int(f), int(c), cohort, self._round))

    def ready(self) -> bool:
        if not self._queue:
            return False
        if len(self._queue) >= self.batch_size:
            return True
        oldest = self._queue[0].enqueue_round
        return (self._round - oldest) >= self.max_wait_rounds

    def next_batch(self) -> Optional[Batch]:
        """Emit up to batch_size frames, padding the remainder."""
        self._round += 1
        if not self._queue:
            return None
        take = min(self.batch_size, len(self._queue))
        items = [self._queue.popleft() for _ in range(take)]
        pad = self.batch_size - take
        self.stats["batches"] += 1
        self.stats["padded_slots"] += pad
        self.stats["frames"] += take
        return Batch(
            frame_ids=np.asarray(
                [i.frame_id for i in items] + [-1] * pad, np.int64
            ),
            chunk_ids=np.asarray(
                [i.chunk_id for i in items] + [-1] * pad, np.int64
            ),
            valid=np.asarray([True] * take + [False] * pad, bool),
            cohorts=np.asarray([i.cohort for i in items] + [-1] * pad, np.int64),
        )

    @property
    def occupancy(self) -> float:
        """Fraction of emitted device slots that carried real frames —
        defined as ``1 − padding_fraction()`` so the two ratios are
        consistent BY CONSTRUCTION, including before any batch has been
        emitted (occupancy 1.0, padding 0.0: an empty history wastes no
        slots)."""
        return 1.0 - self.padding_fraction()

    def padding_fraction(self) -> float:
        """Fraction of emitted device slots that were sentinel padding
        (0.0 before any batch has been emitted)."""
        b = self.stats["batches"]
        if not b:
            return 0.0
        return self.stats["padded_slots"] / (b * self.batch_size)


# ---------------------------------------------------------------------------
# Device-side dedup + detection cache (multi-query driver, DESIGN.md §9)
# ---------------------------------------------------------------------------


def dedup_first_index(frame_ids: jax.Array, valid: jax.Array) -> jax.Array:
    """i32[B] — for each slot, the index of the FIRST valid slot holding the
    same frame id (its dedup representative); invalid slots map to
    themselves.

    Every valid slot therefore gathers detections of exactly its own frame
    (no frame a query sampled is ever dropped), and ``first_idx[i] == i``
    marks the one representative per distinct valid frame (no frame is
    detected, or counted, twice in a batch).  O(B²) compare — B = Q·C
    cohort slots, small by construction.
    """
    b = frame_ids.shape[0]
    idx = jnp.arange(b, dtype=jnp.int32)
    same = (frame_ids[:, None] == frame_ids[None, :]) & valid[None, :]
    first = jnp.min(jnp.where(same, idx[None, :], b), axis=1).astype(jnp.int32)
    return jnp.where(valid & (first < b), first, idx)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DetectionCache:
    """Direct-mapped device-resident cache of raw detector output.

    ``tag[s]`` holds the frame id cached in slot ``s`` (-1 = empty);
    ``store`` is the detector's output pytree with a leading [capacity]
    axis.  Frames map to slots by ``frame % capacity``, so a capacity ≥
    the repository's frame count is exact while smaller capacities trade
    memory for evictions — the production knob.
    """

    tag: jax.Array   # i32[S] — cached frame id, -1 = empty
    store: Any       # detection pytree, each leaf [S, ...]

    @property
    def capacity(self) -> int:
        return self.tag.shape[0]


def init_detection_cache(det_struct: Any, capacity: int) -> DetectionCache:
    """Empty cache for a detector whose (single-frame) output shapes are
    ``det_struct`` (e.g. from ``jax.eval_shape(detector, key, frame)``)."""
    store = jax.tree.map(
        lambda s: jnp.zeros((capacity,) + tuple(s.shape), s.dtype), det_struct
    )
    return DetectionCache(tag=jnp.full((capacity,), -1, jnp.int32), store=store)


def cache_lookup(cache: DetectionCache, frame_ids: jax.Array):
    """(hit bool[B], detections pytree with leading [B]) for each frame.

    Sentinel/padding slots (``frame_ids < 0``) NEVER hit: a padded frame id
    of -1 maps to slot ``capacity-1`` and would compare equal to the
    empty-slot tag -1, reporting a phantom hit whose gathered "detections"
    are garbage (zeros or whatever real frame lives there)."""
    slot = frame_ids % cache.capacity
    hit = (frame_ids >= 0) & (cache.tag[slot] == frame_ids)
    vals = jax.tree.map(lambda x: x[slot], cache.store)
    return hit, vals


def cache_insert(
    cache: DetectionCache, frame_ids: jax.Array, dets: Any, mask: jax.Array
) -> DetectionCache:
    """Insert ``dets`` (leading [B]) for masked frames.  When two distinct
    masked frames collide on one cache slot within a batch the first wins —
    scatter order over duplicate indices is otherwise unspecified.
    Sentinel frames (``frame_ids < 0``) never insert, whatever ``mask``
    says: a -1 padding id would otherwise tag slot ``capacity-1`` with -1
    and poison every later lookup of a real frame in that slot."""
    s = cache.capacity
    slot = (frame_ids % s).astype(jnp.int32)
    valid = mask & (frame_ids >= 0)
    first = dedup_first_index(slot, valid)
    keep = valid & (first == jnp.arange(slot.shape[0], dtype=jnp.int32))
    tgt = jnp.where(keep, slot, s)
    tag = cache.tag.at[tgt].set(frame_ids, mode="drop")
    store = jax.tree.map(
        lambda st, v: st.at[tgt].set(v, mode="drop"), cache.store, dets
    )
    return DetectionCache(tag=tag, store=store)


# ---------------------------------------------------------------------------
# Hash-sharded cache: one logical copy across the mesh (DESIGN.md §14)
# ---------------------------------------------------------------------------
#
# Placement: with total capacity S·L (L = capacity // num_shards), frame f
# lives on home shard ``f % S`` at local slot ``(f // S) % L``.  Writing
# r = f % (S·L) for the direct-mapped slot, the home is ``r % S`` and the
# local slot is ``r // S`` — i.e. the sharded layout is EXACTLY the
# direct-mapped slot array reshaped [L, S] and transposed to [S, L].  Two
# frames collide under the sharded placement iff f1 ≡ f2 (mod S·L), the
# same collision classes as the direct-mapped cache, so per-slot contents,
# evictions, and hit/miss outcomes are bit-identical at equal capacity —
# only WHERE each slot physically lives changes.


def _cache_local_cap(capacity: int, num_shards: int) -> int:
    if capacity % num_shards:
        raise ValueError(
            f"hash-sharded cache capacity {capacity} must be a multiple of "
            f"{num_shards} shards — pad the capacity before init/warm "
            "(a non-divisible capacity would silently mis-place frames)"
        )
    return capacity // num_shards


def shard_cache_layout(cache: DetectionCache, num_shards: int) -> DetectionCache:
    """Permute a direct-mapped cache into the hash-sharded global layout:
    index ``s·L + j`` of the result holds direct-mapped slot ``j·S + s``,
    so sharding the leading axis over the mesh hands shard ``s`` exactly
    its home entries (frames with ``f % S == s``) at local slot
    ``(f // S) % L``.  A pure transposition — bit-exact inverse of
    :func:`unshard_cache_layout`."""
    cap = cache.capacity
    local = _cache_local_cap(cap, num_shards)
    perm = lambda x: (
        x.reshape((local, num_shards) + x.shape[1:])
        .swapaxes(0, 1)
        .reshape((cap,) + x.shape[1:])
    )
    return DetectionCache(
        tag=perm(cache.tag), store=jax.tree.map(perm, cache.store)
    )


def unshard_cache_layout(cache: DetectionCache, num_shards: int) -> DetectionCache:
    """Inverse of :func:`shard_cache_layout`: back to the direct-mapped
    layout every host-side consumer (``cache_lookup``, index publish,
    parity tests) understands."""
    cap = cache.capacity
    local = _cache_local_cap(cap, num_shards)
    perm = lambda x: (
        x.reshape((num_shards, local) + x.shape[1:])
        .swapaxes(0, 1)
        .reshape((cap,) + x.shape[1:])
    )
    return DetectionCache(
        tag=perm(cache.tag), store=jax.tree.map(perm, cache.store)
    )


def reshard_cache_host(cache: DetectionCache, new_capacity: int) -> DetectionCache:
    """Re-place a direct-mapped cache into a NEW capacity (host-side,
    eager): occupied entries re-map to ``frame % new_capacity`` in
    ascending frame-id order, first occupant wins — the same deterministic
    fill convention as ``RepositoryIndex.warm``, so an elastic mesh shrink
    that changes the divisibility-padded capacity replays identically on
    every survivor.  A no-op (same object) when the capacity already
    matches."""
    if new_capacity == cache.capacity:
        return cache
    if new_capacity < 1:
        raise ValueError(f"new_capacity must be >= 1, got {new_capacity}")
    tag_h = np.asarray(cache.tag)
    leaves, treedef = jax.tree.flatten(cache.store)
    leaves_h = [np.asarray(leaf) for leaf in leaves]
    new_tag = np.full((new_capacity,), -1, np.int32)
    new_leaves = [
        np.zeros((new_capacity,) + leaf.shape[1:], leaf.dtype)
        for leaf in leaves_h
    ]
    occupied = np.flatnonzero(tag_h >= 0)
    for src in occupied[np.argsort(tag_h[occupied], kind="stable")]:
        f = int(tag_h[src])
        slot = f % new_capacity
        if new_tag[slot] != -1:
            continue
        new_tag[slot] = f
        for k, leaf in enumerate(leaves_h):
            new_leaves[k][slot] = leaf[src]
    return DetectionCache(
        tag=jnp.asarray(new_tag),
        store=jax.tree.unflatten(
            treedef, [jnp.asarray(x) for x in new_leaves]
        ),
    )


def sharded_cache_lookup(
    cache_local: DetectionCache,
    frame_ids: jax.Array,
    shard_id: jax.Array,
    num_shards: int,
):
    """Home-shard half of the routed lookup, run per shard inside
    ``shard_map``: serve exactly the probes homed here (``frame % S ==
    shard_id``); everything else — sentinels included — reports a miss
    with unread gathered values.  ``frame_ids`` may be any shape."""
    local = cache_local.capacity
    mine = (frame_ids >= 0) & (frame_ids % num_shards == shard_id)
    slot = (frame_ids // num_shards) % local
    hit = mine & (cache_local.tag[slot] == frame_ids)
    vals = jax.tree.map(lambda x: x[slot], cache_local.store)
    return hit, vals


def sharded_cache_insert(
    cache_local: DetectionCache,
    frame_ids: jax.Array,
    dets: Any,
    mask: jax.Array,
    shard_id: jax.Array,
    num_shards: int,
) -> DetectionCache:
    """Home-shard half of the routed insert (flat [B] batch, already
    routed here): store masked frames homed on this shard at their local
    slots, first-write-wins on within-batch slot collisions in batch
    order — the same winner the direct-mapped :func:`cache_insert` picks
    over the equivalent global batch."""
    local = cache_local.capacity
    valid = (
        mask & (frame_ids >= 0) & (frame_ids % num_shards == shard_id)
    )
    slot = ((frame_ids // num_shards) % local).astype(jnp.int32)
    first = dedup_first_index(slot, valid)
    keep = valid & (first == jnp.arange(slot.shape[0], dtype=jnp.int32))
    tgt = jnp.where(keep, slot, local)
    tag = cache_local.tag.at[tgt].set(frame_ids, mode="drop")
    store = jax.tree.map(
        lambda st, v: st.at[tgt].set(v, mode="drop"), cache_local.store, dets
    )
    return DetectionCache(tag=tag, store=store)
