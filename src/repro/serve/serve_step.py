"""Serving steps: prefill, decode, and the detector step for ExSample.

These are the production inference paths the dry-run lowers:

  * ``build_prefill_step``  — full-context forward returning last-position
    logits + populated KV caches (the ``prefill_32k`` cell).
  * ``build_decode_step``   — one autoregressive token against a KV cache
    of the assigned length (``decode_32k`` / ``long_500k`` cells).
  * ``build_detect_step``   — frames → backbone → detection head → boxes;
    the step the ExSample search loop calls per cohort batch.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models.detection import apply_head, pool_features
from repro.models.transformer import (
    DecodeCache,
    forward_decode,
    forward_lm,
)


def build_prefill_step(cfg: ModelConfig, run: RunConfig, *, moe_groups: int = 1):
    def prefill(params: dict, batch: dict) -> jax.Array:
        if run.stacked:
            from repro.models.stacked import forward_lm_stacked as fwd
        else:
            fwd = forward_lm
        logits = fwd(
            params, batch, cfg, run, mode="prefill", moe_groups=moe_groups,
            last_only=True,
        )
        return logits[:, -1]          # next-token logits

    return prefill


def build_decode_step(cfg: ModelConfig, run: RunConfig, *, moe_groups: int = 1):
    def decode(params: dict, token: jax.Array, cache: DecodeCache):
        logits, cache = forward_decode(
            params, token, cache, cfg, run, moe_groups=moe_groups
        )
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_token, logits, cache

    return decode


def build_detect_step(
    cfg: ModelConfig,
    run: RunConfig,
    *,
    max_dets: int,
    num_classes: int,
    feat_dim: int,
    moe_groups: int = 1,
) -> Callable:
    """frames [B, S, D_embed-as-tokens…] → detections.

    The frame enters as a short token sequence (patch embeddings for vlm,
    frame embedding tiled otherwise); backbone features are pooled and the
    detection head emits fixed slots.  Used by examples + the search
    driver; statically shaped so one compilation serves the whole query.
    """
    # Detection consumes backbone *features* (pre-unembed), so it drives
    # the layer stack directly rather than going through forward_lm.
    from repro.models.transformer import embed_tokens, embed_vlm, _decoder_layer
    from repro.models.layers import apply_norm

    def detect_features(params: dict, batch: dict) -> jax.Array:
        if cfg.family == "vlm":
            x = embed_vlm(params, batch["tokens"], batch["patches"], cfg)
        else:
            x = embed_tokens(params, batch["tokens"], cfg)
        positions = jnp.arange(x.shape[1])[None, :]
        for i in range(cfg.num_layers):
            x = _decoder_layer(
                params[f"layer_{i}"], x, cfg, run, i,
                positions=positions, cross_kv=None,
                moe_groups=moe_groups, seq_shard=False,
            )
        return apply_norm(cfg.norm, params["norm_f"], x)

    def detect(params: dict, head_params: dict, batch: dict):
        hidden = detect_features(params, batch)
        pooled = pool_features(hidden)
        return apply_head(
            head_params, pooled,
            max_dets=max_dets, num_classes=num_classes, feat_dim=feat_dim,
        )

    return detect
