"""Three-term roofline from a compiled dry-run artifact (DESIGN.md §6).

Hardware constants: TPU v5e-class target.
  peak bf16 compute : 197 TFLOP/s per chip
  HBM bandwidth     : 819 GB/s per chip
  ICI link bandwidth: 50 GB/s per link

terms (seconds, per step, per chip — cost_analysis is per-device after
SPMD partitioning, verified in DESIGN.md §6):
  compute    = HLO_FLOPs / peak
  memory     = HLO_bytes / HBM_bw
  collective = collective_bytes / ICI_bw
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
ICI_BW = 50e9             # B/s / link
HBM_PER_CHIP = 16 * 1024**3


@dataclasses.dataclass(frozen=True)
class Roofline:
    name: str
    mesh: str
    chips: int
    hlo_flops: float          # per device
    hlo_bytes: float          # per device
    collective: dict          # parsed from HLO (per device)
    model_flops: float        # analytic useful FLOPs (global)
    arg_bytes: float
    temp_bytes: float
    out_bytes: float

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective.get("total_bytes", 0) / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline step estimate: max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips) — remat/padding/dispatch waste."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline step time."""
        denom = self.step_time * self.chips * PEAK_FLOPS
        return self.model_flops / denom if denom else 0.0

    @property
    def fits_hbm(self) -> bool:
        return (self.arg_bytes + self.temp_bytes + self.out_bytes) <= HBM_PER_CHIP

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_dev": self.hlo_flops,
            "hlo_bytes_per_dev": self.hlo_bytes,
            "collective": self.collective,
            "model_flops": self.model_flops,
            "arg_bytes": self.arg_bytes,
            "temp_bytes": self.temp_bytes,
            "out_bytes": self.out_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time,
            "useful_flops_ratio": self.useful_ratio,
            "mfu_at_roofline": self.mfu,
            "fits_hbm": self.fits_hbm,
        }


def from_compiled(
    name: str,
    mesh_desc: str,
    chips: int,
    compiled,
    hlo_text: str,
    model_flops: float,
) -> Roofline:
    from repro.analysis.hlo import collective_bytes

    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    ma = compiled.memory_analysis()
    return Roofline(
        name=name,
        mesh=mesh_desc,
        chips=chips,
        hlo_flops=float(ca.get("flops", 0.0)),
        hlo_bytes=float(ca.get("bytes accessed", 0.0)),
        collective=collective_bytes(hlo_text),
        model_flops=model_flops,
        arg_bytes=float(ma.argument_size_in_bytes),
        temp_bytes=float(ma.temp_size_in_bytes),
        out_bytes=float(ma.output_size_in_bytes),
    )


def save_records(path: str, records: list[dict]) -> None:
    with open(path, "w") as f:
        json.dump(records, f, indent=1)


def load_records(path: str) -> list[dict]:
    with open(path) as f:
        return json.load(f)
