"""HLO text parsing: collective bytes + op census.

``cost_analysis`` does not expose collective traffic, so we parse the
compiled (SPMD-partitioned) HLO text: shapes there are already
*per-device*, so summing operand bytes of every collective op gives the
per-device collective payload of one step.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  "%all-reduce.5 = f32[16,128]{1,0} all-reduce(%x), ..."
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+([a-z0-9\-]+)\("
)
_TUPLE_RE = re.compile(r"=\s*\(([^)]*)\)\s+([a-z0-9\-]+)\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device output bytes of each collective op kind.

    Uses the *result* shape (for all-gather this is the gathered size, a
    fair proxy for link traffic; for reduce-scatter the scattered output;
    for all-reduce the full buffer — matching the ring-transfer volume
    within a small constant).
    """
    totals: dict[str, int] = defaultdict(int)
    counts: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not any(f" {op}(" in stripped or stripped.startswith(op) for op in COLLECTIVE_OPS):
            continue
        for op in COLLECTIVE_OPS:
            if f" {op}(" not in stripped:
                continue
            if f" {op}-start(" in stripped or f" {op}-done(" in stripped:
                continue
            m = _OP_RE.search(stripped)
            nbytes = 0
            if m and m.group(3) == op:
                nbytes = _shape_bytes(m.group(1), m.group(2))
            else:
                mt = _TUPLE_RE.search(stripped)
                if mt and mt.group(2) == op:
                    for dtype, dims in _SHAPE_RE.findall(mt.group(1)):
                        nbytes += _shape_bytes(dtype, dims)
            if nbytes:
                totals[op] += nbytes
                counts[op] += 1
    return {
        "bytes_by_op": dict(totals),
        "counts_by_op": dict(counts),
        "total_bytes": int(sum(totals.values())),
    }


# computation header: "%name (params...) -> type {"; params may contain
# nested parens (tuple types), so only anchor on the name and trailing "{"
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+) \(.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_WHILE_RE2 = re.compile(r"while\(.*?\), body=%?([\w\.\-]+), condition=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\{?\}? constant\((\d+)\)")


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    """Map computation name → its body lines."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    """Heuristic trip count of a scan-style while: the s32 bound constant in
    the condition (jax lowers scan as `i < N`).  Falls back to 1."""
    consts = []
    for line in cond_lines:
        consts += [int(x) for x in _CONST_RE.findall(line)]
    return max(consts) if consts else 1


def _line_collective_bytes(line: str) -> tuple[str, int] | None:
    stripped = line.strip()
    for op in COLLECTIVE_OPS:
        if f" {op}(" not in stripped:
            continue
        if f" {op}-start(" in stripped or f" {op}-done(" in stripped:
            continue
        m = _OP_RE.search(stripped)
        if m and m.group(3) == op:
            return op, _shape_bytes(m.group(1), m.group(2))
        mt = _TUPLE_RE.search(stripped)
        if mt and mt.group(2) == op:
            nbytes = sum(
                _shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(mt.group(1))
            )
            return op, nbytes
    return None


def collective_bytes_scaled(hlo_text: str) -> dict:
    """Collective bytes with while-loop trip-count scaling.

    ``HloCostAnalysis``-style single-count is wrong for scan-over-layers /
    microbatch loops; this walks the computation graph from ENTRY,
    multiplying collectives inside a while body by the loop's trip count
    (parsed from the condition's s32 bound).
    """
    comps = _split_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            if m:
                entry = m.group(1)
    totals: dict[str, float] = defaultdict(float)
    counts: dict[str, int] = defaultdict(int)

    def walk(comp: str, mult: float, seen: tuple):
        if comp not in comps or comp in seen:
            return
        for line in comps[comp]:
            got = _line_collective_bytes(line)
            if got:
                op, nbytes = got
                totals[op] += nbytes * mult
                counts[op] += 1
            wm = _WHILE_RE.search(line) or _WHILE_RE2.search(line)
            if wm:
                a, b = wm.group(1), wm.group(2)
                cond, body = (a, b) if _WHILE_RE.search(line) else (b, a)
                trips = _trip_count(comps.get(cond, []))
                walk(body, mult * max(trips, 1), seen + (comp,))

    if entry:
        walk(entry, 1.0, ())
    else:  # fallback: flat parse
        return collective_bytes(hlo_text)
    return {
        "bytes_by_op": {k: int(v) for k, v in totals.items()},
        "counts_by_op": dict(counts),
        "total_bytes": int(sum(totals.values())),
    }


def op_census(hlo_text: str, ops=("dot", "custom-call", "while", "fusion")) -> dict:
    census: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        for op in ops:
            if f" {op}(" in line:
                census[op] += 1
    return dict(census)
