"""Roofline + HLO analysis utilities."""
