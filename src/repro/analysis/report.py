"""Render EXPERIMENTS.md sections from dry-run artifacts.

  PYTHONPATH=src python -m repro.analysis.report            # print tables
"""
from __future__ import annotations

import json
import os

ART = os.path.join(os.path.dirname(__file__), "../../../artifacts/dryrun")


def load(mesh_tag: str) -> list[dict]:
    d = os.path.join(ART, mesh_tag)
    if not os.path.isdir(d):
        return []
    out = []
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                out.append(json.load(fh))
    return out


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}µs"


def roofline_table(mesh_tag: str) -> str:
    recs = load(mesh_tag)
    if not recs:
        return f"(no artifacts for {mesh_tag})"
    lines = [
        "| cell | t_compute | t_memory | t_collective | bottleneck | "
        "MODEL_FLOPS/HLO | MFU@roofline | HBM/chip (analytic) | fits |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs.sort(key=lambda r: (r["name"].split(":")[0],
                             order.get(r["name"].split(":")[1], 9)))
    for r in recs:
        if r.get("skipped"):
            lines.append(
                f"| {r['name']} | — | — | — | *skipped: {r['skipped']}* | — | — | — | — |"
            )
            continue
        lines.append(
            "| {name} | {tc} | {tm} | {tl} | **{b}** | {ur:.2f} | {mfu:.1%} | "
            "{hbm:.1f} GiB | {fits} |".format(
                name=r["name"],
                tc=_fmt_s(r["t_compute_s"]),
                tm=_fmt_s(r["t_memory_s"]),
                tl=_fmt_s(r["t_collective_s"]),
                b=r["bottleneck"],
                ur=r["useful_flops_ratio"],
                mfu=r["mfu_at_roofline"],
                hbm=r.get("analytic_hbm_bytes", 0) / 2**30,
                fits="✓" if r.get("fits_hbm") else "✗",
            )
        )
    return "\n".join(lines)


def dryrun_table(mesh_tag: str) -> str:
    recs = load(mesh_tag)
    if not recs:
        return f"(no artifacts for {mesh_tag})"
    lines = [
        "| cell | HLO GFLOPs/dev | HLO GB/dev | collective GB/dev (by op) | "
        "HBM cpu-analysis | HBM analytic | compile (cost+mem) |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: r["name"]):
        if r.get("skipped"):
            continue
        ops = ", ".join(
            f"{k.replace('all-','a-')}: {v/2**30:.2f}"
            for k, v in sorted(r["collective"].get("bytes_by_op", {}).items())
        )
        lines.append(
            "| {name} | {fl:.1f} | {by:.1f} | {coll} | {hc:.1f} GiB | {ha:.1f} GiB "
            "| {t1:.0f}+{t2:.0f}s |".format(
                name=r["name"],
                fl=r["hlo_flops_per_dev"] / 1e9,
                by=r["hlo_bytes_per_dev"] / 1e9,
                coll=ops or "0",
                hc=r["hbm_footprint_bytes"] / 2**30,
                ha=r.get("analytic_hbm_bytes", 0) / 2**30,
                t1=r.get("t_cost_config_s", 0),
                t2=r.get("t_mem_config_s", 0),
            )
        )
    return "\n".join(lines)


def summary_stats(mesh_tag: str) -> dict:
    recs = [r for r in load(mesh_tag) if not r.get("skipped")]
    if not recs:
        return {}
    import collections

    bn = collections.Counter(r["bottleneck"] for r in recs)
    return {
        "cells": len(recs),
        "bottlenecks": dict(bn),
        "all_fit": all(r.get("fits_hbm") for r in recs),
        "worst_mfu": min(r["mfu_at_roofline"] for r in recs),
        "best_mfu": max(r["mfu_at_roofline"] for r in recs),
    }


def main():
    for tag in ("single_pod_16x16", "multi_pod_2x16x16",
                "single_pod_16x16_optimized", "multi_pod_2x16x16_optimized"):
        recs = load(tag)
        if not recs:
            continue
        print(f"\n## {tag}\n")
        print(roofline_table(tag))
        print()
        print(summary_stats(tag))


if __name__ == "__main__":
    main()
