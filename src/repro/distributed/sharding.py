"""Logical-axis sharding: one rule table maps schema axes to mesh axes.

``ShardingRules`` resolves the logical axis names used by every ParamSpec
and activation hint to mesh axes.  Model code never mentions mesh axes —
it calls ``shard_hint(x, *logical_axes)`` which is a no-op unless a rules
context is active (so smoke tests on 1 CPU device run the same code).

Activation logical axes:
  "dp"     — batch / groups           → ("pod", "data") or ("data",)
  "seq"    — sequence (SP / KV shard) → "model"
  "heads"  — attention heads          → "model"
  "mlp"/"inner"/"expert"/"vocab"      → "model"
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import ParamSpec, Schema

_ACTIVE_RULES: contextvars.ContextVar[Optional["ShardingRules"]] = (
    contextvars.ContextVar("repro_sharding_rules", default=None)
)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    rules: dict

    @staticmethod
    def for_mesh(mesh: Mesh, *, fsdp_params: bool = False) -> "ShardingRules":
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        dp = dp if len(dp) > 1 else (dp[0] if dp else None)
        has_data = "data" in mesh.axis_names
        table = {
            "dp": dp,
            "seq": "model",
            "heads": "model",
            "kv": "model",
            "mlp": "model",
            "inner": "model",
            "expert": "model",
            "expert_ff": "data" if has_data else None,
            "vocab": "model",
            # fsdp: weight embed-dims shard over `data`, gathered on use
            "embed": "data" if (fsdp_params and has_data) else None,
            None: None,
        }
        return ShardingRules(mesh=mesh, rules=table)

    def pspec(self, logical: tuple) -> P:
        """Resolve logical → mesh axes, dropping duplicate axis uses (a
        PartitionSpec may bind each mesh axis once; with fsdp enabled
        e.g. expert w_down carries both expert_ff→data and embed→data —
        the leftmost binding wins)."""
        used: set = set()
        out = []
        for ax in logical:
            mesh_ax = self.rules.get(ax, None)
            flat = (
                mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
            ) if mesh_ax else ()
            if mesh_ax is None or any(a in used for a in flat):
                out.append(None)
            else:
                used.update(flat)
                out.append(mesh_ax)
        return P(*out)

    def sharding(self, logical: tuple) -> NamedSharding:
        return NamedSharding(self.mesh, self.pspec(logical))

    @property
    def dp_shards(self) -> int:
        dp = self.rules["dp"]
        if dp is None:
            return 1
        axes = dp if isinstance(dp, tuple) else (dp,)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def model_shards(self) -> int:
        return self.mesh.shape.get("model", 1)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    token = _ACTIVE_RULES.set(rules)
    try:
        yield
    finally:
        _ACTIVE_RULES.reset(token)


def active_rules() -> Optional[ShardingRules]:
    return _ACTIVE_RULES.get()


def shard_hint(x: jax.Array, *logical) -> jax.Array:
    """Sharding constraint by logical axes; identity without active rules."""
    rules = active_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, rules.sharding(tuple(logical)))


def param_pspecs(schema: Schema, rules: ShardingRules):
    """PartitionSpec tree matching a parameter schema."""
    return jax.tree.map(
        lambda s: rules.pspec(s.logical),
        schema,
        is_leaf=lambda s: isinstance(s, ParamSpec),
    )


def param_shardings(schema: Schema, rules: ShardingRules):
    return jax.tree.map(
        lambda s: rules.sharding(s.logical),
        schema,
        is_leaf=lambda s: isinstance(s, ParamSpec),
    )
