"""Fault tolerance: failure detection, restart policy, straggler tracking.

What "fault tolerant" means for this system at 1000+ nodes:

  1. **State is always reconstructible**: model params + optimizer +
     ExSample sampler/matcher state + pipeline cursors checkpoint
     atomically (``repro.train.checkpoint``); PRNG keys are derived from
     step counters, never stored device-only.  Restart = restore + replay
     from the cursor.  (Tested in ``tests/test_fault_tolerance.py``.)
  2. **Failures are detected, not assumed away**: ``HeartbeatMonitor``
     tracks per-worker liveness from the driver; a missed deadline marks
     the worker dead and triggers ``ElasticPlan`` (repro.distributed
     .elastic) to drop to a smaller mesh at the next checkpoint boundary.
  3. **Stragglers don't stall sampling**: ExSample cohorts merge
     commutatively (§3.7.1) so slow workers are absorbed — the policy
     here just decides when a straggler is slow enough to re-issue its
     cohort elsewhere (work stealing with at-most-once *effect*, since a
     duplicate frame only perturbs statistics by one sample, which the
     estimator tolerates — documented deviation from exactly-once).

The monitor is transport-agnostic (timestamps in, decisions out) so the
unit tests drive it with synthetic clocks; a deployment feeds it real
heartbeats.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import numpy as np


class WorkerState(enum.Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    DEAD = "dead"


@dataclasses.dataclass
class WorkerInfo:
    # None = registered from a timestamp-less message (legacy caller);
    # liveness is unknown until a real heartbeat arrives, so sweep()
    # treats the worker as silent for 0 s rather than fabricating a
    # monotonic-clock age of `now − 0.0` that would kill it on sight.
    last_heartbeat: Optional[float]
    state: WorkerState = WorkerState.HEALTHY
    inflight_cohort: Optional[int] = None
    inflight_since: Optional[float] = None   # assign() timestamp
    completed: int = 0
    ema_latency: float = 0.0


@dataclasses.dataclass
class HeartbeatMonitor:
    """Driver-side liveness + straggler detection."""

    suspect_after_s: float = 30.0
    dead_after_s: float = 120.0
    straggler_factor: float = 3.0     # × median cohort latency ⇒ re-issue
    ema: float = 0.9

    def __post_init__(self):
        self.workers: dict[int, WorkerInfo] = {}

    def register(self, worker: int, now: Optional[float]) -> None:
        self.workers[worker] = WorkerInfo(last_heartbeat=now)

    def _ensure(self, worker: int, now: Optional[float]) -> WorkerInfo:
        """Register-on-first-contact: a restarted driver process observing
        an old worker's heartbeat (or completion) must absorb it, not
        KeyError — the monitor's view of the fleet is rebuilt from the
        messages themselves."""
        w = self.workers.get(worker)
        if w is None:
            self.register(worker, now)
            w = self.workers[worker]
        return w

    def heartbeat(self, worker: int, now: float) -> None:
        w = self._ensure(worker, now)
        w.last_heartbeat = now
        if w.state is not WorkerState.DEAD:
            w.state = WorkerState.HEALTHY

    def record_completion(
        self, worker: int, latency: float, now: Optional[float] = None
    ) -> None:
        # unknown worker and no timestamp: register with the None sentinel
        # (NOT 0.0 — on a monotonic clock that reads as dead_after_s of
        # silence and the next sweep would kill the worker and re-issue
        # its cohort); the next real heartbeat starts liveness tracking
        w = self._ensure(worker, now)
        w.completed += 1
        w.inflight_cohort = None
        w.inflight_since = None
        w.ema_latency = (
            latency if w.ema_latency == 0
            else self.ema * w.ema_latency + (1 - self.ema) * latency
        )

    def assign(
        self, worker: int, cohort: int, now: Optional[float] = None
    ) -> None:
        """Record that ``worker`` started ``cohort`` at ``now`` —
        ``inflight_since`` is what the straggler rule measures against
        (without a timestamp the cohort can only be re-issued on death,
        never as a straggler)."""
        w = self._ensure(worker, now)
        w.inflight_cohort = cohort
        w.inflight_since = now

    def sweep(self, now: float) -> dict:
        """Advance liveness states; return actions."""
        dead, suspects, reissue = [], [], []
        latencies = [w.ema_latency for w in self.workers.values() if w.ema_latency]
        median = float(np.median(latencies)) if latencies else 0.0
        for wid, w in self.workers.items():
            # no real heartbeat yet (timestamp-less registration): liveness
            # is unknowable, not overdue — skip dead/suspect transitions
            # until the first heartbeat; the straggler rule below still
            # applies if assign() carried a real timestamp
            silent = 0.0 if w.last_heartbeat is None else now - w.last_heartbeat
            if silent >= self.dead_after_s and w.state is not WorkerState.DEAD:
                w.state = WorkerState.DEAD
                dead.append(wid)
                if w.inflight_cohort is not None:
                    reissue.append(w.inflight_cohort)
                    w.inflight_cohort = None
                    w.inflight_since = None
            elif silent >= self.suspect_after_s and w.state is WorkerState.HEALTHY:
                w.state = WorkerState.SUSPECT
                suspects.append(wid)
            # straggler: alive but its inflight cohort is way over budget.
            # The rule measures THE COHORT's elapsed time (now −
            # inflight_since), not the worker's historical ema_latency: one
            # slow completed cohort inflates the EMA for ~1/(1−ema) sweeps,
            # and comparing the EMA to the median would re-issue every
            # subsequent cohort from that worker the moment it is assigned
            # — duplicate work for an entire recovery window.
            if (
                w.state is WorkerState.HEALTHY
                and w.inflight_cohort is not None
                and w.inflight_since is not None
                and median > 0
                and (now - w.inflight_since) > self.straggler_factor * median
            ):
                reissue.append(w.inflight_cohort)
                w.inflight_cohort = None
                w.inflight_since = None
        return {"dead": dead, "suspect": suspects, "reissue_cohorts": reissue}

    @property
    def healthy_workers(self) -> list[int]:
        return [
            wid
            for wid, w in self.workers.items()
            if w.state is not WorkerState.DEAD
        ]


@dataclasses.dataclass(frozen=True)
class RestartPolicy:
    """How a run resumes after failure (consumed by launch drivers)."""

    max_restarts: int = 100
    checkpoint_every_steps: int = 100
    lose_at_most_steps: int = 100     # == checkpoint_every_steps by default

    def should_restart(self, restart_count: int) -> bool:
        return restart_count < self.max_restarts
