"""Fault tolerance: failure detection, restart policy, straggler tracking.

What "fault tolerant" means for this system at 1000+ nodes:

  1. **State is always reconstructible**: model params + optimizer +
     ExSample sampler/matcher state + pipeline cursors checkpoint
     atomically (``repro.train.checkpoint``); PRNG keys are derived from
     step counters, never stored device-only.  Restart = restore + replay
     from the cursor.  (Tested in ``tests/test_fault_tolerance.py``.)
  2. **Failures are detected, not assumed away**: ``HeartbeatMonitor``
     tracks per-worker liveness from the driver; a missed deadline marks
     the worker dead and triggers ``ElasticPlan`` (repro.distributed
     .elastic) to drop to a smaller mesh at the next checkpoint boundary.
  3. **Stragglers don't stall sampling**: ExSample cohorts merge
     commutatively (§3.7.1) so slow workers are absorbed — the policy
     here just decides when a straggler is slow enough to re-issue its
     cohort elsewhere (work stealing with at-most-once *effect*, since a
     duplicate frame only perturbs statistics by one sample, which the
     estimator tolerates — documented deviation from exactly-once).

The monitor is transport-agnostic (timestamps in, decisions out) so the
unit tests drive it with synthetic clocks; a deployment feeds it real
heartbeats.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import numpy as np


class WorkerState(enum.Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    DEAD = "dead"


@dataclasses.dataclass
class WorkerInfo:
    last_heartbeat: float
    state: WorkerState = WorkerState.HEALTHY
    inflight_cohort: Optional[int] = None
    completed: int = 0
    ema_latency: float = 0.0


@dataclasses.dataclass
class HeartbeatMonitor:
    """Driver-side liveness + straggler detection."""

    suspect_after_s: float = 30.0
    dead_after_s: float = 120.0
    straggler_factor: float = 3.0     # × median cohort latency ⇒ re-issue
    ema: float = 0.9

    def __post_init__(self):
        self.workers: dict[int, WorkerInfo] = {}

    def register(self, worker: int, now: float) -> None:
        self.workers[worker] = WorkerInfo(last_heartbeat=now)

    def heartbeat(self, worker: int, now: float) -> None:
        w = self.workers[worker]
        w.last_heartbeat = now
        if w.state is not WorkerState.DEAD:
            w.state = WorkerState.HEALTHY

    def record_completion(self, worker: int, latency: float) -> None:
        w = self.workers[worker]
        w.completed += 1
        w.inflight_cohort = None
        w.ema_latency = (
            latency if w.ema_latency == 0
            else self.ema * w.ema_latency + (1 - self.ema) * latency
        )

    def assign(self, worker: int, cohort: int) -> None:
        self.workers[worker].inflight_cohort = cohort

    def sweep(self, now: float) -> dict:
        """Advance liveness states; return actions."""
        dead, suspects, reissue = [], [], []
        latencies = [w.ema_latency for w in self.workers.values() if w.ema_latency]
        median = float(np.median(latencies)) if latencies else 0.0
        for wid, w in self.workers.items():
            silent = now - w.last_heartbeat
            if silent >= self.dead_after_s and w.state is not WorkerState.DEAD:
                w.state = WorkerState.DEAD
                dead.append(wid)
                if w.inflight_cohort is not None:
                    reissue.append(w.inflight_cohort)
                    w.inflight_cohort = None
            elif silent >= self.suspect_after_s and w.state is WorkerState.HEALTHY:
                w.state = WorkerState.SUSPECT
                suspects.append(wid)
            # straggler: alive but its inflight cohort is way over budget
            if (
                w.state is WorkerState.HEALTHY
                and w.inflight_cohort is not None
                and median > 0
                and w.ema_latency > self.straggler_factor * median
            ):
                reissue.append(w.inflight_cohort)
                w.inflight_cohort = None
        return {"dead": dead, "suspect": suspects, "reissue_cohorts": reissue}

    @property
    def healthy_workers(self) -> list[int]:
        return [
            wid
            for wid, w in self.workers.items()
            if w.state is not WorkerState.DEAD
        ]


@dataclasses.dataclass(frozen=True)
class RestartPolicy:
    """How a run resumes after failure (consumed by launch drivers)."""

    max_restarts: int = 100
    checkpoint_every_steps: int = 100
    lose_at_most_steps: int = 100     # == checkpoint_every_steps by default

    def should_restart(self, restart_count: int) -> bool:
        return restart_count < self.max_restarts
