"""Distribution substrate: sharding rules, fault tolerance, elastic, compression."""
