"""Elastic scaling: reshape the mesh without losing state.

All state in this framework is *logically* sharded (PartitionSpecs derived
from the same schema regardless of mesh), so elasticity is: (1) checkpoint
(or keep host copies), (2) build the new mesh, (3) re-place every leaf
with the specs resolved against the new mesh.  Chunk statistics are dense
1-D arrays → any shard count works after ``pad_chunks``.

Constraints checked here (fail fast rather than mis-shard):
  * ``model`` axis size must keep dividing all sharded parameter dims;
  * batch must keep dividing the data-parallel shard count;
  * pods can join/leave freely (pure DP axis).

``ElasticPlan`` captures a target mesh + the validated transfer plan;
``apply`` executes it (device_put with new shardings).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh

from repro.distributed.sharding import ShardingRules, param_shardings
from repro.models.layers import ParamSpec, Schema


def _sharded_dims(schema: Schema, rules: ShardingRules):
    """Yield (path, dim_size, mesh_axis_size) for every sharded param dim."""
    flat, _ = jax.tree_util.tree_flatten_with_path(
        schema, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    for path, spec in flat:
        for size, logical in zip(spec.shape, spec.logical):
            axis = rules.rules.get(logical)
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            n = 1
            for a in axes:
                n *= rules.mesh.shape[a]
            yield "/".join(map(str, path)), size, n


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old_mesh: Optional[Mesh]
    new_mesh: Mesh
    new_rules: ShardingRules
    issues: tuple

    @property
    def feasible(self) -> bool:
        return not self.issues


def plan_resize(
    schema: Schema,
    new_mesh: Mesh,
    *,
    global_batch: Optional[int] = None,
    old_mesh: Optional[Mesh] = None,
) -> ElasticPlan:
    rules = ShardingRules.for_mesh(new_mesh)
    issues = []
    for path, dim, shards in _sharded_dims(schema, rules):
        if dim % shards:
            issues.append(
                f"param {path}: dim {dim} not divisible by {shards} shards"
            )
    if global_batch is not None and global_batch % rules.dp_shards:
        issues.append(
            f"global_batch {global_batch} not divisible by dp={rules.dp_shards}"
        )
    return ElasticPlan(
        old_mesh=old_mesh, new_mesh=new_mesh, new_rules=rules, issues=tuple(issues)
    )


def apply_resize(plan: ElasticPlan, schema: Schema, params) -> object:
    """Re-place params under the new mesh (host-mediated; on a real cluster
    this happens via checkpoint restore on the surviving nodes)."""
    if not plan.feasible:
        raise ValueError(f"infeasible elastic plan: {plan.issues}")
    shardings = param_shardings(schema, plan.new_rules)
    host = jax.tree.map(np.asarray, params)
    return jax.tree.map(jax.device_put, host, shardings)


def resize_chunk_stats(n1, n, frames, new_shards: int):
    """Strip previous padding, then re-pad ExSample chunk statistics for a
    new shard count.

    ``pad_chunks`` appends dummy chunks with the exhausted fill
    ``n1=0, n=1, frames=0`` (so ``n >= frames`` keeps them unsampleable).
    Resizing already-padded stats must first strip that trailing dummy run,
    otherwise padding stacks up across successive resizes (M grows every
    shrink/grow).  Operates on the LAST axis, matching ``pad_chunks`` —
    ``[M]`` stats from the solo sharded driver and ``[Q, M]`` stats from
    the composed multi-query driver both resize with one fill contract (a
    multi-query chunk column is padding only if it is the fill for EVERY
    query).  This is an eager host-boundary function: inputs are concrete,
    so the data-dependent strip is done in numpy.
    """
    import jax.numpy as jnp

    if new_shards < 1:
        raise ValueError(f"new_shards must be >= 1, got {new_shards}")
    h_n1 = np.asarray(n1)
    h_n = np.asarray(n)
    h_frames = np.asarray(frames)
    dummy = (h_n1 == 0) & (h_n == 1) & (h_frames == 0)
    if dummy.ndim > 1:
        dummy = dummy.all(axis=tuple(range(dummy.ndim - 1)))
    m = h_n1.shape[-1]
    # Length of the trailing all-dummy run (real chunks are never stripped,
    # even if an interior chunk happens to match the fill pattern).
    while m > 0 and dummy[m - 1]:
        m -= 1
    pad = (-m) % new_shards
    f = lambda x, fill: jnp.concatenate(
        [
            jnp.asarray(x[..., :m]),
            jnp.full(x.shape[:-1] + (pad,), fill, x.dtype),
        ],
        axis=-1,
    )
    return f(h_n1, 0), f(h_n, 1), f(h_frames, 0)
