"""Gradient compression for the slow inter-pod links (DESIGN.md §5).

Cross-pod gradient reduction at 398 B params × 2 B (bf16) per step is the
multi-pod bottleneck (DCN links are ~10× slower than in-pod ICI).  We
compress the *pod-axis* all-reduce to int8 with per-block absmax scales
and **error feedback** (residual carried into the next step — Karimireddy
et al., arXiv:1901.09847), which restores convergence to uncompressed
rates for smooth objectives.

In-pod (``data`` axis) reductions stay bf16: ICI is fast and the int8
round-trip would cost more than it saves there.

The compressed all-reduce is expressed with ``shard_map`` + ``psum`` over
the ``pod`` axis only: quantized int8 payloads are summed in int32 (exact
— no overflow for ≤ 2¹⁵ pods), then dequantized with the max of the pod
scales.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


class ErrorFeedback(NamedTuple):
    residual: dict      # same structure/dtype as grads (f32)


def init_error_feedback(grads_like: dict) -> ErrorFeedback:
    return ErrorFeedback(
        residual=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    )


def _quantize(x: jax.Array, block: int):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad)).reshape(-1, block)
    scale = jnp.maximum(jnp.max(jnp.abs(flat), axis=1) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(flat / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array, shape, block: int):
    flat = q.astype(jnp.float32) * scale[:, None]
    n = 1
    for s in shape:
        n *= s
    return flat.reshape(-1)[:n].reshape(shape)


def compressed_psum_leaf(
    g: jax.Array, r: jax.Array, *, axis: str, block: int = 256
):
    """int8+EF psum of one gradient leaf over ``axis`` (inside shard_map).

    Returns (mean gradient f32, new residual).
    """
    # jax.lax.axis_size is absent from older JAX; psum of 1 over the axis
    # is the version-portable spelling of the same quantity.
    if hasattr(jax.lax, "axis_size"):
        npods = jax.lax.axis_size(axis)
    else:
        npods = jax.lax.psum(1, axis)
    x = g.astype(jnp.float32) + r
    q, scale = _quantize(x, block)
    sent = _dequantize(q, scale, x.shape, block)
    new_residual = x - sent                       # error feedback
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis)
    scale_max = jax.lax.pmax(scale, axis)
    # conservative decode: sum of per-pod values ≤ sum |q| × max scale;
    # exact when pods share scales, bounded error otherwise (absorbed by EF).
    total = _dequantize(
        jnp.clip(q_sum, -127 * npods, 127 * npods).astype(jnp.int32),
        scale_max,
        x.shape,
        block,
    )
    return total / npods, new_residual


def make_cross_pod_allreduce(mesh: Mesh, *, compress: bool, block: int = 256):
    """Returns fn(grads, ef) -> (mean grads over pod axis, ef').

    When the mesh has no ``pod`` axis or compress=False, reduces in bf16
    (identity if no pod axis: GSPMD already reduced over data shards).
    """
    if "pod" not in mesh.axis_names:
        return lambda grads, ef: (grads, ef)

    from repro.core.distributed import get_shard_map

    shard_map = get_shard_map()

    if not compress:
        def plain(grads, ef):
            f = shard_map(
                lambda g: jax.tree.map(
                    lambda x: jax.lax.pmean(x, "pod"), g
                ),
                mesh=mesh,
                in_specs=(P(),),
                out_specs=P(),
                check_rep=False,
            )
            return f(grads), ef
        return plain

    def compressed(grads, ef: ErrorFeedback):
        def body(g_tree, r_tree):
            outs = jax.tree.map(
                lambda g, r: compressed_psum_leaf(g, r, axis="pod", block=block),
                g_tree,
                r_tree,
            )
            means = jax.tree.map(lambda t: t[0], outs, is_leaf=lambda x: isinstance(x, tuple))
            resid = jax.tree.map(lambda t: t[1], outs, is_leaf=lambda x: isinstance(x, tuple))
            return means, resid

        f = shard_map(
            body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
            check_rep=False,
        )
        means, resid = f(grads, ef.residual)
        return means, ErrorFeedback(residual=resid)

    return compressed
