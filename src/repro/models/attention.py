"""Attention: blocked-causal (train/prefill) and partial-softmax decode.

Memory-bounded by construction: scores are only ever materialized for one
(block_q × block_kv) tile (f32), with online-softmax accumulators carried
across KV tiles — the standard flash-attention recurrence expressed in
plain JAX so that (a) the XLA dry-run's temp memory stays bounded at any
sequence length and (b) it doubles as the oracle for the Pallas kernel
(``repro.kernels.flash_attention``).

Two loop encodings, same math:
  * ``unroll=True``  — Python loops → fully unrolled HLO.  Used by the
    dry-run so ``cost_analysis`` sees every FLOP (XLA counts ``while``
    bodies once), and enabling *static* causal block skipping
    (``causal_skip``): KV tiles strictly above the diagonal are never
    emitted, halving attention FLOPs vs. masked-full.
  * ``unroll=False`` — ``lax.scan`` over tiles → compact HLO for runtime.

Decode (``decode_attention``) evaluates one query against a long KV cache
with a split-softmax that is *sharding-oblivious*: reductions over the KV
sequence axis lower to psums when that axis is sharded over ``model``
(flash-decoding; see DESIGN.md §5).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def repeat_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """GQA: expand kv heads to match q heads (B,S,KV,D) → (B,S,H,D).

    Done *before* sharding so q/k/v all shard head-wise over ``model``.
    """
    kv = k.shape[2]
    if kv == num_heads:
        return k
    return jnp.repeat(k, num_heads // kv, axis=2)


def _attn_tile(q, k, v, mask, scale, *, probs_dtype=jnp.float32):
    """One (bq × bk) tile: returns (scores_max, exp_scores, pv) in f32.

    probs_dtype=bf16 halves the probability-matrix HBM traffic (the tile's
    dominant tensor) at <1e-3 output error — accumulation stays f32 via
    preferred_element_type (standard flash-attention practice).
    """
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)                                 # [B,H,Q]
    p = jnp.exp(s - m[..., None]).astype(probs_dtype)
    pv = jnp.einsum(
        "bhqk,bkhd->bqhd", p, v.astype(probs_dtype),
        preferred_element_type=jnp.float32,
    )
    return m, jnp.sum(p, axis=-1, dtype=jnp.float32), pv


def _merge(acc, m_new, l_new, pv_new):
    """Online-softmax merge of a new tile into (m, l, o) accumulators."""
    m, l, o = acc
    m2 = jnp.maximum(m, m_new)
    c1 = jnp.exp(m - m2)
    c2 = jnp.exp(m_new - m2)
    l2 = l * c1 + l_new * c2
    o2 = o * c1.transpose(0, 2, 1)[..., None] + pv_new * c2.transpose(0, 2, 1)[..., None]
    return m2, l2, o2


def blocked_attention(
    q: jax.Array,                      # [B, S, H, D]
    k: jax.Array,                      # [B, T, H, D]  (kv already repeated)
    v: jax.Array,                      # [B, T, H, D]
    *,
    causal: bool = True,
    block_q: int = 2048,
    block_kv: int = 2048,
    causal_skip: bool = True,
    unroll: bool = False,
    q_offset: int = 0,                 # global position of q[0] (chunked prefill)
    probs_dtype=jnp.float32,
) -> jax.Array:
    """Flash-style blocked attention.  Returns [B, S, H, D] in q.dtype."""
    b, s, h, d = q.shape
    t = k.shape[1]
    scale = 1.0 / math.sqrt(d)
    bq = min(block_q, s)
    bk = min(block_kv, t)
    assert s % bq == 0 and t % bk == 0, (s, bq, t, bk)
    nq, nk = s // bq, t // bk

    q_pos = jnp.arange(s) + q_offset
    k_pos = jnp.arange(t)

    def kv_tile_mask(qi: int, ki: int):
        """Causal mask for tile (qi, ki); None if tile is fully visible."""
        if not causal:
            return None
        lo_q = qi * bq + q_offset
        hi_k = (ki + 1) * bk - 1
        if lo_q >= hi_k:              # tile fully below diagonal
            return None
        qp = q_pos[qi * bq : (qi + 1) * bq]
        kp = k_pos[ki * bk : (ki + 1) * bk]
        return qp[None, None, :, None] >= kp[None, None, None, :]

    def tile_needed(qi: int, ki: int) -> bool:
        if not causal or not causal_skip:
            return True
        return ki * bk <= qi * bq + q_offset + bq - 1

    if unroll:
        # tile-level rematerialization: the O(bq×bk) probability matrix is
        # recomputed inside each tile's backward, so the bwd peak is O(one
        # tile), not O(S²/heads) — the flash-attention memory property,
        # enforced via jax.checkpoint around the tile body.
        def tile_body(acc, qb, kb, vb, mask):
            return _merge(
                acc, *_attn_tile(qb, kb, vb, mask, scale, probs_dtype=probs_dtype)
            )

        tile_ckpt = jax.checkpoint(tile_body, static_argnums=())
        outs = []
        for qi in range(nq):
            qb = q[:, qi * bq : (qi + 1) * bq]
            m = jnp.full((b, h, bq), NEG_INF, jnp.float32)
            l = jnp.zeros((b, h, bq), jnp.float32)
            o = jnp.zeros((b, bq, h, d), jnp.float32)
            acc = (m, l, o)
            for ki in range(nk):
                if not tile_needed(qi, ki):
                    continue
                kb = k[:, ki * bk : (ki + 1) * bk]
                vb = v[:, ki * bk : (ki + 1) * bk]
                acc = tile_ckpt(acc, qb, kb, vb, kv_tile_mask(qi, ki))
            m, l, o = acc
            outs.append(o / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None])
        return jnp.concatenate(outs, axis=1).astype(q.dtype)

    # scan encoding: outer scan over q tiles, inner scan over kv tiles with
    # dynamic masking (no block skipping — runtime path trades FLOPs for
    # compact HLO; the Pallas kernel recovers the skip on TPU).
    kr = k.reshape(b, nk, bk, h, d)
    vr = v.reshape(b, nk, bk, h, d)
    qr = q.reshape(b, nq, bq, h, d)

    def q_step(_, qi):
        qb = qr[:, qi]
        q_lo = qi * bq + q_offset

        def kv_step(acc, ki):
            kb = kr[:, ki]
            vb = vr[:, ki]
            if causal:
                qp = q_lo + jnp.arange(bq)
                kp = ki * bk + jnp.arange(bk)
                mask = qp[None, None, :, None] >= kp[None, None, None, :]
            else:
                mask = None
            return _merge(
                acc, *_attn_tile(qb, kb, vb, mask, scale, probs_dtype=probs_dtype)
            ), None

        acc0 = (
            jnp.full((b, h, bq), NEG_INF, jnp.float32),
            jnp.zeros((b, h, bq), jnp.float32),
            jnp.zeros((b, bq, h, d), jnp.float32),
        )
        (m, l, o), _ = jax.lax.scan(kv_step, acc0, jnp.arange(nk))
        out = o / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
        return None, out.astype(q.dtype)

    _, tiles = jax.lax.scan(q_step, None, jnp.arange(nq))   # [nq, B, bq, H, D]
    return jnp.moveaxis(tiles, 0, 1).reshape(b, s, h, d)


def decode_attention(
    q: jax.Array,                      # [B, 1, H, D]
    k_cache: jax.Array,                # [B, T, KV, D]
    v_cache: jax.Array,                # [B, T, KV, D]
    *,
    cache_len: Optional[jax.Array] = None,
) -> jax.Array:
    """One-token attention against a (possibly sequence-sharded) KV cache.

    All KV-axis reductions are expressed as plain jnp reductions so GSPMD
    lowers them to (max, sum) psums over ``model`` when T is sharded —
    the flash-decoding combine.  GQA via reshape, no repeat: q grouped as
    (B, KV, G, D) so memory traffic over the cache is O(T·KV·D).
    """
    b, one, h, d = q.shape
    t, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, kv, g, d)
    s = jnp.einsum(
        "bkgd,btkd->bkgt", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale                                                # [B,KV,G,T]
    if cache_len is not None:
        pos = jnp.arange(t)
        s = jnp.where(pos[None, None, None, :] < cache_len[:, None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum(
        "bkgt,btkd->bkgd", p / jnp.maximum(l, 1e-30), v_cache.astype(jnp.float32)
    )
    return o.reshape(b, 1, h, d).astype(q.dtype)


def attention_flops(
    tokens: int, kv_len: int, heads: int, head_dim: int, *, causal: bool
) -> float:
    """Analytic attention FLOPs (qk + pv), causal-optimal when causal."""
    full = 2.0 * tokens * kv_len * heads * head_dim * 2.0
    return full / 2.0 if causal else full
