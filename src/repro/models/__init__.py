"""Model zoo: unified backbone + detection heads for all assigned archs."""
