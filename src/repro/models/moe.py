"""Mixture-of-Experts block: grouped top-k routing with capacity dispatch.

Distribution model (DESIGN.md §5): tokens are pre-grouped into G groups
(G = number of data shards, supplied by the launcher) so every dispatch
cumsum/gather/scatter is *group-local* — no cross-shard index math.  Expert
weights live on the ``model`` axis (expert parallelism); activations enter
replicated over ``model``, each shard routes redundantly (deterministic,
cheap: T·E f32 matmul) and computes only its local experts; the combine
scatter-add carries a psum over ``model`` inserted by GSPMD.  Collective
traffic per MoE layer is therefore one bf16 psum of the token activations —
identical shape to a TP FFN combine, no all-to-all required.

Capacity semantics follow GShard/Switch: per-group per-expert capacity
C = ceil(T_g · top_k / E · capacity_factor); overflowing tokens are dropped
from that expert (combine weight 0), underflow slots are masked.  The
router runs in f32 regardless of activation dtype.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.distributed.sharding import shard_hint
from repro.models.layers import ParamSpec, Schema, apply_mlp


def moe_schema(d_model: int, cfg: MoEConfig, mlp_kind: str) -> Schema:
    e, f = cfg.num_experts, cfg.d_ff
    schema: Schema = {
        "router": ParamSpec((d_model, e), ("embed", None), scale=0.1),
    }
    # 2-D weight sharding: experts over ``model`` (EP) + FFN width over
    # ``data`` (FSDP/ZeRO-3 gather-on-use) — expert weights are too large
    # for a single mesh axis on the ≥100B MoEs (DESIGN.md §5).
    if mlp_kind in ("swiglu", "geglu"):
        schema.update(
            w_gate=ParamSpec((e, d_model, f), ("expert", "embed", "expert_ff")),
            w_up=ParamSpec((e, d_model, f), ("expert", "embed", "expert_ff")),
            w_down=ParamSpec((e, f, d_model), ("expert", "expert_ff", "embed")),
        )
    else:
        schema.update(
            w_up=ParamSpec((e, d_model, f), ("expert", "embed", "expert_ff")),
            w_down=ParamSpec((e, f, d_model), ("expert", "expert_ff", "embed")),
        )
    return schema


class MoEStats(NamedTuple):
    aux_loss: jax.Array        # Switch load-balancing loss (scalar)
    dropped_fraction: jax.Array


def capacity(tokens_per_group: int, cfg: MoEConfig) -> int:
    c = math.ceil(tokens_per_group * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(int(c), 1)


def apply_moe(
    params: dict,
    x: jax.Array,                  # [G, T, D] — pre-grouped tokens
    cfg: MoEConfig,
    *,
    mlp_kind: str,
    router_key: jax.Array | None = None,
    token_exchange: bool = False,
) -> tuple[jax.Array, MoEStats]:
    g, t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    c = capacity(t, cfg)

    # ---- routing (f32 accumulation, bf16 operands — no full f32 copy of x)
    logits = jnp.einsum(
        "gtd,de->gte", x, params["router"], preferred_element_type=jnp.float32
    )
    if cfg.router_jitter and router_key is not None:
        logits += cfg.router_jitter * jax.random.normal(router_key, logits.shape)
    probs = jax.nn.softmax(logits, axis=-1)                     # [G,T,E]
    top_p, top_e = jax.lax.top_k(probs, k)                      # [G,T,K]
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # ---- capacity positions (group-local cumsum) ---------------------------
    # flatten (T,K) token-major so earlier tokens win capacity
    flat_e = top_e.reshape(g, t * k)                            # [G,TK]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)         # [G,TK,E]
    pos = jnp.cumsum(onehot, axis=1) - onehot                   # rank within expert
    flat_pos = jnp.sum(pos * onehot, axis=-1)                   # [G,TK]
    keep = flat_pos < c                                         # capacity mask

    # ---- dispatch: gather tokens into [G, E, C, D] -------------------------
    # slot owner: for each (expert, slot) find the source flat index.
    slot_id = flat_e * c + jnp.minimum(flat_pos, c - 1)         # [G,TK]
    slot_id = jnp.where(keep, slot_id, e * c)                   # dropped → pad slot
    src = jnp.full((g, e * c + 1), t * k, jnp.int32)
    src = jax.vmap(lambda s, sl: s.at[sl].set(jnp.arange(t * k, dtype=jnp.int32)))(
        src, slot_id
    )[:, : e * c]                                               # [G,EC]
    token_of_flat = jnp.arange(t * k, dtype=jnp.int32) // k
    src_token = jnp.where(src < t * k, token_of_flat[src], t)   # [G,EC]; t = pad row
    x_pad = jnp.concatenate([x, jnp.zeros((g, 1, d), x.dtype)], axis=1)
    xe = jnp.take_along_axis(
        x_pad, src_token[..., None], axis=1
    ).reshape(g, e, c, d)                                       # [G,E,C,D]
    if token_exchange:
        # EP moves TOKENS: dispatch buffers replicate over `data` so the
        # expert matmuls can keep F data-sharded — weights never gather.
        xe = shard_hint(xe, None, "expert", None, None)
    else:
        xe = shard_hint(xe, "dp", "expert", None, None)

    # ---- expert FFN (batched over G, E; experts sharded over model) --------
    def expert_ffn(xe_):
        hint_h = (
            (lambda t: shard_hint(t, None, "expert", None, "expert_ff"))
            if token_exchange
            else (lambda t: t)
        )
        if mlp_kind in ("swiglu", "geglu"):
            act = jax.nn.silu if mlp_kind == "swiglu" else (
                lambda u: jax.nn.gelu(u, approximate=True)
            )
            h = act(
                hint_h(jnp.einsum("gecd,edf->gecf", xe_, params["w_gate"]))
            ) * hint_h(jnp.einsum("gecd,edf->gecf", xe_, params["w_up"]))
        else:
            h = jax.nn.gelu(
                hint_h(jnp.einsum("gecd,edf->gecf", xe_, params["w_up"])),
                approximate=True,
            )
        return jnp.einsum("gecf,efd->gecd", h, params["w_down"])

    ye = expert_ffn(xe)                                         # [G,E,C,D]

    # ---- combine: per-expert scatter slabs, then reduce over E -------------
    # A single scatter-add with model-sharded updates makes GSPMD all-gather
    # the expert outputs (8 GiB/layer on dbrx); batching the scatter per
    # expert keeps it local to each model shard, and the Σ over the sharded
    # E axis lowers to one psum — the intended TP-style combine.
    w_flat = (top_p.reshape(g, t * k) * keep).astype(ye.dtype)  # [G,TK]
    slot_valid = src < t * k                                    # [G,EC]
    w_slots = jnp.where(
        slot_valid, jnp.take_along_axis(w_flat, jnp.minimum(src, t * k - 1), axis=1), 0.0
    )
    contrib = ye * w_slots.reshape(g, e, c)[..., None]          # [G,E,C,D]
    # combine always runs with G data-sharded: in token-exchange mode the
    # small contrib buffer reshards back (O(C·D) traffic — the "return
    # leg" of the token exchange); replicated (G,E,T,D) slabs would not fit
    contrib = shard_hint(contrib, "dp", "expert", None, None)
    tgt = src_token.reshape(g, e, c)                            # [G,E,C] (t = pad)
    out_e = jnp.zeros((g, e, t + 1, d), ye.dtype)
    out_e = jax.vmap(jax.vmap(lambda o, idx, u: o.at[idx].add(u)))(out_e, tgt, contrib)
    out_e = shard_hint(out_e, "dp", "expert", None, None)
    out = jnp.sum(out_e[:, :, :t], axis=1)                      # psum over model

    # ---- diagnostics --------------------------------------------------------
    frac_per_expert = jnp.mean(
        jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32), axis=(0, 1)
    )
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_per_expert * mean_prob)
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return out.astype(x.dtype), MoEStats(aux_loss=aux, dropped_fraction=dropped)


def moe_flops(tokens: int, d_model: int, cfg: MoEConfig, mlp_kind: str) -> float:
    """Active-expert FLOPs (the MODEL_FLOPS convention: 6·N_active·D uses
    top_k experts per token; capacity padding is HLO overhead, not model
    FLOPs)."""
    mats = 3 if mlp_kind in ("swiglu", "geglu") else 2
    return 2.0 * tokens * cfg.top_k * d_model * cfg.d_ff * mats
