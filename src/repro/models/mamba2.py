"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD algorithm: the sequence is split into chunks of length Q; each
chunk computes a quadratic *intra-chunk* term (the "attention-like" matrix
masked by cumulative decay) plus a linear *inter-chunk* term propagated
through a recurrent chunk state h ∈ [B, H, P, N].  The chunk loop is a
``lax.scan`` at runtime and a Python loop under ``unroll=True`` for the
dry-run (cost-analysis fidelity + per-chunk peak memory, mirroring
``blocked_attention``).

TP: heads shard over ``model`` (in_proj output-sharded, out_proj
row-sharded with a psum); B/C projections use a single group (ngroups=1)
and are replicated — they are O(S·N), negligible.  Decode is a single
O(1) state update per token: the long_500k cell's whole point.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import ParamSpec, Schema


def mamba_dims(d_model: int, cfg: SSMConfig) -> tuple[int, int, int]:
    d_inner = cfg.expand * d_model
    nheads = d_inner // cfg.head_dim
    return d_inner, nheads, cfg.state_dim


def mamba_schema(d_model: int, cfg: SSMConfig) -> Schema:
    """Projections are split per component so each shards cleanly:
    z/x/dt over ``inner`` (TP over SSM heads), B/C replicated (O(S·N))."""
    d_inner, nheads, n = mamba_dims(d_model, cfg)
    return {
        "wz": ParamSpec((d_model, d_inner), ("embed", "inner")),
        "wx": ParamSpec((d_model, d_inner), ("embed", "inner")),
        "wbc": ParamSpec((d_model, 2 * n), ("embed", None)),
        "wdt": ParamSpec((d_model, nheads), ("embed", "inner")),
        "conv_x_w": ParamSpec((cfg.conv_width, d_inner), (None, "inner"), scale=1.0),
        "conv_x_b": ParamSpec((d_inner,), ("inner",), init="zeros"),
        "conv_bc_w": ParamSpec((cfg.conv_width, 2 * n), (None, None), scale=1.0),
        "conv_bc_b": ParamSpec((2 * n,), (None,), init="zeros"),
        "dt_bias": ParamSpec((nheads,), ("inner",), init="zeros"),
        "a_log": ParamSpec((nheads,), ("inner",), init="ones"),
        "d_skip": ParamSpec((nheads,), ("inner",), init="ones"),
        "norm_g": ParamSpec((d_inner,), ("inner",), init="ones"),
        "out_proj": ParamSpec((d_inner, d_model), ("inner", "embed")),
    }


class MambaCache(NamedTuple):
    conv: jax.Array    # [B, W-1, conv_ch] — rolling conv window
    ssm: jax.Array     # [B, H, P, N]      — recurrent state


def init_cache(batch: int, d_model: int, cfg: SSMConfig, dtype) -> MambaCache:
    d_inner, nheads, n = mamba_dims(d_model, cfg)
    return MambaCache(
        conv=jnp.zeros((batch, cfg.conv_width - 1, d_inner + 2 * n), dtype),
        ssm=jnp.zeros((batch, nheads, cfg.head_dim, n), jnp.float32),
    )


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d: x [B,S,C], w [W,C] → [B,S,C]."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(width)
    )
    return jax.nn.silu(out + b[None, None, :])


def _project(params: dict, x: jax.Array):
    """x [B,S,D] → (z, x_ssm, bc, dt) via the split projections."""
    return x @ params["wz"], x @ params["wx"], x @ params["wbc"], x @ params["wdt"]


def _chunk_terms(xh, dth, bmat, cmat, a_log):
    """Per-chunk SSD terms.  xh [B,Q,H,P]; dth [B,Q,H]; bmat/cmat [B,Q,N].

    Returns (y_intra [B,Q,H,P], chunk_state [B,H,P,N], decay_total [B,H],
    decay_out [B,Q,H] — cumulative decay from chunk start to each position).
    """
    a = dth * (-jnp.exp(a_log))[None, None, :]              # [B,Q,H] log-decay ≤ 0
    acs = jnp.cumsum(a, axis=1)                             # inclusive cumsum
    # intra-chunk decay matrix L[t, s] = exp(acs_t - acs_s) for s ≤ t.
    # Mask BEFORE the exp: for s > t, rel is positive and exp overflows —
    # `where(tri, exp(rel), 0)` is forward-safe but leaks inf·0 = NaN into
    # the backward (the classic where-grad trap).
    rel = acs[:, :, None, :] - acs[:, None, :, :]           # [B,Q,Q,H]
    q = xh.shape[1]
    tri = jnp.tril(jnp.ones((q, q), bool))
    rel = jnp.where(tri[None, :, :, None], rel, -jnp.inf)
    l_mat = jnp.exp(rel)
    scores = jnp.einsum("bqn,bsn->bqs", cmat, bmat)[..., None] * l_mat  # [B,Q,Q,H]
    xdt = xh * dth[..., None]                               # [B,Q,H,P]
    y_intra = jnp.einsum("bqsh,bshp->bqhp", scores, xdt)
    # chunk state: sum_s exp(acs_last - acs_s) * B_s ⊗ (x_s dt_s)
    decay_to_end = jnp.exp(acs[:, -1:, :] - acs)            # [B,Q,H]
    state = jnp.einsum("bsh,bsn,bshp->bhpn", decay_to_end, bmat, xdt)
    return y_intra, state, jnp.exp(acs[:, -1]), jnp.exp(acs)


def ssd_scan(
    x: jax.Array,          # [B, S, H, P]  (f32)
    dt: jax.Array,         # [B, S, H]     (f32, post-softplus)
    bmat: jax.Array,       # [B, S, N]
    cmat: jax.Array,       # [B, S, N]
    a_log: jax.Array,      # [H]
    *,
    chunk: int,
    unroll: bool = False,
    h0: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD.  Returns (y [B,S,H,P] f32, final state [B,H,P,N])."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q
    h_state = h0 if h0 is not None else jnp.zeros((b, h, p, n), jnp.float32)

    def one_chunk(h_state, xc, dtc, bc, cc):
        y_intra, state_c, decay_tot, decay_out = _chunk_terms(xc, dtc, bc, cc, a_log)
        # inter-chunk: y_t += C_t · (decay(0→t) * h_in)
        y_inter = jnp.einsum("bqn,bqh,bhpn->bqhp", cc, decay_out, h_state)
        h_next = decay_tot[..., None, None] * h_state + state_c
        return h_next, y_intra + y_inter

    if unroll:
        ys = []
        for c in range(nc):
            sl = slice(c * q, (c + 1) * q)
            h_state, y = one_chunk(h_state, x[:, sl], dt[:, sl], bmat[:, sl], cmat[:, sl])
            ys.append(y)
        return jnp.concatenate(ys, axis=1), h_state

    xr = x.reshape(b, nc, q, h, p)
    dtr = dt.reshape(b, nc, q, h)
    br = bmat.reshape(b, nc, q, n)
    cr = cmat.reshape(b, nc, q, n)

    def step(hs, c):
        hs2, y = one_chunk(hs, xr[:, c], dtr[:, c], br[:, c], cr[:, c])
        return hs2, y

    h_state, ys = jax.lax.scan(step, h_state, jnp.arange(nc))
    return jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p), h_state


def apply_mamba(
    params: dict,
    x: jax.Array,              # [B, S, D]
    cfg: SSMConfig,
    *,
    unroll: bool = False,
) -> jax.Array:
    """Full Mamba-2 block (train/prefill)."""
    b, s, d = x.shape
    d_inner, nheads, n = mamba_dims(d, cfg)
    z, xc, bc, dt = _project(params, x)
    xc = _causal_conv(xc, params["conv_x_w"], params["conv_x_b"])
    bc = _causal_conv(bc, params["conv_bc_w"], params["conv_bc_b"])
    bmat, cmat = jnp.split(bc, [n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    xh = xc.reshape(b, s, nheads, cfg.head_dim).astype(jnp.float32)
    y, _ = ssd_scan(
        xh, dt, bmat.astype(jnp.float32), cmat.astype(jnp.float32),
        params["a_log"].astype(jnp.float32), chunk=cfg.chunk_len, unroll=unroll,
    )
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    # gated RMSNorm (Mamba-2): norm(y * silu(z))
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (
        yf * jax.lax.rsqrt(jnp.mean(yf * yf, axis=-1, keepdims=True) + 1e-6)
        * params["norm_g"].astype(jnp.float32)
    ).astype(x.dtype)
    return y @ params["out_proj"]


def apply_mamba_decode(
    params: dict,
    x: jax.Array,              # [B, 1, D]
    cache: MambaCache,
    cfg: SSMConfig,
) -> tuple[jax.Array, MambaCache]:
    """Single-token Mamba-2 step with O(1) state."""
    b, _, d = x.shape
    d_inner, nheads, n = mamba_dims(d, cfg)
    z, xc, bc, dt = _project(params, x)
    xbc_new = jnp.concatenate([xc, bc], axis=-1)[:, 0]          # [B, C]
    window = jnp.concatenate([cache.conv, xbc_new[:, None]], axis=1)  # [B, W, C]
    conv_w = jnp.concatenate([params["conv_x_w"], params["conv_bc_w"]], axis=1)
    conv_b = jnp.concatenate([params["conv_x_b"], params["conv_bc_b"]], axis=0)
    conv_out = jnp.einsum("bwc,wc->bc", window, conv_w) + conv_b
    xbc = jax.nn.silu(conv_out)
    xc1, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    dt1 = jax.nn.softplus(
        dt[:, 0].astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )                                                           # [B,H]
    a = jnp.exp(dt1 * (-jnp.exp(params["a_log"]))[None, :])     # [B,H] decay
    xh = xc1.reshape(b, nheads, cfg.head_dim).astype(jnp.float32)
    # h ← a·h + dt·(B ⊗ x)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt1, bmat.astype(jnp.float32), xh)
    h_new = a[..., None, None] * cache.ssm + upd
    y = jnp.einsum("bn,bhpn->bhp", cmat.astype(jnp.float32), h_new)
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (
        yf * jax.lax.rsqrt(jnp.mean(yf * yf, axis=-1, keepdims=True) + 1e-6)
        * params["norm_g"].astype(jnp.float32)
    ).astype(x.dtype)
    return y @ params["out_proj"], MambaCache(conv=window[:, 1:], ssm=h_new)


def mamba_flops(tokens: int, d_model: int, cfg: SSMConfig) -> float:
    """Analytic FLOPs per token span (projections + SSD terms)."""
    d_inner, nheads, n = mamba_dims(d_model, cfg)
    proj = 2.0 * tokens * d_model * (2 * d_inner + 2 * n + nheads)
    out = 2.0 * tokens * d_inner * d_model
    q = cfg.chunk_len
    intra = 2.0 * tokens * q * (n + nheads * cfg.head_dim)   # scores + apply
    inter = 4.0 * tokens * n * d_inner                        # state build + read
    return proj + out + intra + inter
