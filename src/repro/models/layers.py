"""Shared neural building blocks + the parameter-schema system.

Params are plain nested dicts of jnp arrays.  Every leaf is declared once
via ``ParamSpec`` (shape, init, logical axes); ``materialize`` turns a
schema into initialized params and ``logical_to_pspec`` turns the same
schema into a ``PartitionSpec`` tree — a single source of truth for both,
so sharding can never drift from the parameter layout.

Logical axis names (mapped to mesh axes in ``repro.distributed.sharding``):
  "embed"   — d_model                (replicated)
  "heads"   — attention head blocks  (→ model)
  "kv"      — kv head blocks         (→ model when divisible else None)
  "mlp"     — FFN hidden             (→ model)
  "vocab"   — vocabulary             (→ model)
  "expert"  — MoE expert             (→ model)
  "inner"   — SSM inner channels     (→ model)
  None      — replicated
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    logical: tuple[Optional[str], ...]
    init: str = "normal"           # normal | zeros | ones | embed_normal
    scale: float = 1.0

    def materialize(self, key: jax.Array, dtype) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        fan_in = self.shape[0] if len(self.shape) > 1 else max(self.shape[0], 1)
        if self.init == "embed_normal":
            # tied unembedding: rows ~ N(0, 1/d) keep init logits O(1)
            std = 1.0 / math.sqrt(self.shape[-1])
        else:
            std = self.scale / math.sqrt(fan_in)
        return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(dtype)


Schema = dict[str, Any]  # nested dict of ParamSpec


def materialize(schema: Schema, key: jax.Array, dtype) -> dict:
    """Initialize all params of a schema (deterministic per-path keys)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        schema, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    leaves = []
    for path, spec in flat:
        path_str = "/".join(str(p) for p in path)
        k = jax.random.fold_in(key, hash(path_str) % (2**31))
        leaves.append(spec.materialize(k, dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def abstract_params(schema: Schema, dtype) -> dict:
    """ShapeDtypeStruct tree (for dry-run lowering without allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        schema,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def count_params(schema: Schema) -> int:
    leaves = jax.tree.leaves(schema, is_leaf=lambda x: isinstance(x, ParamSpec))
    return sum(int(np_prod(s.shape)) for s in leaves)


def np_prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


# --------------------------------------------------------------------------
# normalization
# --------------------------------------------------------------------------

def rmsnorm(x: jax.Array, gamma: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with f32 *accumulation* but no full-tensor f32 materialization
    (a full-residual f32 copy per norm dominated backward memory at scale —
    reductions carry the f32, elementwise math stays in x.dtype)."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    inv = jax.lax.rsqrt(ms + eps).astype(x.dtype)
    return x * inv * gamma.astype(x.dtype)


def layernorm(
    x: jax.Array, gamma: jax.Array, beta: jax.Array, *, eps: float = 1e-5
) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True, dtype=jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    var = ms - mu * mu
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    mu = mu.astype(x.dtype)
    return (x - mu) * inv * gamma.astype(x.dtype) + beta.astype(x.dtype)


def norm_schema(cfg_norm: str, d: int) -> Schema:
    if cfg_norm == "rmsnorm":
        return {"gamma": ParamSpec((d,), ("embed",), init="ones")}
    return {
        "gamma": ParamSpec((d,), ("embed",), init="ones"),
        "beta": ParamSpec((d,), ("embed",), init="zeros"),
    }


def apply_norm(cfg_norm: str, p: dict, x: jax.Array) -> jax.Array:
    if cfg_norm == "rmsnorm":
        return rmsnorm(x, p["gamma"])
    return layernorm(x, p["gamma"], p["beta"])


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)  # f32[head_dim/2]


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [..., S, H, D]; positions i32[..., S] (broadcastable).

    cos/sin are cast to x.dtype *before* the product — mixing bf16
    activations with f32 trig tables would promote the whole tensor.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # [...,S,1,D/2]
    cos = jnp.cos(angles).astype(x.dtype)
    sin = jnp.sin(angles).astype(x.dtype)
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# --------------------------------------------------------------------------
# MLP / GLU
# --------------------------------------------------------------------------

def mlp_schema(d_model: int, d_ff: int, kind: str) -> Schema:
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": ParamSpec((d_model, d_ff), ("embed", "mlp")),
            "w_up": ParamSpec((d_model, d_ff), ("embed", "mlp")),
            "w_down": ParamSpec((d_ff, d_model), ("mlp", "embed")),
        }
    return {
        "w_up": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "w_down": ParamSpec((d_ff, d_model), ("mlp", "embed")),
    }


def apply_mlp(p: dict, x: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif kind == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"], approximate=True) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"], approximate=True)
    return h @ p["w_down"]


def mlp_flops(d_model: int, d_ff: int, kind: str, tokens: int) -> float:
    mats = 3 if kind in ("swiglu", "geglu") else 2
    return 2.0 * tokens * d_model * d_ff * mats


# --------------------------------------------------------------------------
# embedding / unembedding
# --------------------------------------------------------------------------

def embed_schema(vocab: int, d_model: int) -> Schema:
    return {"table": ParamSpec((vocab, d_model), ("vocab", "embed"), init="embed_normal")}


def apply_embed(p: dict, tokens: jax.Array, d_model: int) -> jax.Array:
    # gather; under vocab sharding GSPMD emits masked-gather + psum
    return jnp.take(p["table"], tokens, axis=0) * (1.0 / math.sqrt(d_model))


def apply_unembed(p: dict, x: jax.Array) -> jax.Array:
    return x @ p["table"].T
