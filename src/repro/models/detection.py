"""Detection head + surrogate scorer.

``DetectionHead`` maps pooled backbone features of a frame to D detection
slots (box, objectness, class logits, appearance feature) — a light
anchor-free head in the spirit of DETR's box MLP.  It is what makes the
assigned backbones usable as the "expensive detector" in the ExSample loop
(DESIGN.md §2).

``SurrogateScorer`` is the cheap model of the BlazeIt-style baseline: a
two-layer MLP over frame embeddings producing a scalar relevance score.
Its training loop lives in ``repro.train``; its cost accounting in
``repro.sim.costmodel``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, Schema, materialize


class HeadOutput(NamedTuple):
    boxes: jax.Array      # f32[B, D, 4]
    scores: jax.Array     # f32[B, D]   (objectness, post-sigmoid)
    cls_logits: jax.Array # f32[B, D, C]
    feats: jax.Array      # f32[B, D, F]


def head_schema(d_model: int, *, max_dets: int, num_classes: int, feat_dim: int) -> Schema:
    width = 4 + 1 + num_classes + feat_dim
    return {
        "w1": ParamSpec((d_model, 4 * d_model), ("embed", "mlp")),
        "w2": ParamSpec((4 * d_model, max_dets * width), ("mlp", None)),
        "b2": ParamSpec((max_dets * width,), (None,), init="zeros"),
    }


def apply_head(
    p: dict, feats: jax.Array, *, max_dets: int, num_classes: int, feat_dim: int
) -> HeadOutput:
    """feats f32[B, d_model] (pooled backbone features) → detections."""
    h = jax.nn.gelu(feats @ p["w1"], approximate=True)
    out = (h @ p["w2"] + p["b2"]).reshape(
        feats.shape[0], max_dets, 4 + 1 + num_classes + feat_dim
    )
    boxes = jax.nn.sigmoid(out[..., :4])
    scores = jax.nn.sigmoid(out[..., 4])
    cls_logits = out[..., 5 : 5 + num_classes]
    f = out[..., 5 + num_classes :]
    f = f / jnp.maximum(jnp.linalg.norm(f, axis=-1, keepdims=True), 1e-9)
    return HeadOutput(boxes=boxes, scores=scores, cls_logits=cls_logits, feats=f)


def pool_features(hidden: jax.Array) -> jax.Array:
    """Mean-pool sequence features [B, S, D] → [B, D]."""
    return jnp.mean(hidden.astype(jnp.float32), axis=1)


# --------------------------------------------------------------------------
# surrogate (BlazeIt-style specialized model)
# --------------------------------------------------------------------------

def surrogate_schema(embed_dim: int, hidden: int = 128) -> Schema:
    return {
        "w1": ParamSpec((embed_dim, hidden), (None, None)),
        "b1": ParamSpec((hidden,), (None,), init="zeros"),
        "w2": ParamSpec((hidden, hidden), (None, None)),
        "b2": ParamSpec((hidden,), (None,), init="zeros"),
        "w3": ParamSpec((hidden, 1), (None, None)),
        "b3": ParamSpec((1,), (None,), init="zeros"),
    }


def init_surrogate(key: jax.Array, embed_dim: int, hidden: int = 128) -> dict:
    return materialize(surrogate_schema(embed_dim, hidden), key, jnp.float32)


def surrogate_score(p: dict, emb: jax.Array) -> jax.Array:
    """emb f32[..., E] → relevance score f32[...]."""
    h = jax.nn.relu(emb @ p["w1"] + p["b1"])
    h = jax.nn.relu(h @ p["w2"] + p["b2"])
    return (h @ p["w3"] + p["b3"])[..., 0]


def surrogate_loss(p: dict, emb: jax.Array, has_object: jax.Array) -> jax.Array:
    """Binary cross-entropy against 'frame contains ≥1 query object'."""
    logit = surrogate_score(p, emb)
    z = jax.nn.log_sigmoid(logit)
    zc = jax.nn.log_sigmoid(-logit)
    y = has_object.astype(jnp.float32)
    return -jnp.mean(y * z + (1 - y) * zc)


def surrogate_flops(embed_dim: int, hidden: int = 128) -> float:
    return 2.0 * (embed_dim * hidden + hidden * hidden + hidden)
