"""Scan-over-layers forward with stacked parameters (memory-fidelity path).

The dry-run lowers each cell twice (DESIGN.md §6):
  * cost config   — Python-unrolled layers: HloCostAnalysis sees every FLOP.
  * memory config — this module: layers stacked into groups of one pattern
    period and iterated with ``lax.scan`` + per-group ``jax.checkpoint``,
    which forces buffer reuse across layers so ``memory_analysis`` reports
    the *schedulable* peak (XLA:CPU's list scheduler keeps all unrolled
    layers' backward transients live otherwise — measured 13 GiB/layer).

Heterogeneous stacks (jamba's mamba/attn interleave, MoE every k-th layer)
are handled by grouping: the layer-type pattern of every assigned arch is
periodic, so a group of ``pattern_period`` layers is homogeneous across
groups and stacks cleanly.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models.layers import ParamSpec, Schema, apply_norm, apply_unembed
from repro.models.transformer import (
    _decoder_layer,
    _cross_kv,
    _encoder_layer_schema,
    _decoder_layer_schema,
    embed_tokens,
    embed_vlm,
    encoder_forward,
)
from repro.distributed.sharding import shard_hint


def pattern_period(cfg: ModelConfig) -> int:
    """Smallest p such that layer schemas repeat with period p."""
    p = 1
    if cfg.attn_every_k > 1:
        p = cfg.attn_every_k
    if cfg.moe is not None and cfg.moe.every_k_layers > 1:
        p = math.lcm(p, cfg.moe.every_k_layers)
    return p


def stack_schema(cfg: ModelConfig) -> tuple[Schema, int, int]:
    """Returns (schema, group_size, num_groups).  Layer params live under
    ``groups/pos_<j>`` with a leading (num_groups,) stack dim."""
    gs = pattern_period(cfg)
    assert cfg.num_layers % gs == 0, (cfg.num_layers, gs)
    ng = cfg.num_layers // gs

    def stack(spec: ParamSpec) -> ParamSpec:
        return ParamSpec(
            (ng,) + spec.shape, (None,) + spec.logical, init=spec.init,
            scale=spec.scale,
        )

    from repro.models.layers import embed_schema, norm_schema

    s: Schema = {"embed": embed_schema(cfg.vocab, cfg.d_model)}
    if cfg.num_patches and cfg.patch_dim:
        s["patch_proj"] = {
            "w": ParamSpec((cfg.patch_dim, cfg.d_model), (None, "embed")),
            "b": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        }
    s["groups"] = {
        f"pos_{j}": jax.tree.map(
            stack,
            _decoder_layer_schema(cfg, j),
            is_leaf=lambda x: isinstance(x, ParamSpec),
        )
        for j in range(gs)
    }
    s["norm_f"] = norm_schema(cfg.norm, cfg.d_model)
    for i in range(cfg.encoder_layers):
        s[f"enc_{i}"] = _encoder_layer_schema(cfg)
    if cfg.encoder_layers:
        s["enc_norm_f"] = norm_schema(cfg.norm, cfg.d_model)
    return s, gs, ng


def forward_lm_stacked(
    params: dict,
    batch: dict,
    cfg: ModelConfig,
    run: RunConfig,
    *,
    mode: str = "train",
    moe_groups: int = 1,
    last_only: bool = False,
) -> jax.Array:
    """Same semantics as ``forward_lm`` but layers run under lax.scan."""
    if cfg.family == "vlm":
        x = embed_vlm(params, batch["tokens"], batch["patches"], cfg)
    else:
        x = embed_tokens(params, batch["tokens"], cfg)
    cross_out = None
    if cfg.encoder_layers:
        cross_out = encoder_forward(params, batch["frames"], cfg, run)
    positions = jnp.arange(x.shape[1])[None, :]
    gs = pattern_period(cfg)

    def group_body(x, gp):
        # pin the per-iteration parameter slices: without the barrier,
        # XLA:CPU hoists the FSDP all-gather of expert weights above the
        # while loop (gather-then-slice), materializing every layer's
        # gathered weights at once — observed 37 GiB → 6 TiB blowups on
        # the MoE cells.  The barrier keeps gathers loop-variant.
        gp = jax.lax.optimization_barrier(gp)
        for j in range(gs):
            pl = gp[f"pos_{j}"]
            cross_kv = (
                _cross_kv(pl["cross"], cross_out, cfg)
                if cross_out is not None
                else None
            )
            x = _decoder_layer(
                pl, x, cfg, run, j,
                positions=positions, cross_kv=cross_kv,
                moe_groups=moe_groups,
                seq_shard=run.sequence_parallel,
            )
        return x

    body = group_body
    if mode == "train" and run.remat:
        body = jax.checkpoint(group_body)

    def scan_step(x, gp):
        return body(x, gp), None

    x, _ = jax.lax.scan(scan_step, x, params["groups"])
    x = apply_norm(cfg.norm, params["norm_f"], x)
    if last_only:
        x = x[:, -1:]
    x = shard_hint(x, "dp", None, None)
    logits = apply_unembed(params["embed"], x)
    return shard_hint(logits, "dp", None, "vocab")
