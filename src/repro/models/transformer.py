"""Unified backbone covering all assigned architecture families.

One schema/forward pair handles dense (phi3/qwen/granite/gemma), MoE
(dbrx/granite-moe), hybrid (jamba: mamba↔attn interleave + MoE), pure SSM
(mamba2), VLM (phi3-vision: patch-embedding stub frontend) and enc-dec
audio (whisper: frame-embedding stub frontend + cross-attention).

Layer parameters are stored per-layer (``layer_<i>``) and the layer loop
is a Python loop: heterogeneous stacks (hybrid) stay trivial, and XLA's
cost analysis sees every layer (``lax.scan`` bodies are counted once — see
DESIGN.md §6).  Compile cost is bounded because runtime paths only ever
build reduced configs on CPU; full configs exist solely through the
dry-run, which wants the unrolled HLO anyway.

All sharding is expressed through logical ``shard_hint``s — no mesh axis
names appear here.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.distributed.sharding import shard_hint
from repro.models import mamba2
from repro.models.attention import (
    blocked_attention,
    decode_attention,
    repeat_kv,
)
from repro.models.layers import (
    ParamSpec,
    Schema,
    apply_embed,
    apply_mlp,
    apply_norm,
    apply_rope,
    apply_unembed,
    embed_schema,
    materialize,
    mlp_schema,
    norm_schema,
)
from repro.models.moe import apply_moe, moe_schema


# --------------------------------------------------------------------------
# schema
# --------------------------------------------------------------------------

def _attn_schema(cfg: ModelConfig, *, cross: bool = False) -> Schema:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    s: Schema = {
        "wq": ParamSpec((d, h * hd), ("embed", "heads")),
        "wk": ParamSpec((d, kv * hd), ("embed", "kv")),
        "wv": ParamSpec((d, kv * hd), ("embed", "kv")),
        "wo": ParamSpec((h * hd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias and not cross:
        s.update(
            bq=ParamSpec((h * hd,), ("heads",), init="zeros"),
            bk=ParamSpec((kv * hd,), ("kv",), init="zeros"),
            bv=ParamSpec((kv * hd,), ("kv",), init="zeros"),
        )
    return s


def _decoder_layer_schema(cfg: ModelConfig, layer: int) -> Schema:
    s: Schema = {"norm1": norm_schema(cfg.norm, cfg.d_model)}
    if cfg.is_attn_layer(layer):
        s["attn"] = _attn_schema(cfg)
    else:
        s["mamba"] = mamba2.mamba_schema(cfg.d_model, cfg.ssm)
    if cfg.cross_attention:
        s["norm_x"] = norm_schema(cfg.norm, cfg.d_model)
        s["cross"] = _attn_schema(cfg, cross=True)
    if cfg.is_moe_layer(layer):
        s["norm2"] = norm_schema(cfg.norm, cfg.d_model)
        s["moe"] = moe_schema(cfg.d_model, cfg.moe, cfg.mlp)
    elif cfg.d_ff > 0:
        s["norm2"] = norm_schema(cfg.norm, cfg.d_model)
        s["mlp"] = mlp_schema(cfg.d_model, cfg.d_ff, cfg.mlp)
    return s


def _encoder_layer_schema(cfg: ModelConfig) -> Schema:
    return {
        "norm1": norm_schema(cfg.norm, cfg.d_model),
        "attn": _attn_schema(cfg),
        "norm2": norm_schema(cfg.norm, cfg.d_model),
        "mlp": mlp_schema(cfg.d_model, cfg.d_ff, cfg.mlp),
    }


def backbone_schema(cfg: ModelConfig) -> Schema:
    s: Schema = {"embed": embed_schema(cfg.vocab, cfg.d_model)}
    if cfg.num_patches and cfg.patch_dim:
        s["patch_proj"] = {
            "w": ParamSpec((cfg.patch_dim, cfg.d_model), (None, "embed")),
            "b": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        }
    for i in range(cfg.num_layers):
        s[f"layer_{i}"] = _decoder_layer_schema(cfg, i)
    s["norm_f"] = norm_schema(cfg.norm, cfg.d_model)
    for i in range(cfg.encoder_layers):
        s[f"enc_{i}"] = _encoder_layer_schema(cfg)
    if cfg.encoder_layers:
        s["enc_norm_f"] = norm_schema(cfg.norm, cfg.d_model)
    return s


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32) -> dict:
    return materialize(backbone_schema(cfg), key, dtype)


def pad_heads(cfg: ModelConfig, multiple: int) -> ModelConfig:
    """Round head counts up so TP sharding divides (DESIGN.md §5).

    Padded heads are dead weight zero-initialized in ``wo`` rows — outputs
    are exact; the FLOP overhead is reported by the roofline's useful-FLOPs
    ratio.
    """
    def up(x: int) -> int:
        return -(-x // multiple) * multiple

    h = up(cfg.num_heads)
    if h == cfg.num_heads:
        return cfg
    hd = cfg.resolved_head_dim
    kv = cfg.num_kv_heads
    # keep GQA ratio integral: pad kv so h % kv == 0
    while h % kv:
        kv += 1
    return dataclasses.replace(cfg, num_heads=h, num_kv_heads=kv, head_dim=hd)


def pad_vocab(cfg: ModelConfig, multiple: int) -> ModelConfig:
    """Round vocab up so the embedding/logits shard (padded ids unused)."""
    v = -(-cfg.vocab // multiple) * multiple
    return cfg if v == cfg.vocab else dataclasses.replace(cfg, vocab=v)


# --------------------------------------------------------------------------
# sublayers
# --------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array     # [B, T, KV, hd]
    v: jax.Array


def _qkv(p: dict, h_in: jax.Array, cfg: ModelConfig):
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    b, s, _ = h_in.shape
    q = h_in @ p["wq"]
    k = h_in @ p["wk"]
    v = h_in @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (
        q.reshape(b, s, h, hd),
        k.reshape(b, s, kv, hd),
        v.reshape(b, s, kv, hd),
    )


def _self_attention(
    p: dict,
    x_norm: jax.Array,
    cfg: ModelConfig,
    run: RunConfig,
    *,
    causal: bool,
    positions: jax.Array,
) -> jax.Array:
    q, k, v = _qkv(p, x_norm, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    k = repeat_kv(k, cfg.num_heads)
    v = repeat_kv(v, cfg.num_heads)
    # sharding note: q/k/v inherit head sharding from the projection weights
    # (GSPMD propagation); explicit hints here caused reshard thrash when
    # kv_heads < model shards, so only q (always divisible) is pinned.
    q = shard_hint(q, "dp", None, "heads", None)
    import jax.numpy as _jnp

    o = blocked_attention(
        q, k, v,
        causal=causal,
        block_q=run.block_q,
        block_kv=run.block_kv,
        causal_skip=run.causal_block_skip,
        unroll=run.unroll,
        probs_dtype=_jnp.bfloat16 if run.probs_bf16 else _jnp.float32,
    )
    b, s = o.shape[:2]
    return o.reshape(b, s, -1) @ p["wo"]


def _cross_attention(
    p: dict, x_norm: jax.Array, cross_kv: KVCache, cfg: ModelConfig, run: RunConfig
) -> jax.Array:
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    b, s, _ = x_norm.shape
    q = (x_norm @ p["wq"]).reshape(b, s, h, hd)
    k = repeat_kv(cross_kv.k, h)
    v = repeat_kv(cross_kv.v, h)
    o = blocked_attention(
        q, k, v, causal=False,
        block_q=run.block_q, block_kv=run.block_kv, unroll=run.unroll,
    )
    return o.reshape(b, s, -1) @ p["wo"]


def _cross_kv(p: dict, enc_out: jax.Array, cfg: ModelConfig) -> KVCache:
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    b, t, _ = enc_out.shape
    return KVCache(
        k=(enc_out @ p["wk"]).reshape(b, t, kv, hd),
        v=(enc_out @ p["wv"]).reshape(b, t, kv, hd),
    )


def _ffn(pl: dict, x: jax.Array, cfg: ModelConfig, layer: int, run: RunConfig,
         moe_groups: int):
    """Post-mixer feed-forward sublayer (dense MLP or MoE), with residual."""
    if cfg.is_moe_layer(layer):
        h = apply_norm(cfg.norm, pl["norm2"], x)
        b, s, d = h.shape
        g = max(min(moe_groups, b * s), 1)
        tokens = h.reshape(g, (b * s) // g, d)
        tokens = shard_hint(tokens, "dp", None, None)
        y, _stats = apply_moe(
            pl["moe"], tokens, cfg.moe, mlp_kind=cfg.mlp,
            token_exchange=run.moe_token_exchange,
        )
        y = shard_hint(y, "dp", None, None)
        return x + y.reshape(b, s, d)
    if "mlp" in pl:
        h = apply_norm(cfg.norm, pl["norm2"], x)
        return x + apply_mlp(pl["mlp"], h, cfg.mlp)
    return x


def _decoder_layer(
    pl: dict,
    x: jax.Array,
    cfg: ModelConfig,
    run: RunConfig,
    layer: int,
    *,
    positions: jax.Array,
    cross_kv: Optional[KVCache],
    moe_groups: int,
    seq_shard: bool,
) -> jax.Array:
    if seq_shard:
        x = shard_hint(x, "dp", "seq", None)
    h = apply_norm(cfg.norm, pl["norm1"], x)
    if cfg.is_attn_layer(layer):
        x = x + _self_attention(pl["attn"], h, cfg, run, causal=True, positions=positions)
    else:
        x = x + mamba2.apply_mamba(pl["mamba"], h, cfg.ssm, unroll=run.unroll)
    if cross_kv is not None and cfg.cross_attention:
        hx = apply_norm(cfg.norm, pl["norm_x"], x)
        x = x + _cross_attention(pl["cross"], hx, cross_kv, cfg, run)
    x = _ffn(pl, x, cfg, layer, run, moe_groups)
    if seq_shard:
        x = shard_hint(x, "dp", "seq", None)
    return x


# --------------------------------------------------------------------------
# embedding frontends
# --------------------------------------------------------------------------

def embed_tokens(params: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = apply_embed(params["embed"], tokens, cfg.d_model)
    return shard_hint(x, "dp", None, None)


def embed_vlm(
    params: dict, tokens: jax.Array, patches: jax.Array, cfg: ModelConfig
) -> jax.Array:
    """VLM stub frontend: precomputed patch embeddings → linear proj,
    prepended to the token embedding sequence."""
    tok = apply_embed(params["embed"], tokens, cfg.d_model)
    img = patches @ params["patch_proj"]["w"] + params["patch_proj"]["b"]
    x = jnp.concatenate([img.astype(tok.dtype), tok], axis=1)
    return shard_hint(x, "dp", None, None)


# --------------------------------------------------------------------------
# full forward passes
# --------------------------------------------------------------------------

def encoder_forward(
    params: dict, frames: jax.Array, cfg: ModelConfig, run: RunConfig
) -> jax.Array:
    """Bidirectional encoder over stub frame embeddings [B, T, D]."""
    x = shard_hint(frames, "dp", None, None)
    positions = jnp.arange(x.shape[1])[None, :]
    for i in range(cfg.encoder_layers):
        pl = params[f"enc_{i}"]
        h = apply_norm(cfg.norm, pl["norm1"], x)
        x = x + _self_attention(pl["attn"], h, cfg, run, causal=False, positions=positions)
        h = apply_norm(cfg.norm, pl["norm2"], x)
        x = x + apply_mlp(pl["mlp"], h, cfg.mlp)
    return apply_norm(cfg.norm, params["enc_norm_f"], x)


def forward_lm(
    params: dict,
    batch: dict,
    cfg: ModelConfig,
    run: RunConfig,
    *,
    mode: str = "train",          # train | prefill
    moe_groups: int = 1,
    last_only: bool = False,      # unembed only the final position (serving)
) -> jax.Array:
    """Causal LM forward → logits [B, S, V].

    batch keys by family: "tokens" (all), "patches" (vlm),
    "frames" (audio encoder input).
    """
    if cfg.family == "vlm":
        x = embed_vlm(params, batch["tokens"], batch["patches"], cfg)
    else:
        x = embed_tokens(params, batch["tokens"], cfg)

    cross_out = None
    if cfg.encoder_layers:
        cross_out = encoder_forward(params, batch["frames"], cfg, run)

    positions = jnp.arange(x.shape[1])[None, :]
    seq_shard = mode == "train" and run.sequence_parallel

    def layer_fn(pl, x, i, cross_kv):
        return _decoder_layer(
            pl, x, cfg, run, i,
            positions=positions,
            cross_kv=cross_kv,
            moe_groups=moe_groups,
            seq_shard=seq_shard,
        )

    for i in range(cfg.num_layers):
        pl = params[f"layer_{i}"]
        cross_kv = _cross_kv(pl["cross"], cross_out, cfg) if cross_out is not None else None
        if mode == "train" and run.remat:
            x = jax.checkpoint(
                lambda pl_, x_, ck_: layer_fn(pl_, x_, i, ck_),
                static_argnums=(),
            )(pl, x, cross_kv)
        else:
            x = layer_fn(pl, x, i, cross_kv)

    x = apply_norm(cfg.norm, params["norm_f"], x)
    if last_only:
        x = x[:, -1:]              # only the next-token position matters
    x = shard_hint(x, "dp", None, None)
    logits = apply_unembed(params["embed"], x)
    return shard_hint(logits, "dp", None, "vocab")


# --------------------------------------------------------------------------
# decode path (serve_step)
# --------------------------------------------------------------------------

class DecodeCache(NamedTuple):
    """Per-layer caches + current length (uniform across batch)."""

    layers: tuple          # per layer: KVCache | MambaCache | None-cross pairs
    cross: tuple           # per layer: KVCache | None
    pos: jax.Array         # i32[] — tokens already in cache


def init_decode_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype
) -> DecodeCache:
    kv = cfg.num_kv_heads
    hd = cfg.resolved_head_dim if cfg.num_heads else 0
    layers, cross = [], []
    for i in range(cfg.num_layers):
        if cfg.is_attn_layer(i):
            layers.append(
                KVCache(
                    k=jnp.zeros((batch, max_len, kv, hd), dtype),
                    v=jnp.zeros((batch, max_len, kv, hd), dtype),
                )
            )
        else:
            layers.append(mamba2.init_cache(batch, cfg.d_model, cfg.ssm, dtype))
        if cfg.cross_attention:
            cross.append(
                KVCache(
                    k=jnp.zeros((batch, cfg.encoder_len, kv, hd), dtype),
                    v=jnp.zeros((batch, cfg.encoder_len, kv, hd), dtype),
                )
            )
        else:
            cross.append(None)
    return DecodeCache(layers=tuple(layers), cross=tuple(cross),
                       pos=jnp.zeros((), jnp.int32))


def abstract_decode_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    """ShapeDtypeStruct cache for dry-run lowering."""
    return jax.eval_shape(
        lambda: init_decode_cache(cfg, batch, max_len, dtype)
    )


def forward_decode(
    params: dict,
    token: jax.Array,          # i32[B, 1]
    cache: DecodeCache,
    cfg: ModelConfig,
    run: RunConfig,
    *,
    moe_groups: int = 1,
) -> tuple[jax.Array, DecodeCache]:
    """One autoregressive step.  Returns (logits [B, V], updated cache)."""
    b = token.shape[0]
    x = embed_tokens(params, token, cfg)
    pos = cache.pos
    positions = jnp.full((b, 1), pos, jnp.int32)
    new_layers = []
    for i in range(cfg.num_layers):
        pl = params[f"layer_{i}"]
        h = apply_norm(cfg.norm, pl["norm1"], x)
        if cfg.is_attn_layer(i):
            q, k_new, v_new = _qkv(pl["attn"], h, cfg)
            q = apply_rope(q, positions, cfg.rope_theta)
            k_new = apply_rope(k_new, positions, cfg.rope_theta)
            kc: KVCache = cache.layers[i]
            k_cache = jax.lax.dynamic_update_slice(
                kc.k, k_new.astype(kc.k.dtype), (0, pos, 0, 0)
            )
            v_cache = jax.lax.dynamic_update_slice(
                kc.v, v_new.astype(kc.v.dtype), (0, pos, 0, 0)
            )
            k_cache = shard_hint(k_cache, "dp", "seq", None, None)
            v_cache = shard_hint(v_cache, "dp", "seq", None, None)
            o = decode_attention(
                q, k_cache, v_cache,
                cache_len=jnp.full((b,), pos + 1, jnp.int32),
            )
            x = x + o.reshape(b, 1, -1) @ pl["attn"]["wo"]
            new_layers.append(KVCache(k=k_cache, v=v_cache))
        else:
            y, mc = mamba2.apply_mamba_decode(pl["mamba"], h, cache.layers[i], cfg.ssm)
            x = x + y
            new_layers.append(mc)
        if cfg.cross_attention and cache.cross[i] is not None:
            hx = apply_norm(cfg.norm, pl["norm_x"], x)
            ckv = cache.cross[i]
            o = decode_attention(
                (hx @ pl["cross"]["wq"]).reshape(b, 1, cfg.num_heads, cfg.resolved_head_dim),
                ckv.k, ckv.v,
            )
            x = x + o.reshape(b, 1, -1) @ pl["cross"]["wo"]
        x = _ffn(pl, x, cfg, i, run, moe_groups)
    x = apply_norm(cfg.norm, params["norm_f"], x)
    logits = apply_unembed(params["embed"], x)[:, 0]
    logits = shard_hint(logits, "dp", "vocab")
    return logits, DecodeCache(
        layers=tuple(new_layers), cross=cache.cross, pos=pos + 1
    )


# --------------------------------------------------------------------------
# loss
# --------------------------------------------------------------------------

def lm_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy in f32 (vocab axis may be sharded)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
