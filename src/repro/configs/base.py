"""Config dataclasses: model architecture, shapes, run settings.

A ``ModelConfig`` fully determines parameter schema + forward semantics;
``ShapeConfig`` names one of the assigned input-shape cells; ``RunConfig``
carries execution knobs (sharding, remat, dry-run unrolling, kernels).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden width
    capacity_factor: float = 1.25
    every_k_layers: int = 1        # jamba applies MoE every 2nd layer
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_len: int = 1024          # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 ⇒ d_model // num_heads
    mlp: str = "swiglu"            # swiglu | geglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every_k: int = 1          # hybrid: layer l is attention iff (l % k == k-1); 1 ⇒ all attn; 0 ⇒ attn-free
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    cross_attention: bool = False
    encoder_len: int = 1500        # cross-KV length (whisper 30 s @ 50 Hz)
    # multimodal stub frontends
    num_patches: int = 0           # vlm: image patches prepended to the sequence
    patch_dim: int = 0             # vlm: raw patch embedding width (CLIP stub)
    frontend: str = "none"         # none | vision | audio

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid families)."""
        return self.family in ("ssm", "hybrid")

    def is_attn_layer(self, layer: int) -> bool:
        if self.attn_every_k == 0:
            return False
        if self.attn_every_k == 1:
            return True
        return layer % self.attn_every_k == (self.attn_every_k - 1)

    def is_moe_layer(self, layer: int) -> bool:
        return self.moe is not None and layer % self.moe.every_k_layers == (
            self.moe.every_k_layers - 1
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Execution knobs (orthogonal to architecture)."""

    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    # attention blocking
    block_q: int = 2048
    block_kv: int = 2048
    causal_block_skip: bool = True     # triangular block enumeration (perf)
    probs_bf16: bool = False           # bf16 attention probabilities (perf)
    unroll: bool = False               # python-loop layers/blocks (dry-run)
    stacked: bool = False              # scan-over-layers (memory-fidelity)
    # training
    remat: bool = True
    microbatches: int = 1              # gradient-accumulation chunks per step
    fsdp_params: bool = False          # shard weight embed-dims over `data`
    #   (ZeRO-3 gather-on-use: trades per-token TP psums for per-layer
    #    weight gathers — the §Perf lever for collective-bound train cells)
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    adam_8bit: bool = False            # 8-bit optimizer state (big models)
    grad_compression: bool = False     # int8 cross-pod gradient all-reduce
    sequence_parallel: bool = True     # seq-shard residual stream (train)
    # moe
    moe_token_exchange: bool = False   # EP moves tokens, not weights (perf):
    #   dispatch buffers replicate over `data` so expert matmuls keep the
    #   F dim data-sharded — O(C·D) token traffic instead of O(E·D·F)
    #   weight gathers per µbatch (decisive when weights ≫ tokens)
    use_kernels: bool = False          # route hot ops through Pallas kernels

    def dtype(self):
        import jax.numpy as jnp

        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.param_dtype]


def scale_down(cfg: ModelConfig, *, layers: int = 2, d_model: int = 64,
               heads: int = 4, kv_heads: int = 0, d_ff: int = 128,
               vocab: int = 256) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kv = kv_heads or min(cfg.num_kv_heads, heads)
    kv = max(1, min(kv, heads))
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2), d_ff=d_ff,
        )
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(cfg.ssm, state_dim=16, head_dim=16, chunk_len=32)
    return dataclasses.replace(
        cfg,
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=(64 if cfg.head_dim else 0),
        d_ff=d_ff,
        vocab=vocab,
        moe=moe,
        ssm=ssm,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_len=min(cfg.encoder_len, 16),
        num_patches=min(cfg.num_patches, 8),
        patch_dim=min(cfg.patch_dim, 32) if cfg.patch_dim else 0,
    )
