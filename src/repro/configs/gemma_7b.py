"""gemma-7b — GeGLU, head_dim=256 (16 heads × 256 = 4096 ≠ d_model 3072;
o_proj maps back).  [arXiv:2403.08295; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab=256000,
    mlp="geglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)
