"""phi-3-vision-4.2b — phi3-mini backbone + CLIP stub frontend.
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

The modality frontend is a STUB per the assignment: ``input_specs()``
supplies 576 precomputed CLIP patch embeddings (width 1024) which a linear
projector maps into the token sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    mlp="swiglu",
    rope_theta=10_000.0,
    num_patches=576,
    patch_dim=1024,
    frontend="vision",
)
