"""whisper-base — enc-dec audio backbone, conv frontend stubbed.
[arXiv:2212.04356; unverified]

The conv1d stem is a STUB: ``input_specs()`` supplies precomputed frame
embeddings [B, T, 512] fed straight to the 6-layer bidirectional encoder;
the 6-layer decoder cross-attends to the encoder output (cross-KV length
1500 = 30 s at 50 Hz).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    mlp="gelu",
    norm="layernorm",
    rope_theta=10_000.0,
    encoder_layers=6,
    cross_attention=True,
    encoder_len=1500,
    frontend="audio",
)
