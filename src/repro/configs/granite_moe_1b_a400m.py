"""granite-moe-1b-a400m — MoE 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab=49155,
    mlp="swiglu",
    rope_theta=10_000.0,
    moe=MoEConfig(num_experts=32, top_k=8, d_ff=512),
)
