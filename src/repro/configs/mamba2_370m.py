"""mamba2-370m — attention-free SSD (state-space duality).
[arXiv:2405.21060; unverified]

d_inner = 2×1024 = 2048, head_dim 64 ⇒ 32 SSM heads; no FFN sublayer
(d_ff=0 per the assignment).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab=50280,
    mlp="gelu",
    attn_every_k=0,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk_len=1024),
)
