"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887; hf]

attn_every_k=8 realizes the 1:7 attention:mamba ratio (layer 7, 15, ... are
attention).  MoE is applied every 2nd layer per the Jamba paper.
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    mlp="swiglu",
    rope_theta=1_000_000.0,
    attn_every_k=8,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=24576, every_k_layers=2),
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk_len=1024),
)
