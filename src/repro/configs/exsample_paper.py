"""The paper's own evaluation setup (§4), as a selectable config.

Dashcam-scale: 10 h of 30 fps video (1.08 M frames) in variable-length
drives, ≤30-minute chunks; plus the BDD-style variant of 1000 × 40 s clips
(one chunk per clip — the paper's hard case for chunking).
"""
from __future__ import annotations

import dataclasses

from repro.sim.repository import RepoSpec


@dataclasses.dataclass(frozen=True)
class PaperSetup:
    repo: RepoSpec
    result_limits: tuple = (0.1, 0.5, 0.9)   # recall targets (§4.3)
    num_classes: int = 8                      # 8 queries/dataset (§4.3)
    cohorts: int = 50                         # batch size B ≤ 50 (§3.7.1)


def dashcam(seed: int = 0, scale: float = 1.0) -> PaperSetup:
    """~10 h across 8 drives of 20 min – 3 h (scaled)."""
    minutes = [20, 45, 60, 90, 120, 60, 45, 160]
    lengths = [int(m * 60 * 30 * scale) for m in minutes]
    # chunk length scales with the repository so the CHUNK COUNT (~20 for
    # the paper's 10 h dashcam set) is preserved at any scale — the
    # chunk-score skew, not absolute video length, drives the technique
    return PaperSetup(
        repo=RepoSpec(
            video_lengths=lengths,
            num_instances=int(4000 * scale),
            num_classes=8,
            duration_mu=4.5 + (0 if scale >= 1 else -1.0),  # keep p_i scale-free
            duration_sigma=1.6,
            locality=3.0,
            chunk_frames=max(int(54_000 * scale), 1_000),
            seed=seed,
        )
    )


def bdd(seed: int = 0, scale: float = 1.0) -> PaperSetup:
    """1000 × 40 s clips; chunk = clip (short chunks, many of them)."""
    n_clips = int(1000 * scale)
    return PaperSetup(
        repo=RepoSpec(
            video_lengths=[40 * 30] * n_clips,
            num_instances=int(3000 * scale),
            num_classes=8,
            duration_mu=3.5,
            duration_sigma=1.3,
            locality=2.0,
            chunk_frames=40 * 30,
            seed=seed,
        )
    )
