"""Architecture registry: ``get_config(arch_id)`` + shape lookup.

All 10 assigned architectures plus the paper's own evaluation setup.
"""
from __future__ import annotations

from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES_BY_NAME,
    TRAIN_4K,
    ModelConfig,
    MoEConfig,
    RunConfig,
    ShapeConfig,
    SSMConfig,
    scale_down,
)

from repro.configs import (
    dbrx_132b,
    gemma_7b,
    granite_20b,
    granite_moe_1b_a400m,
    jamba_1_5_large_398b,
    mamba2_370m,
    phi3_medium_14b,
    phi3_vision_4_2b,
    qwen2_5_32b,
    whisper_base,
)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        dbrx_132b,
        granite_moe_1b_a400m,
        jamba_1_5_large_398b,
        phi3_medium_14b,
        qwen2_5_32b,
        granite_20b,
        gemma_7b,
        mamba2_370m,
        phi3_vision_4_2b,
        whisper_base,
    )
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[name]


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells.  ``long_500k`` only applies to
    sub-quadratic families unless include_skipped."""
    for arch, cfg in ARCHS.items():
        for shape in ALL_SHAPES:
            skipped = shape.name == "long_500k" and not cfg.sub_quadratic
            if skipped and not include_skipped:
                continue
            yield arch, shape, skipped


__all__ = [
    "ARCHS", "get_config", "get_shape", "cells",
    "ModelConfig", "MoEConfig", "SSMConfig", "RunConfig", "ShapeConfig",
    "ALL_SHAPES", "SHAPES_BY_NAME", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
    "LONG_500K", "scale_down",
]
