"""Declarative search plans — ONE entry point for every driver (DESIGN.md §10).

The paper's contribution is a single adaptive-sampling loop (choose →
sample → detect → match → update, §3); the repo grew five divergent entry
points for it — host loop, device-resident scan, mesh-sharded, multi-query
and async — whose capabilities could not be combined.  Following the
query-plan / execution-strategy split of Focus (Hsieh et al., 2018) and
EKO (Bang et al., 2021), a :class:`SearchPlan` now describes WHAT to
search (queries, predicates via ``select``, result limits, frame budget)
while :class:`Execution` describes HOW to run it (mesh shards, Q-axis
batching, async workers, detection cache, merge schedule).  ``lower()``
validates option compatibility (typed :class:`PlanError`\\ s) and compiles
the plan to ONE device-resident driver — including the composition the
legacy API could not express: Q queries × M-sharded statistics sharing one
deduplicated detector pass per round across the mesh.

    plan = SearchPlan(
        queries=8, result_limit=40, max_steps=8_192, cohorts=8,
        execution=Execution(queries_axis=True, shards=8, cache=-1),
    )
    result = plan.run(carries, chunks, detector=det, select=select)
    result.results, result.traces, result.stats.detector_invocations

Plans are plain data: ``to_dict()``/``from_dict()`` round-trip exactly
(property-tested), so a plan can live in a config file or a CLI flag
(``repro.launch.search --plan '<json>'``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

_STRATEGIES = ("auto", "host", "scan", "sharded", "async")
_METHODS = ("auto", "exact", "wilson_hilferty", "pallas")


class PlanError(ValueError):
    """A :class:`SearchPlan` that cannot be lowered.

    ``field`` names the offending option so tooling can point at it.
    Subclasses: :class:`PlanValueError` (an option invalid on its own),
    :class:`PlanCompatibilityError` (valid options that cannot combine).
    """

    def __init__(self, message: str, *, field: str | None = None):
        super().__init__(message)
        self.field = field


class PlanValueError(PlanError):
    """An option value that is invalid regardless of the rest of the plan."""


class PlanCompatibilityError(PlanError):
    """Individually-valid options that no lowering can combine."""


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Per-tenant service contract riding on an :class:`Execution`
    (DESIGN.md §12) — consumed by :class:`repro.serve.service.SearchService`
    at admission, ignored by every batch lowering.

    * ``slo_latency_s`` — time-to-FIRST-result objective, measured from
      admission onto the driver (0.0 = no SLO; the service reports
      attainment, it never kills a query for missing it).
    * ``priority`` — admission-queue ordering (higher admits first among
      queued plans; FIFO within a priority level).
    * ``queue_on_reject`` — a plan whose projected cost exceeds the
      remaining budget queues for later capacity instead of being
      rejected outright.
    """

    slo_latency_s: float = 0.0
    priority: int = 0
    queue_on_reject: bool = False

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ServiceConfig":
        d = dict(d)
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise PlanValueError(
                f"unknown ServiceConfig option(s) {sorted(unknown)}; valid: "
                f"{sorted(f.name for f in dataclasses.fields(cls))}",
                field=sorted(unknown)[0],
            )
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class IndexSpec:
    """Persistent repository-index binding riding on an :class:`Execution`
    (DESIGN.md §13) — consumed by the executor (and the serving path) to
    open / warm / write back a
    :class:`~repro.index.store.RepositoryIndex`.

    * ``path`` — snapshot directory (auto-loaded when it exists, saved at
      the end of a writable run); ``None`` keeps the index in-memory.
    * ``detector_version`` — the host tier is keyed by
      ``(frame_id, detector_version)``, so a model upgrade is a clean
      miss instead of replaying stale detections.
    * ``read_only`` — consult the index but never publish or save.
    * ``prior_weight`` — how many frames of accumulated past-search
      evidence each chunk's Thompson prior is worth (0.0 = cold start,
      bit-identical to a plan without an index).
    """

    path: Optional[str] = None
    detector_version: str = "v0"
    read_only: bool = False
    prior_weight: float = 0.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "IndexSpec":
        d = dict(d)
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise PlanValueError(
                f"unknown IndexSpec option(s) {sorted(unknown)}; valid: "
                f"{sorted(f.name for f in dataclasses.fields(cls))}",
                field=sorted(unknown)[0],
            )
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class Execution:
    """HOW a plan runs — the execution strategy half of the split.

    * ``strategy`` — ``"auto"`` picks the lowering from the other options
      (DESIGN.md §10 rules); ``"host"``/``"scan"``/``"sharded"``/``"async"``
      force a driver family.
    * ``shards`` — data-axis mesh extent; ``> 1`` selects the mesh-resident
      §8 loop (chunk statistics sharded, delta-psum merge schedule).
    * ``queries_axis`` — the carry has a leading ``[Q]`` axis and the §9
      Q-batched machinery (cross-query dedup, one detector pass per round)
      is used even at Q=1.  Implied by ``SearchPlan.queries > 1``.
    * ``sync_every`` — rounds between sampler/matcher merges on the mesh
      paths (eventual-consistency Thompson, §8).
    * ``async_workers`` — ``> 0`` lowers to the threaded async runtime:
      the single-query :class:`~repro.core.runtime.AsyncSearchDriver`, or
      — composed with the Q axis — the slot-based
      :class:`~repro.core.runtime.AsyncMultiSearchDriver` (DESIGN.md
      §11).  Cannot combine with mesh sharding.
    * ``cache`` — :class:`~repro.serve.batcher.DetectionCache` capacity:
      ``None`` disables, ``-1`` sizes it to the repository at run time,
      positive values trade memory for evictions.  Requires the Q-axis
      machinery (the cache lives on the shared detector pass).
    * ``service`` — optional :class:`ServiceConfig` per-tenant contract
      (SLO / priority / queue-on-reject); only the serving path reads it.
    * ``index`` — optional :class:`IndexSpec` persistent repository-index
      binding (DESIGN.md §13): the executor preloads the detection cache
      from the index, writes fresh detections back at the end of the run
      and warm-starts Thompson alphas by ``prior_weight``.
    """

    strategy: str = "auto"
    shards: int = 1
    axis: str = "data"
    queries_axis: bool = False
    sync_every: int = 1
    async_workers: int = 0
    cache: Optional[int] = None
    service: Optional[ServiceConfig] = None
    index: Optional[IndexSpec] = None

    def __post_init__(self):
        if isinstance(self.service, dict):
            object.__setattr__(
                self, "service", ServiceConfig.from_dict(self.service)
            )
        if isinstance(self.index, dict):
            object.__setattr__(
                self, "index", IndexSpec.from_dict(self.index)
            )

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Execution":
        d = dict(d)
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise PlanValueError(
                f"unknown Execution option(s) {sorted(unknown)}; valid: "
                f"{sorted(f.name for f in dataclasses.fields(cls))}",
                field=sorted(unknown)[0],
            )
        if isinstance(d.get("service"), dict):
            d["service"] = ServiceConfig.from_dict(d["service"])
        if isinstance(d.get("index"), dict):
            d["index"] = IndexSpec.from_dict(d["index"])
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class SearchPlan:
    """WHAT to search: queries × limits × budget, plus the
    :class:`Execution` strategy.  ``lower()`` validates and resolves the
    plan to one driver; ``run()`` executes it and returns a
    :class:`~repro.core.executor.SearchResult`.

    ``result_limit`` is an int (shared by every query) or a tuple with one
    entry per query.  ``method`` is the Thompson sampler — ``"auto"``
    resolves to exact Gamma on host/scan/multi lowerings and to
    Wilson–Hilferty on the mesh-resident paths (which never run the
    rejection sampler, DESIGN.md §3/§8).
    """

    queries: int = 1
    result_limit: Union[int, tuple] = 50
    max_steps: int = 10_000
    cohorts: int = 1
    method: str = "auto"
    trace_every: int = 0
    execution: Execution = dataclasses.field(default_factory=Execution)

    def __post_init__(self):
        if isinstance(self.result_limit, list):
            object.__setattr__(self, "result_limit", tuple(self.result_limit))
        if isinstance(self.execution, dict):
            object.__setattr__(
                self, "execution", Execution.from_dict(self.execution)
            )

    # ---- validation + lowering resolution (DESIGN.md §10) -----------------

    def resolve(self) -> tuple[str, str]:
        """Validate and return ``(kind, method)``: the lowering target (one
        of ``host | scan | async | sharded | multi | multi_sharded |
        async_multi``) and the resolved Thompson method.  Raises typed
        :class:`PlanError`\\ s with actionable messages on invalid or
        incompatible options."""
        ex = self.execution

        # -- per-option value checks ---------------------------------------
        if self.queries < 1:
            raise PlanValueError(
                f"queries={self.queries} must be >= 1 (a plan searches at "
                "least one query)", field="queries")
        if self.max_steps < 1:
            raise PlanValueError(
                f"max_steps={self.max_steps} must be >= 1", field="max_steps")
        if self.cohorts < 1:
            raise PlanValueError(
                f"cohorts={self.cohorts} must be >= 1 (frames chosen per "
                "Thompson round)", field="cohorts")
        if self.trace_every < 0:
            raise PlanValueError(
                f"trace_every={self.trace_every} must be >= 0 (0 disables "
                "recall-trace checkpoints)", field="trace_every")
        if self.method not in _METHODS:
            raise PlanValueError(
                f"method={self.method!r} not in {_METHODS}", field="method")
        if isinstance(self.result_limit, tuple):
            if len(self.result_limit) != self.queries:
                raise PlanValueError(
                    f"result_limit has {len(self.result_limit)} entries for "
                    f"queries={self.queries}; pass one int per query or a "
                    "single shared int", field="result_limit")
            limits = self.result_limit
        else:
            limits = (self.result_limit,)
        if any(int(v) < 1 for v in limits):
            raise PlanValueError(
                f"result_limit={self.result_limit} must be >= 1 per query",
                field="result_limit")
        if ex.strategy not in _STRATEGIES:
            raise PlanValueError(
                f"strategy={ex.strategy!r} not in {_STRATEGIES}",
                field="strategy")
        if ex.shards < 1:
            raise PlanValueError(
                f"shards={ex.shards} must be >= 1", field="shards")
        if not ex.axis:
            raise PlanValueError("axis must be a non-empty mesh axis name",
                                 field="axis")
        if ex.sync_every < 1:
            raise PlanValueError(
                f"sync_every={ex.sync_every} must be >= 1 (a zero-round "
                "merge window would never advance the resident loop)",
                field="sync_every")
        if ex.async_workers < 0:
            raise PlanValueError(
                f"async_workers={ex.async_workers} must be >= 0",
                field="async_workers")
        if ex.cache == 0:
            raise PlanValueError(
                "cache=0 is ambiguous: use cache=None to disable the "
                "detection cache or a positive capacity (-1 = size to the "
                "repository)", field="cache")
        if ex.cache is not None and ex.cache < -1:
            raise PlanValueError(
                f"cache={ex.cache} must be None, -1 (repository-sized) or a "
                "positive capacity", field="cache")
        if ex.service is not None:
            if ex.service.slo_latency_s < 0:
                raise PlanValueError(
                    f"service.slo_latency_s={ex.service.slo_latency_s} must "
                    "be >= 0 (0 disables the SLO)", field="slo_latency_s")
            if not isinstance(ex.service.priority, int):
                raise PlanValueError(
                    f"service.priority={ex.service.priority!r} must be an "
                    "int (admission-queue ordering)", field="priority")
        if ex.index is not None:
            if not ex.index.detector_version or not isinstance(
                ex.index.detector_version, str
            ):
                raise PlanValueError(
                    f"index.detector_version="
                    f"{ex.index.detector_version!r} must be a non-empty "
                    "string (the host tier is keyed by it)",
                    field="detector_version")
            if ex.index.prior_weight < 0:
                raise PlanValueError(
                    f"index.prior_weight={ex.index.prior_weight} must be "
                    ">= 0 (0 disables Thompson warm-start)",
                    field="prior_weight")
            if ex.index.path is not None and not isinstance(
                ex.index.path, str
            ):
                raise PlanValueError(
                    f"index.path={ex.index.path!r} must be a string "
                    "snapshot directory or None (in-memory index)",
                    field="path")

        # -- cross-option compatibility ------------------------------------
        multi = ex.queries_axis or self.queries > 1
        sharded = ex.shards > 1 or ex.strategy == "sharded"
        if self.queries > 1 and ex.strategy in ("host", "scan"):
            raise PlanCompatibilityError(
                f"queries={self.queries} needs the Q-axis drivers; "
                f"strategy={ex.strategy!r} is single-query — use "
                "strategy='auto' (or 'sharded' to compose with a mesh, "
                "or 'async' for the slot scheduler)",
                field="strategy")
        if ex.cache is not None and not multi:
            raise PlanCompatibilityError(
                "cache requires queries_axis=True: the detection cache "
                "lives on the shared Q-axis detector pass (set "
                "Execution(queries_axis=True), valid at queries=1)",
                field="cache")
        if ex.async_workers > 0:
            if ex.shards > 1:
                raise PlanCompatibilityError(
                    f"async_workers={ex.async_workers} with shards="
                    f"{ex.shards}: the threaded async driver and the "
                    "mesh-resident loop are alternative execution "
                    "strategies — pick one (shards>1 already runs "
                    "barrier-free via the §8 merge schedule)",
                    field="async_workers")
            if self.trace_every > 0 and not multi:
                raise PlanCompatibilityError(
                    "async_workers>0 on a single-query carry records no "
                    "recall trace (merges land out of order); set "
                    "trace_every=0, or compose with queries_axis=True — "
                    "the slot scheduler serializes per-query rounds so "
                    "per-query traces are exact (DESIGN.md §11)",
                    field="trace_every")
            if ex.strategy not in ("auto", "async"):
                raise PlanCompatibilityError(
                    f"async_workers={ex.async_workers} conflicts with "
                    f"strategy={ex.strategy!r}", field="strategy")
        if ex.strategy == "async" and ex.async_workers == 0:
            raise PlanCompatibilityError(
                "strategy='async' needs async_workers >= 1",
                field="async_workers")
        if ex.shards > 1 and ex.strategy in ("host", "scan"):
            raise PlanCompatibilityError(
                f"shards={ex.shards} with strategy={ex.strategy!r}: only "
                "the sharded lowerings place statistics on a mesh — use "
                "strategy='auto' or 'sharded'", field="strategy")
        if ex.strategy == "host" and multi:
            raise PlanCompatibilityError(
                "strategy='host' is the single-query reference loop; it "
                "cannot take queries_axis=True or a cache", field="strategy")
        if ex.strategy == "scan" and multi:
            raise PlanCompatibilityError(
                "strategy='scan' is the single-query resident loop; use "
                "strategy='auto' to get the Q-axis lowering",
                field="strategy")
        if ex.sync_every > 1 and not sharded:
            raise PlanCompatibilityError(
                f"sync_every={ex.sync_every} only applies to the mesh "
                "merge schedule; it needs shards>1 (or strategy='sharded')",
                field="sync_every")
        if sharded and self.cohorts % ex.shards:
            raise PlanCompatibilityError(
                f"cohorts={self.cohorts} must be a positive multiple of "
                f"shards={ex.shards} (each shard processes cohorts/shards "
                f"frames per round; try cohorts={ex.shards * max(1, self.cohorts // ex.shards)})",
                field="cohorts")
        if sharded and self.method in ("exact", "pallas"):
            raise PlanCompatibilityError(
                f"method={self.method!r} on a sharded lowering: the "
                "mesh-resident path is Wilson–Hilferty only (DESIGN.md "
                "§3/§8) — use method='auto' or 'wilson_hilferty'",
                field="method")

        # -- lowering kind (DESIGN.md §10 table) ---------------------------
        if ex.async_workers > 0 or ex.strategy == "async":
            kind = "async_multi" if multi else "async"
        elif ex.strategy == "host":
            kind = "host"
        elif sharded and multi:
            kind = "multi_sharded"
        elif sharded:
            kind = "sharded"
        elif multi:
            kind = "multi"
        else:
            kind = "scan"

        if kind in ("async", "async_multi") and self.method not in (
            "auto", "exact"
        ):
            raise PlanCompatibilityError(
                f"method={self.method!r} on the async lowering: cohort "
                "issue uses the exact Gamma sampler — use method='auto'",
                field="method")

        if self.method != "auto":
            method = self.method
        elif kind in ("sharded", "multi_sharded"):
            method = "wilson_hilferty"
        else:
            method = "exact"
        return kind, method

    def lower(self):
        """Validate and compile: returns a
        :class:`~repro.core.executor.LoweredPlan` bound to one driver."""
        from repro.core.executor import lower

        return lower(self)

    def run(self, carry, chunks, *, detector, select=None, mesh=None,
            index=None):
        """``lower()`` + execute.  See
        :meth:`repro.core.executor.LoweredPlan.run`.  ``index`` passes an
        already-open :class:`~repro.index.store.RepositoryIndex` (e.g. a
        service's shared instance) instead of opening one from
        ``execution.index``."""
        return self.lower().run(
            carry, chunks, detector=detector, select=select, mesh=mesh,
            index=index,
        )

    # ---- serde ------------------------------------------------------------

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if isinstance(d["result_limit"], tuple):
            d["result_limit"] = list(d["result_limit"])
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "SearchPlan":
        d = dict(d)
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise PlanValueError(
                f"unknown SearchPlan option(s) {sorted(unknown)}; valid: "
                f"{sorted(f.name for f in dataclasses.fields(cls))}",
                field=sorted(unknown)[0],
            )
        if isinstance(d.get("execution"), dict):
            d["execution"] = Execution.from_dict(d["execution"])
        return cls(**d)
