"""ExSample core: the paper's contribution as a composable JAX module.

Public API re-exports; see DESIGN.md for the paper <-> module map.
"""
from repro.core.state import (
    SamplerState,
    init_state,
    apply_update,
    apply_cross_chunk_decrement,
    merge_states,
    point_estimate,
    DEFAULT_ALPHA0,
    DEFAULT_BETA0,
)
from repro.core.chunks import ChunkIndex, build_chunks, randomplus_frame
from repro.core.thompson import (
    choose_chunks,
    choose_chunks_batched,
    draw_scores,
    gamma_params,
)
from repro.core.matcher import (
    MatcherState,
    ResultLog,
    eviction_mask,
    init_matcher,
    init_matcher_multi,
    match_and_update,
    merge_matcher,
    merge_matcher_checked,
    pairwise_iou,
)
from repro.core.exsample import (
    ExSampleCarry,
    init_carry,
    init_carry_multi,
    stack_carries,
    exsample_step,
    exsample_batch_step,
    run_search,
    run_search_scan,
    run_search_sharded,
    run_search_multi,
)
from repro.core.plan import (
    Execution,
    PlanCompatibilityError,
    PlanError,
    PlanValueError,
    SearchPlan,
)
from repro.core.runtime import (
    AsyncMultiSearchDriver,
    AsyncSearchDriver,
    MatcherRingOverflow,
)
from repro.core.executor import (
    LoweredPlan,
    SearchResult,
    SearchStats,
    lower,
    run_search_multi_sharded,
)

__all__ = [
    "SamplerState", "init_state", "apply_update", "apply_cross_chunk_decrement",
    "merge_states", "point_estimate", "DEFAULT_ALPHA0", "DEFAULT_BETA0",
    "ChunkIndex", "build_chunks", "randomplus_frame",
    "choose_chunks", "choose_chunks_batched", "draw_scores", "gamma_params",
    "MatcherState", "init_matcher", "init_matcher_multi", "match_and_update",
    "merge_matcher", "merge_matcher_checked", "pairwise_iou",
    "ResultLog", "eviction_mask",
    "AsyncSearchDriver", "AsyncMultiSearchDriver", "MatcherRingOverflow",
    "ExSampleCarry", "init_carry", "init_carry_multi", "stack_carries",
    "exsample_step", "exsample_batch_step",
    "run_search", "run_search_scan", "run_search_sharded", "run_search_multi",
    "SearchPlan", "Execution", "PlanError", "PlanValueError",
    "PlanCompatibilityError", "LoweredPlan", "SearchResult", "SearchStats",
    "lower", "run_search_multi_sharded",
]
