"""Detection matcher (paper §2.3, Algorithm 1 line 12).

The matcher decides which detections are *new* results (d₀) and which are
the *second* sighting of a known result (d₁) — the only two quantities the
ExSample update consumes.  Production implementation: a fixed-capacity
result memory of (box, feature, video, frame, times_seen) entries, matched
by IoU in frame-space plus temporal gating (SORT-style) and optional
appearance-feature cosine similarity.

Everything is statically shaped so the whole match-update step jits; the
result memory is a ring buffer of capacity ``max_results``.

The pairwise-IoU inner product is the compute hot spot for crowded scenes
(D × R box pairs) and is backed by the ``repro.kernels.iou_match`` Pallas
kernel; the pure-jnp path here doubles as its reference.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG = -1e9


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MatcherState:
    """Ring-buffer result memory (capacity R)."""

    boxes: jax.Array        # f32[R, 4]  — (x0, y0, x1, y1) of first sighting
    feats: jax.Array        # f32[R, F]  — appearance feature of first sighting
    video: jax.Array        # i32[R]     — video id of first sighting
    frame: jax.Array        # i32[R]     — global frame id of first sighting
    chunk: jax.Array        # i32[R]     — chunk of first sighting (§3.4)
    times_seen: jax.Array   # i32[R]     — 0 = empty slot
    cursor: jax.Array       # i32[]      — ring insert position
    total_inserted: jax.Array  # i32[]   — monotone insertion count (never wraps)
    iou_thresh: float = dataclasses.field(metadata=dict(static=True), default=0.5)
    time_gate: int = dataclasses.field(metadata=dict(static=True), default=900)
    feat_thresh: float = dataclasses.field(metadata=dict(static=True), default=-1.0)

    @property
    def capacity(self) -> int:
        return self.boxes.shape[0]


def init_matcher(
    *,
    max_results: int,
    feat_dim: int = 8,
    iou_thresh: float = 0.5,
    time_gate: int = 900,
    feat_thresh: float = -1.0,
) -> MatcherState:
    return MatcherState(
        boxes=jnp.zeros((max_results, 4), jnp.float32),
        feats=jnp.zeros((max_results, feat_dim), jnp.float32),
        video=jnp.full((max_results,), -1, jnp.int32),
        frame=jnp.full((max_results,), -(10**9), jnp.int32),
        chunk=jnp.full((max_results,), -1, jnp.int32),
        times_seen=jnp.zeros((max_results,), jnp.int32),
        cursor=jnp.zeros((), jnp.int32),
        total_inserted=jnp.zeros((), jnp.int32),
        iou_thresh=iou_thresh,
        time_gate=time_gate,
        feat_thresh=feat_thresh,
    )


def broadcast_leading(tree, num_queries: int):
    """Leading-[Q] broadcast of every array leaf — the shared layout
    transform behind the multi-query carry (DESIGN.md §9); static/aux
    fields pass through untouched."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (num_queries,) + x.shape), tree
    )


def init_matcher_multi(num_queries: int, **kwargs) -> MatcherState:
    """Q independent result memories as ONE pytree with a leading [Q] axis
    on every array leaf — the matcher half of the multi-query carry
    (DESIGN.md §9).  Static thresholds are shared across queries."""
    return broadcast_leading(init_matcher(**kwargs), num_queries)


def pairwise_iou(a: jax.Array, b: jax.Array) -> jax.Array:
    """IoU matrix f32[D, R] for boxes a f32[D,4], b f32[R,4] (x0,y0,x1,y1)."""
    area_a = jnp.maximum(a[:, 2] - a[:, 0], 0.0) * jnp.maximum(a[:, 3] - a[:, 1], 0.0)
    area_b = jnp.maximum(b[:, 2] - b[:, 0], 0.0) * jnp.maximum(b[:, 3] - b[:, 1], 0.0)
    lt = jnp.maximum(a[:, None, :2], b[None, :, :2])
    rb = jnp.minimum(a[:, None, 2:], b[None, :, 2:])
    wh = jnp.maximum(rb - lt, 0.0)
    inter = wh[..., 0] * wh[..., 1]
    union = area_a[:, None] + area_b[None, :] - inter
    return inter / jnp.maximum(union, 1e-9)


class MatchResult(NamedTuple):
    d0: jax.Array           # i32[] — detections matching nothing (new results)
    d1: jax.Array           # i32[] — results transitioning seen-once → seen-twice
    cross_chunk: jax.Array  # i32[] — of d1, how many were first seen elsewhere (§3.4)
    cross_home: jax.Array   # i32[R_pad] — home chunks to decrement (padded, -1 = none)
    is_new: jax.Array       # bool[D] — per-detection novelty flag
    new_state: "MatcherState"


def match_and_update(
    state: MatcherState,
    boxes: jax.Array,       # f32[D, 4]
    feats: jax.Array,       # f32[D, F]
    valid: jax.Array,       # bool[D] — detector emits fixed D slots, some invalid
    video_id: jax.Array,    # i32[]
    frame_id: jax.Array,    # i32[]
    chunk_id: jax.Array,    # i32[]
) -> MatchResult:
    """Match one frame's detections against the result memory and update it.

    Semantics (statically shaped, single frame):
      - a detection *matches* memory entry r iff same video, |Δframe| ≤
        time_gate, IoU ≥ iou_thresh, and (optionally) feature cosine ≥
        feat_thresh.  Ties go to the highest IoU entry.
      - unmatched valid detections are new results → inserted (times_seen=1).
      - matched detections bump times_seen of their entry;  d₁ counts
        entries whose times_seen went exactly 1 → 2 this frame.
    """
    occupied = state.times_seen > 0
    iou = pairwise_iou(boxes, state.boxes)
    same_video = state.video[None, :] == video_id
    in_gate = jnp.abs(state.frame[None, :] - frame_id) <= state.time_gate
    match_ok = iou >= state.iou_thresh
    score_val = iou
    if state.feat_thresh > -1.0:
        # appearance re-identification: long-range duplicates (an object
        # re-seen after drifting across the frame, or across chunks §3.4)
        # can't match by IoU — cosine similarity substitutes for overlap,
        # the role the paper's tracker-based matcher plays.
        an = feats / jnp.maximum(jnp.linalg.norm(feats, axis=-1, keepdims=True), 1e-9)
        bn = state.feats / jnp.maximum(
            jnp.linalg.norm(state.feats, axis=-1, keepdims=True), 1e-9
        )
        sim = an @ bn.T
        match_ok = match_ok | (sim >= state.feat_thresh)
        score_val = jnp.maximum(iou, sim)
    eligible = occupied[None, :] & same_video & in_gate & match_ok
    scores = jnp.where(eligible, score_val, NEG)

    best = jnp.argmax(scores, axis=-1)                       # i32[D]
    has_match = jnp.take_along_axis(scores, best[:, None], axis=-1)[:, 0] > NEG / 2
    has_match = has_match & valid
    is_new = valid & ~has_match

    # --- bump times_seen for matched entries (scatter-add over entries) ---
    bump = jnp.zeros((state.capacity,), jnp.int32).at[best].add(
        has_match.astype(jnp.int32)
    )
    new_seen = state.times_seen + jnp.where(occupied, bump, 0)
    went_twice = occupied & (state.times_seen == 1) & (new_seen >= 2)
    d1 = jnp.sum(went_twice).astype(jnp.int32)
    # §3.4 cross-chunk: entry first seen in another chunk ⇒ its home chunk's
    # N¹ must be decremented instead of this one's.
    crossed = went_twice & (state.chunk != chunk_id)
    cross_chunk = jnp.sum(crossed).astype(jnp.int32)
    cross_home = jnp.where(crossed, state.chunk, -1)

    # --- insert new results into ring buffer slots ---
    d0 = jnp.sum(is_new).astype(jnp.int32)
    num_new = d0
    # Target slots: cursor, cursor+1, ... (ring).  Build per-detection slot
    # ids via exclusive cumsum over is_new.
    order = jnp.cumsum(is_new.astype(jnp.int32)) - is_new.astype(jnp.int32)
    slot = (state.cursor + order) % state.capacity
    slot = jnp.where(is_new, slot, state.capacity)  # dump non-new to OOB pad
    pad = lambda arr, fill: jnp.concatenate([arr, jnp.full((1,) + arr.shape[1:], fill, arr.dtype)], 0)

    boxes_mem = pad(state.boxes, 0.0).at[slot].set(boxes)[:-1]
    feats_mem = pad(state.feats, 0.0).at[slot].set(feats)[:-1]
    video_mem = pad(state.video, -1).at[slot].set(jnp.broadcast_to(video_id, slot.shape))[:-1]
    frame_mem = pad(state.frame, 0).at[slot].set(jnp.broadcast_to(frame_id, slot.shape))[:-1]
    chunk_mem = pad(state.chunk, -1).at[slot].set(jnp.broadcast_to(chunk_id, slot.shape))[:-1]
    seen_mem = pad(new_seen, 0).at[slot].set(1)[:-1]

    new_state = dataclasses.replace(
        state,
        boxes=boxes_mem,
        feats=feats_mem,
        video=video_mem,
        frame=frame_mem,
        chunk=chunk_mem,
        times_seen=seen_mem,
        cursor=(state.cursor + num_new) % state.capacity,
        total_inserted=state.total_inserted + num_new,
    )
    return MatchResult(
        d0=d0,
        d1=d1,
        cross_chunk=cross_chunk,
        cross_home=cross_home,
        is_new=is_new,
        new_state=new_state,
    )


def num_results(state: MatcherState) -> jax.Array:
    return jnp.sum(state.times_seen > 0).astype(jnp.int32)


class MergeStats(NamedTuple):
    """Ring-pressure diagnostics of one ``merge_matcher`` application."""

    inserted: jax.Array   # i32[] — TRUE insertions src made since snap
    overflow: jax.Array   # bool[] — insertions ≥ capacity: the src ring
    #                       wrapped and silently dropped entries, so the
    #                       merge window (a mod-capacity cursor delta)
    #                       aliases and cannot recover them
    clobbered: jax.Array  # i32[] — live dst entries this merge overwrites


def merge_stats(dst: MatcherState, src: MatcherState, snap: MatcherState) -> MergeStats:
    """Ring-wrap guard (ROADMAP): ``merge_matcher`` assumes fewer insertions
    per merge than capacity; the cursor delta it appends from is taken mod
    capacity, so an overflowing worker silently loses ``capacity·k``
    entries.  The monotone ``total_inserted`` counter makes the true
    insertion count observable — callers surface it as a high-water mark
    and raise/flag on overflow instead of wrapping (see
    ``repro.core.runtime.AsyncSearchDriver._merge``)."""
    cap = dst.capacity
    inserted = src.total_inserted - snap.total_inserted
    n_new = inserted % cap
    idx = jnp.arange(cap, dtype=jnp.int32)
    dst_slot_hit = (idx - dst.cursor) % cap < n_new
    clobbered = jnp.sum(dst_slot_hit & (dst.times_seen > 0)).astype(jnp.int32)
    return MergeStats(
        inserted=inserted, overflow=inserted >= cap, clobbered=clobbered
    )


@jax.jit
def merge_matcher_checked(
    dst: MatcherState, src: MatcherState, snap: MatcherState
) -> tuple[MatcherState, MergeStats]:
    """``merge_matcher`` plus its ``MergeStats`` — one fused jitted call."""
    return merge_matcher(dst, src, snap), merge_stats(dst, src, snap)


def eviction_mask(dst: MatcherState, n_new) -> jax.Array:
    """bool[R] — the live ``dst`` entries that appending ``n_new`` fresh
    insertions at ``dst.cursor`` will overwrite (the ring-spill contract,
    DESIGN.md §11).

    This is the append window ``[dst.cursor, dst.cursor + n_new) mod R``
    restricted to occupied slots — exactly the entries
    ``merge_stats.clobbered`` counts.  Callers extract them to a host-side
    :class:`ResultLog` *before* the merge/replacement lands, so a fixed
    device ring supports unbounded result sets with zero loss (as long as
    a single merge window inserts fewer than ``R`` entries; beyond that
    the source ring itself wrapped and the entries are unrecoverable —
    ``MergeStats.overflow``)."""
    cap = dst.capacity
    idx = jnp.arange(cap, dtype=jnp.int32)
    window = (idx - dst.cursor) % cap < jnp.minimum(n_new, cap)
    return window & (dst.times_seen > 0)


class ResultLog:
    """Append-only host-side log of results evicted from a device ring.

    The matcher ring is a *recent window*; entries pushed out by new
    insertions drain here at merge boundaries (``spill``), so the total
    distinct-result set of a long search is ``ring live entries +
    len(log)`` with nothing dropped.  Host-side numpy on purpose: spills
    happen on the driver thread between device calls, and the log never
    re-enters jit."""

    _FIELDS = ("boxes", "feats", "video", "frame", "chunk", "times_seen")

    def __init__(self):
        self._chunks: list[dict] = []
        self.count = 0

    def __len__(self) -> int:
        return self.count

    def spill(self, matcher: MatcherState, mask) -> int:
        """Append ``matcher``'s entries selected by ``mask`` (bool[R]);
        returns how many were spilled."""
        import numpy as np

        mask_np = np.asarray(mask)
        k = int(mask_np.sum())
        if k:
            self._chunks.append({
                f: np.asarray(getattr(matcher, f))[mask_np]
                for f in self._FIELDS
            })
            self.count += k
        return k

    def as_arrays(self) -> dict:
        """The whole log as one dict of concatenated numpy arrays."""
        import numpy as np

        if not self._chunks:
            return {
                "boxes": np.zeros((0, 4), np.float32),
                "feats": np.zeros((0, 0), np.float32),
                "video": np.zeros((0,), np.int32),
                "frame": np.zeros((0,), np.int32),
                "chunk": np.zeros((0,), np.int32),
                "times_seen": np.zeros((0,), np.int32),
            }
        return {
            f: np.concatenate([c[f] for c in self._chunks])
            for f in self._FIELDS
        }


@jax.jit
def merge_matcher(
    dst: MatcherState, src: MatcherState, snap: MatcherState
) -> MatcherState:
    """Merge a worker's matcher ``src`` into the shared ``dst``, where both
    diverged from snapshot ``snap`` (async runtime, DESIGN.md §5).

    Replacement (``dst := src``) is last-writer-wins: with overlapping
    workers it drops every entry a concurrent merge added.  Instead:

      * entries ``src`` INSERTED since the snapshot (the ring slots
        ``[snap.cursor, src.cursor)``) are appended at ``dst.cursor`` —
        no worker's insertions are ever lost;
      * ``times_seen`` bumps to pre-existing entries are merged
        *additively*, applied only where ``dst`` still holds the same
        entry as the snapshot (identified by (video, frame) of first
        sighting) — commutative, and exact in the sequential case.

    Duplicate entries across overlapping workers remain possible (two
    workers can both insert the same object); that is the documented
    at-most-once-*effect* tolerance.  Assumes fewer insertions per merge
    than ``capacity`` (cohort sizes ≪ ring capacity) — violations are
    detectable via ``merge_stats``/``merge_matcher_checked`` (overflow
    flag + high-water insertion count) rather than silently wrapping."""
    cap = dst.capacity
    idx = jnp.arange(cap, dtype=jnp.int32)
    n_new = (src.cursor - snap.cursor) % cap
    src_slot = (snap.cursor + idx) % cap
    valid = idx < n_new
    dst_slot = jnp.where(valid, (dst.cursor + idx) % cap, cap)  # OOB ⇒ drop

    # --- additive seen-count bumps for entries that existed at snapshot ---
    src_inserted = jnp.zeros((cap,), bool).at[src_slot].set(valid, mode="drop")
    same_as_snap = (
        (dst.video == snap.video)
        & (dst.frame == snap.frame)
        & (snap.times_seen > 0)
    )
    bump = jnp.where(
        same_as_snap & ~src_inserted, src.times_seen - snap.times_seen, 0
    )
    times = dst.times_seen + bump

    # --- append src's new entries at dst's cursor --------------------------
    put = lambda d, s: jnp.concatenate(
        [d, jnp.zeros((1,) + d.shape[1:], d.dtype)], 0
    ).at[dst_slot].set(s[src_slot], mode="drop")[:-1]
    return dataclasses.replace(
        dst,
        boxes=put(dst.boxes, src.boxes),
        feats=put(dst.feats, src.feats),
        video=put(dst.video, src.video),
        frame=put(dst.frame, src.frame),
        chunk=put(dst.chunk, src.chunk),
        times_seen=put(times, src.times_seen),
        cursor=(dst.cursor + n_new) % cap,
        total_inserted=dst.total_inserted
        + (src.total_inserted - snap.total_inserted),
    )
