"""SearchPlan lowering + execution (DESIGN.md §10).

``lower(plan)`` resolves a declarative :class:`~repro.core.plan.SearchPlan`
to ONE driver (host | scan | async | sharded | multi | multi_sharded |
async_multi) and ``LoweredPlan.run`` executes it, returning a structured
:class:`SearchResult` — per-query step/results/trace plus uniform
:class:`SearchStats` (detector invocations, cache hit rate, matcher merge
high-water / overflow, async scheduling counters) instead of the raw carry
tuples and ad-hoc stats dicts the legacy ``run_search_*`` entry points
returned.

The module also owns the one lowering the legacy API could not express:
``run_search_multi_sharded`` — the §9 leading-[Q] multi-query carry lifted
into the §8 ``shard_map`` loop, so Q queries AND M-sharded Thompson
statistics share one deduplicated (and per-shard cached) detector pass per
round across the mesh.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import thompson
from repro.core.chunks import ChunkIndex, randomplus_frame
from repro.core.exsample import (
    DetectorFn,
    ExSampleCarry,
    SelectFn,
    _host_search,
    _multi_search,
    _scan_search,
    _sharded_search,
)
from repro.core.matcher import MatcherState, match_and_update, merge_matcher
from repro.core.plan import PlanError, SearchPlan
from repro.core.state import SamplerState


@dataclasses.dataclass(frozen=True)
class SearchStats:
    """Uniform per-run accounting, populated by every lowering (fields a
    lowering cannot observe stay at their zero defaults):

    * ``detector_invocations`` / ``cache_hits`` — detector economics: the
      Q-axis lowerings count unique, uncached frames actually detected;
      single-query lowerings pay one invocation per sampled frame.
    * ``rounds`` — synchronized choose→detect rounds (Q-axis lowerings).
    * ``frames_sampled`` — Σ per-query steps (what sequential runs pay).
    * ``merge_high_water`` / ``merge_overflow`` — matcher ring pressure
      from ``merge_matcher_checked`` semantics: the largest number of
      insertions folded in a single merge window, and whether any window
      reached ring capacity (sharded + composed syncs, async merges).
    * ``merges`` / ``reissues`` / ``duplicate_drops`` — async scheduler
      counters (DESIGN.md §5/§11).
    * ``results_spilled`` — ring-evicted results drained to the host
      :class:`~repro.core.matcher.ResultLog` at merge boundaries (the
      async lowerings' spill contract, DESIGN.md §11).
    * ``matcher_inserted`` / ``matcher_capacity`` — final ring totals.
    * ``index_hits`` / ``persisted_detections`` / ``warm_rounds_saved`` —
      repository-index economics (DESIGN.md §13): cache hits served by
      the index preload (detector calls a PAST search paid for — a subset
      of ``cache_hits``), fresh detections persisted into the index at
      the end of the run, and the rounds of cold-start exploration the
      Thompson warm-start priors replaced.
    """

    detector_invocations: int = 0
    cache_hits: int = 0
    rounds: int = 0
    frames_sampled: int = 0
    merge_high_water: int = 0
    merge_overflow: bool = False
    merges: int = 0
    reissues: int = 0
    duplicate_drops: int = 0
    results_spilled: int = 0
    matcher_inserted: int = 0
    matcher_capacity: int = 0
    index_hits: int = 0
    persisted_detections: int = 0
    warm_rounds_saved: int = 0

    @property
    def cache_hit_rate(self) -> float:
        """Hits over cache lookups (hits + fresh detector invocations)."""
        total = self.cache_hits + self.detector_invocations
        return self.cache_hits / total if total else 0.0

    @property
    def amortization(self) -> float:
        """Frames sampled per detector invocation — the Q-axis sharing win."""
        return self.frames_sampled / max(self.detector_invocations, 1)


@dataclasses.dataclass(frozen=True)
class SearchResult:
    """Structured outcome of ``SearchPlan.run``: the final carry plus
    per-query counters/traces and uniform :class:`SearchStats`."""

    carry: ExSampleCarry
    steps: tuple
    results: tuple
    traces: list
    stats: SearchStats
    plan: SearchPlan
    kind: str

    @property
    def num_queries(self) -> int:
        return len(self.steps)

    @property
    def trace(self):
        """Single-query convenience view of ``traces``."""
        return self.traces[0]


def lower(plan: SearchPlan) -> "LoweredPlan":
    """Validate ``plan`` and bind it to one driver (DESIGN.md §10)."""
    kind, method = plan.resolve()
    return LoweredPlan(plan=plan, kind=kind, method=method)


def tenant_stats_from_row(row) -> SearchStats:
    """Uniform per-tenant accounting for the serving path (DESIGN.md §12):
    package one Q-axis row (an ``AsyncMultiSearchDriver`` ``_QueryRow``,
    live or vacated) into the same :class:`SearchStats` every batch
    lowering returns, so a tenant's view of its own query reads identically
    to a solo run's stats.  Detector economics are attributed by dedup
    representative — frames a tenant's lane shared with another tenant's
    batch slot ride for free and appear in neither counter."""
    return SearchStats(
        detector_invocations=int(row.fresh_calls),
        cache_hits=int(row.cache_hits),
        rounds=int(row.rounds),
        frames_sampled=int(np.asarray(row.carry.step)),
        results_spilled=len(row.log),
        index_hits=int(getattr(row, "index_hits", 0)),
        warm_rounds_saved=int(getattr(row, "warm_rounds_saved", 0)),
        **_matcher_totals(row.carry),
    )


def _matcher_totals(carry: ExSampleCarry) -> dict:
    return dict(
        matcher_inserted=int(np.asarray(carry.matcher.total_inserted).sum()),
        matcher_capacity=int(carry.matcher.times_seen.shape[-1]),
    )


@dataclasses.dataclass(frozen=True)
class LoweredPlan:
    """A validated plan bound to one lowering ``kind``; ``run()`` executes
    the compiled driver and packages the :class:`SearchResult`."""

    plan: SearchPlan
    kind: str
    method: str

    def run(
        self,
        carry: ExSampleCarry,
        chunks: ChunkIndex,
        *,
        detector: DetectorFn,
        select: SelectFn | None = None,
        mesh=None,
        index=None,
    ) -> SearchResult:
        p, ex = self.plan, self.plan.execution
        multi = self.kind in ("multi", "multi_sharded", "async_multi")
        ndim = jnp.ndim(carry.step)
        if multi and ndim != 1:
            raise PlanError(
                f"the {self.kind!r} lowering needs a leading-[Q] carry "
                "(init_carry_multi / stack_carries); got a single-query "
                "carry", field="queries")
        if multi and int(carry.step.shape[0]) != p.queries:
            raise PlanError(
                f"carry has {int(carry.step.shape[0])} queries but the plan "
                f"declares queries={p.queries}", field="queries")
        if not multi and ndim != 0:
            raise PlanError(
                f"the {self.kind!r} lowering is single-query but the carry "
                "has a leading axis; set queries/queries_axis on the plan",
                field="queries")
        if select is not None and not multi:
            raise PlanError(
                "select predicates ride on the shared Q-axis detector pass; "
                "this plan lowers to the single-query "
                f"{self.kind!r} driver", field="queries")
        cache = ex.cache
        if cache == -1:
            cache = chunks.total_frames
        if cache and self.kind == "multi_sharded":
            # hash-sharded placement (DESIGN.md §14) needs the capacity to
            # divide over the mesh; pad BEFORE the index warm so the warm
            # fill and the device layout agree on one modulus
            cache += (-cache) % ex.shards
        if isinstance(p.result_limit, tuple):
            limits = p.result_limit
        else:
            limits = (p.result_limit,) * p.queries
        limit0 = int(limits[0])

        # ---- repository index (DESIGN.md §13): open / version-check /
        # Thompson warm-start / device-cache preload --------------------
        spec = ex.index
        if index is None and spec is not None:
            from repro.index.store import RepositoryIndex

            index = RepositoryIndex.open(spec)
        elif (
            index is not None and spec is not None
            and spec.detector_version != index.detector_version
        ):
            raise PlanError(
                f"plan declares index.detector_version="
                f"{spec.detector_version!r} but the live index holds "
                f"{index.detector_version!r} — a version mismatch must be "
                "a clean miss, not a silent replay", field="detector_version")
        prior_weight = (
            spec.prior_weight if spec is not None
            else (index.prior_weight if index is not None else 0.0)
        )
        warm_rounds_saved = 0
        if index is not None and prior_weight > 0:
            warmed, equiv = index.priors.warm_sampler(
                carry.sampler, None, prior_weight
            )
            if equiv:
                carry = dataclasses.replace(carry, sampler=warmed)
                warm_rounds_saved = int(equiv) // max(p.cohorts, 1)
        if index is not None:
            # evidence base AFTER the warm boost, so recorded deltas never
            # re-count injected priors as fresh evidence
            n1_base = np.asarray(carry.sampler.n1, np.float64)
            n_base = np.asarray(carry.sampler.n, np.float64)
        warm_cache = warm_tag = None
        if index is not None and cache and self.kind in (
            "multi", "multi_sharded"
        ):
            struct = jax.eval_shape(
                detector, jax.random.PRNGKey(0), jnp.zeros((), jnp.int32)
            )
            warm_cache, _warm = index.warm(struct, cache)
            warm_tag = warm_cache.tag

        def finish(out, traces, stats, final_cache=None, index_hits=0):
            """Index write-back tail shared by every lowering branch."""
            if index is not None:
                persisted = 0
                if not index.read_only:
                    persisted = index.publish_cache(final_cache)
                    index.priors.record(
                        None,
                        np.asarray(out.sampler.n1, np.float64) - n1_base,
                        np.asarray(out.sampler.n, np.float64) - n_base,
                    )
                    if index.path is not None:
                        index.save()
                stats = dataclasses.replace(
                    stats,
                    index_hits=int(index_hits),
                    persisted_detections=int(persisted),
                    warm_rounds_saved=warm_rounds_saved,
                )
            return self._package(out, traces, stats)

        if self.kind in ("host", "scan"):
            fn = _host_search if self.kind == "host" else _scan_search
            out, trace = fn(
                carry, chunks, detector=detector, result_limit=limit0,
                max_steps=p.max_steps, cohorts=p.cohorts, method=self.method,
                trace_every=p.trace_every,
            )
            step = int(out.step)
            stats = SearchStats(
                detector_invocations=step, frames_sampled=step,
                **_matcher_totals(out),
            )
            return finish(out, [trace], stats)

        if self.kind == "async":
            from repro.core.runtime import AsyncSearchDriver

            driver = AsyncSearchDriver(
                carry, chunks, detector, cohort_size=p.cohorts,
                num_workers=ex.async_workers, result_limit=limit0,
                max_frames=p.max_steps,
            )
            out = driver.run()
            step = int(out.step)
            stats = SearchStats(
                detector_invocations=step, frames_sampled=step,
                merge_high_water=int(driver.stats["merge_high_water"]),
                merges=int(driver.stats["merges"]),
                reissues=int(driver.stats["reissues"]),
                duplicate_drops=int(driver.stats["duplicate_drops"]),
                results_spilled=int(driver.stats["spilled"]),
                **_matcher_totals(out),
            )
            return finish(out, [[(step, int(out.results))]], stats)

        if self.kind == "async_multi":
            from repro.core.runtime import AsyncMultiSearchDriver

            driver = AsyncMultiSearchDriver(
                carry, chunks, detector, cohorts=p.cohorts,
                num_workers=ex.async_workers,
                result_limits=[int(v) for v in limits],
                max_steps=p.max_steps, method=self.method, select=select,
                cache_frames=cache or 0, trace_every=p.trace_every,
                index=index,
            )
            out = driver.run()
            stats = SearchStats(
                detector_invocations=int(driver.stats["detector_invocations"]),
                cache_hits=int(driver.stats["cache_hits"]),
                rounds=int(driver.stats["rounds"]),
                frames_sampled=int(np.asarray(out.step).sum()),
                merge_high_water=int(driver.stats["merge_high_water"]),
                merges=int(driver.stats["merges"]),
                reissues=int(driver.stats["reissues"]),
                duplicate_drops=int(driver.stats["duplicate_drops"]),
                results_spilled=int(driver.stats["spilled"]),
                **_matcher_totals(out),
            )
            return finish(
                out, driver.traces, stats, final_cache=driver.cache,
                index_hits=int(driver.stats.get("index_hits", 0)),
            )

        if mesh is None:
            if ex.axis != "data":
                raise PlanError(
                    f"axis={ex.axis!r}: only a 'data' mesh can be built "
                    "automatically — pass mesh= with the named axis",
                    field="axis")
            from repro.launch.mesh import make_data_mesh

            mesh = make_data_mesh(ex.shards)
        else:
            shape = dict(mesh.shape)
            if shape.get(ex.axis) != ex.shards:
                raise PlanError(
                    f"mesh axes {shape} do not provide the plan's "
                    f"{ex.shards} {ex.axis!r} shards — the validated "
                    "cohorts/shards geometry must match what executes",
                    field="shards")

        if self.kind == "sharded":
            out, trace, sh = _sharded_search(
                carry, chunks, mesh=mesh, detector=detector,
                result_limit=limit0, max_steps=p.max_steps,
                cohorts=p.cohorts, sync_every=ex.sync_every, axis=ex.axis,
            )
            step = int(out.step)
            stats = SearchStats(
                detector_invocations=step, frames_sampled=step,
                merge_high_water=sh["merge_high_water"],
                merge_overflow=sh["merge_overflow"],
                merges=sh["merges"],
                **_matcher_totals(out),
            )
            return finish(out, [trace], stats)

        limits_arr = jnp.asarray([int(v) for v in limits], jnp.int32)
        if self.kind == "multi":
            out, traces, ms = _multi_search(
                carry, chunks, detector=detector, result_limits=limits_arr,
                max_steps=p.max_steps, cohorts=p.cohorts, method=self.method,
                trace_every=p.trace_every, select=select,
                cache_frames=cache or 0,
                cache=warm_cache, warm_tag=warm_tag,
            )
        else:  # multi_sharded — the composed lowering
            out, traces, ms = run_search_multi_sharded(
                carry, chunks, mesh=mesh, detector=detector, select=select,
                result_limits=limits_arr, max_steps=p.max_steps,
                cohorts=p.cohorts, sync_every=ex.sync_every, axis=ex.axis,
                cache_frames=cache or 0,
                cache=warm_cache, warm_tag=warm_tag,
            )
        stats = SearchStats(
            detector_invocations=ms["detector_invocations"],
            cache_hits=ms["cache_hits"],
            rounds=ms["rounds"],
            frames_sampled=ms["frames_sampled"],
            merge_high_water=ms.get("merge_high_water", 0),
            merge_overflow=ms.get("merge_overflow", False),
            merges=ms.get("merges", 0),
            **_matcher_totals(out),
        )
        return finish(
            out, traces, stats, final_cache=ms.get("final_cache"),
            index_hits=int(ms.get("index_hits", 0)),
        )

    def _package(self, out, traces, stats) -> SearchResult:
        steps = tuple(int(s) for s in np.atleast_1d(np.asarray(out.step)))
        results = tuple(
            int(r) for r in np.atleast_1d(np.asarray(out.results))
        )
        return SearchResult(
            carry=out, steps=steps, results=results, traces=traces,
            stats=stats, plan=self.plan, kind=self.kind,
        )


# ---------------------------------------------------------------------------
# Composed lowering: Q-query carry × M-sharded statistics (DESIGN.md §10)
# ---------------------------------------------------------------------------


@partial(
    jax.jit,
    static_argnames=(
        "mesh", "axis", "detector", "select", "cohorts", "sync_every",
        "max_steps", "alpha0", "beta0",
    ),
)
def _search_multi_sharded_device(
    keys: jax.Array,         # key[Q]
    step0: jax.Array,        # i32[Q]
    results0: jax.Array,     # i32[Q]
    n1: jax.Array,           # f32[Q, M] — sharded over the last axis
    n: jax.Array,            # f32[Q, M] — sharded
    frames: jax.Array,       # i32[Q, M] — sharded
    matcher: MatcherState,   # leaves [Q, ...] — replicated
    chunks: ChunkIndex,      # replicated
    result_limits: jax.Array,  # i32[Q]
    cache,                   # DetectionCache or None — hash-sharded global
    #   layout (shard_cache_layout): leading axes split over the mesh so
    #   each shard holds the 1/S of one logical cache homed on it
    warm_tag,                # i32[cap] index-preload tag snapshot
    #   (direct-mapped layout, replicated), or None
    window_limit: jax.Array,  # i32[] — max sync windows THIS call executes
    #   (INT32_MAX = run to completion; a finite limit returns a fully
    #   resumable state at a sync boundary, the elastic drain point)
    *,
    mesh,
    axis: str,
    detector: DetectorFn,
    select: SelectFn | None,
    cohorts: int,
    sync_every: int,
    max_steps: int,
    alpha0: float,
    beta0: float,
):
    """Mesh-resident multi-query loop: the §9 Q-axis round (per-query
    Thompson choice, cross-query dedup + detection cache, per-query
    scatter-back) composed with the §8 merge schedule (full-width per-query
    delta buffers, one psum per sync, per-query matcher folds with the
    exact k−1 duplicate-d₁ add-back).

    Layout: every statistic of the §9 carry gains the §8 sharding — chunk
    stats ``[Q, M]`` sharded over ``axis``, per-(query, shard) matcher
    replicas of a shared ``[Q]`` snapshot, one full-width ``[Q, M]`` delta
    buffer per shard.  Per round the replicated
    ``local_cohort_winners_batched`` choice hands shard s cohorts
    ``[s·C/S, (s+1)·C/S)`` of EVERY query, whose Q·C/S frames dedup — and
    miss-check the HASH-SHARDED :class:`DetectionCache` (frame f homed on
    shard ``f % S``, DESIGN.md §14; lookups and inserts route over
    ``all_to_all``) — into one detector batch.  Per-query liveness is evaluated at sync boundaries (the §8
    overshoot caveat, per query); a finished query freezes exactly like the
    §9 masking contract (key/step/sampler gated, slots leave the dedup).

    Parity contract (tests/test_plan_parity.py): with a deterministic
    detector, query q's trajectory — (step, results), trace, sampler
    statistics, final key — is bit-identical to its own solo
    ``run_search_sharded`` run on the same mesh with the same key, at ANY
    Q: cross-query dedup and caching change WHICH detector invocations
    happen, never the values a query consumes.
    """
    from repro.core.distributed import (
        get_shard_map,
        local_cohort_winners_batched,
    )
    from repro.serve.batcher import (
        dedup_first_index,
        sharded_cache_insert,
        sharded_cache_lookup,
    )
    from jax.sharding import PartitionSpec as P

    q_n = step0.shape[0]
    num_shards = mesh.shape[axis]
    m = n1.shape[-1]
    local_m = m // num_shards
    per_shard = cohorts // num_shards
    b = q_n * per_shard
    per_sync = cohorts * sync_every
    cap = min(max_steps // max(per_sync, 1) + 3, 4096)
    cap_r = matcher.times_seen.shape[-1]

    def shard_fn(keys, step0, results0, n1_l, n_l, frames_l, matcher0,
                 chks, rlimits, cache0, wtag, wlimit):
        shard_id = jax.lax.axis_index(axis)
        fdt = n_l.dtype
        qi = jnp.arange(q_n, dtype=jnp.int32)
        my_slice = lambda full: jax.lax.dynamic_slice(
            full, (0, shard_id * local_m), (q_n, local_m)
        )

        def live_mask(step, results, n_loc):
            exh_l = jnp.all(
                n_loc >= frames_l.astype(fdt), axis=-1
            ).astype(jnp.int32)                                  # [Q]
            exhausted = jax.lax.psum(exh_l, axis) == num_shards
            return (results < rlimits) & (step < max_steps) & ~exhausted

        def one_round(base_n1, base_n, active, rstate):
            keys, delta_n1, delta_n, foreign, matcher, cache, lstep, lres, \
                lcalls, lhits, lihits = rstate
            ks = jax.vmap(lambda k: jax.random.split(k, 3))(keys)
            key_next, k_choice, k_det = ks[:, 0], ks[:, 1], ks[:, 2]
            # per-query view: authoritative slice + own pending deltas (the
            # §8 staleness model, replicated per query)
            view = SamplerState(
                n1=base_n1 + my_slice(delta_n1),
                n=base_n + my_slice(delta_n),
                frames=frames_l,
                alpha0=alpha0,
                beta0=beta0,
            )
            a_l, b_l = thompson.gamma_params(view)
            c_ids, c_scores, c_n = local_cohort_winners_batched(
                k_choice, a_l, b_l, view.exhausted(), view.n,
                axis=axis, cohorts=cohorts,
            )                                                    # [Q, C]
            # §8 within-window random+ rank dedup, per query: occurrence
            # index within the round plus replicated foreign-pick counts
            live_c = jnp.isfinite(c_scores) & active[:, None]    # [Q, C]
            owner = c_ids // local_m                             # [Q, C]
            pshard = jnp.arange(cohorts, dtype=jnp.int32) // per_shard
            same_before = jnp.tril(
                c_ids[:, :, None] == c_ids[:, None, :], -1
            )                                                    # [Q, C, C]
            occ = jnp.sum(same_before & live_c[:, None, :], axis=-1)
            fgather = jnp.take_along_axis(foreign, c_ids, axis=-1)
            ranks = (
                c_n + fgather.astype(fdt) + occ.astype(fdt)
            ).astype(jnp.int32)                                  # [Q, C]
            foreign = foreign.at[qi[:, None], c_ids].add(
                ((pshard[None, :] != owner) & live_c).astype(jnp.int32)
            )

            # ---- this shard's slots: cohorts [s·C/S, (s+1)·C/S) of every
            # query, deduped + cache-checked into ONE detector batch.  The
            # full [Q, C] frame matrix is computed replicated — winner ids
            # and ranks are replicated, so every shard knows which frames
            # every OTHER shard processes this round, which is what makes
            # the hash-sharded cache routing below collective-cheap ----
            fids_all = randomplus_frame(chks, c_ids, ranks)      # [Q, C]
            g0 = shard_id * per_shard
            slc = lambda a: jax.lax.dynamic_slice(
                a, (0, g0), (q_n, per_shard)
            )
            cids_s, live_s, fids_s = slc(c_ids), slc(live_c), slc(fids_all)
            gidx = g0 + jnp.arange(per_shard, dtype=jnp.int32)
            det_keys = jax.vmap(
                lambda kq: jax.vmap(
                    lambda g: jax.random.fold_in(kq, g)
                )(gidx)
            )(k_det)                                             # [Q, C/S]
            flat_frames = fids_s.reshape(b)
            flat_live = live_s.reshape(b)
            det_keys_flat = det_keys.reshape((b,) + det_keys.shape[2:])
            first_idx = dedup_first_index(flat_frames, flat_live)
            is_rep = (first_idx == jnp.arange(b, dtype=jnp.int32)) & flat_live
            fresh = jax.vmap(detector)(det_keys_flat, flat_frames)
            if cache is not None:
                # Hash-sharded cache routing (DESIGN.md §14): frame f lives
                # ONLY on shard f % S.  Requests are free — the replicated
                # [Q, C] frame matrix lets every home shard compute every
                # requester's probes locally — so one round costs two
                # all_to_alls out (hit flags + values, rows = requesters)
                # and two back in (routed fresh inserts).  Per-link volume
                # matches the all-gathers this replaces, but each shard now
                # stores and scans 1/S of one logical cache instead of a
                # full replica.
                req = jnp.where(live_c, fids_all, -1)            # [Q, C]
                req = req.reshape(q_n, num_shards, per_shard)
                req = req.transpose(1, 0, 2).reshape(num_shards, b)
                r_hit, r_vals = sharded_cache_lookup(
                    cache, req, shard_id, num_shards
                )                                                # [S, b]
                a_hit = jax.lax.all_to_all(r_hit, axis, 0, 0)
                a_vals = jax.tree.map(
                    lambda x: jax.lax.all_to_all(x, axis, 0, 0), r_vals
                )
                # row h of a_* is home shard h's answer for MY b slots
                home = jnp.where(
                    flat_frames >= 0, flat_frames % num_shards, 0
                )
                bi = jnp.arange(b, dtype=jnp.int32)
                hit = a_hit[home, bi]
                cached = jax.tree.map(lambda x: x[home, bi], a_vals)
                expand = lambda mk, x: mk.reshape(
                    mk.shape + (1,) * (x.ndim - 1)
                )
                resolved = jax.tree.map(
                    lambda cv, fv: jnp.where(expand(hit, fv), cv, fv),
                    cached, fresh,
                )
                need = is_rep & ~hit
                # route fresh detections to their home shards; flattening
                # the received rows requester-major reproduces the exact
                # u-major batch order the replica design's gathered insert
                # used, so within-batch slot collisions pick the same
                # winner and the logical cache stays bit-identical
                dest = jnp.arange(num_shards, dtype=jnp.int32)[:, None]
                ins_frames = jnp.where(
                    (home[None, :] == dest) & need[None, :],
                    flat_frames[None, :], -1,
                )                                                # [S, b]
                ins_vals = jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x[None], (num_shards,) + x.shape
                    ),
                    fresh,
                )
                g_frames = jax.lax.all_to_all(
                    ins_frames, axis, 0, 0
                ).reshape(-1)
                g_vals = jax.tree.map(
                    lambda x: jax.lax.all_to_all(x, axis, 0, 0).reshape(
                        (-1,) + x.shape[2:]
                    ),
                    ins_vals,
                )
                cache = sharded_cache_insert(
                    cache, g_frames, g_vals, g_frames >= 0,
                    shard_id, num_shards,
                )
            else:
                hit = jnp.zeros((b,), bool)
                resolved = fresh
                need = is_rep
            dets_flat = jax.tree.map(lambda x: x[first_idx], resolved)
            lcalls = lcalls + jnp.sum(need).astype(jnp.int32)
            lhits = lhits + jnp.sum(is_rep & hit).astype(jnp.int32)
            if wtag is not None:
                # index hits: cache hits whose slot still tags the frame
                # the repository-index preload installed (DESIGN.md §13)
                wslot = flat_frames % wtag.shape[0]
                lihits = lihits + jnp.sum(
                    is_rep & hit & (wtag[wslot] == flat_frames)
                ).astype(jnp.int32)
            dets_q = jax.tree.map(
                lambda x: x.reshape((q_n, per_shard) + x.shape[1:]),
                dets_flat,
            )

            # ---- per-query sequential fold over its own slots (vmapped
            # over Q; mirrors the §8 proc loop per query) ----
            def fold_query(q, dn1_q, dn_q, matcher_q, dets_c, cids_q,
                           fids_q, live_q, lstep_q, lres_q):
                def bodyj(j, st):
                    dn1_q, dn_q, matcher_q, lstep_q, lres_q = st
                    d = jax.tree.map(lambda x: x[j], dets_c)
                    live = live_q[j]
                    valid = d.valid & live
                    if select is not None:
                        valid = valid & select(q, d)
                    mres = match_and_update(
                        matcher_q, d.boxes, d.feats, valid,
                        chks.video_id[cids_q[j]], fids_q[j], cids_q[j],
                    )
                    d1_local = mres.d1 - mres.cross_chunk
                    upd = live.astype(dn1_q.dtype)
                    dn1_q = dn1_q.at[cids_q[j]].add(
                        (mres.d0 - d1_local).astype(dn1_q.dtype) * upd
                    )
                    dn_q = dn_q.at[cids_q[j]].add(upd)
                    valid_home = mres.cross_home >= 0
                    dn1_q = dn1_q.at[
                        jnp.where(valid_home, mres.cross_home, 0)
                    ].add(-valid_home.astype(dn1_q.dtype))
                    return (
                        dn1_q, dn_q, mres.new_state,
                        lstep_q + live.astype(jnp.int32),
                        lres_q + mres.d0,
                    )

                return jax.lax.fori_loop(
                    0, per_shard, bodyj,
                    (dn1_q, dn_q, matcher_q, lstep_q, lres_q),
                )

            delta_n1, delta_n, matcher, lstep, lres = jax.vmap(fold_query)(
                qi, delta_n1, delta_n, matcher, dets_q, cids_s, fids_s,
                live_s, lstep, lres,
            )
            keys = jnp.where(
                active.reshape((q_n,) + (1,) * (keys.ndim - 1)),
                key_next, keys,
            )
            return (keys, delta_n1, delta_n, foreign, matcher, cache,
                    lstep, lres, lcalls, lhits, lihits)

        def body(st):
            (keys, n1_l, n_l, matcher, snap, cache, step, results, buf, tn,
             wcalls, whits, wihits, hw, ov, windows, _cont) = st
            active = live_mask(step, results, n_l)               # [Q]
            rst = (
                keys,
                jnp.zeros((q_n, m), n1_l.dtype),
                jnp.zeros((q_n, m), fdt),
                jnp.zeros((q_n, m), jnp.int32),
                matcher,
                cache,
                jnp.zeros((q_n,), jnp.int32),
                jnp.zeros((q_n,), jnp.int32),
                wcalls,
                whits,
                wihits,
            )
            keys, dn1, dn, _foreign, matcher, cache, lstep, lres, wcalls, \
                whits, wihits = jax.lax.fori_loop(
                    0, sync_every, lambda r, s: one_round(n1_l, n_l, active, s),
                    rst,
                )
            # ---- sampler sync: one [Q, M] psum (exact, additive) ----
            n1_l = n1_l + my_slice(jax.lax.psum(dn1, axis))
            n_l = n_l + my_slice(jax.lax.psum(dn, axis))
            # ---- matcher sync: per-query §8 fold + exact k−1 add-back of
            # cross-shard duplicate d₁ decrements ----
            stacked = jax.tree.map(
                lambda x: jax.lax.all_gather(x, axis), matcher
            )                                                    # [S, Q, ..]
            same_e = (stacked.video == snap.video[None]) & (
                stacked.frame == snap.frame[None]
            )
            trans = (
                same_e
                & (snap.times_seen[None] == 1)
                & (stacked.times_seen >= 2)
            )                                                    # [S, Q, R]
            k = jnp.sum(trans, axis=0)                           # [Q, R]
            over = jnp.maximum(k - 1, 0).astype(n1_l.dtype)
            corr = jnp.zeros((q_n, m), n1_l.dtype).at[
                qi[:, None], jnp.where(k > 0, snap.chunk, 0)
            ].add(jnp.where(k > 0, over, jnp.zeros((), n1_l.dtype)))
            n1_l = n1_l + my_slice(corr)
            merged = jax.lax.fori_loop(
                1,
                num_shards,
                lambda s, dst: jax.vmap(merge_matcher)(
                    dst, jax.tree.map(lambda x: x[s], stacked), snap
                ),
                jax.tree.map(lambda x: x[0], stacked),
            )
            # ---- ring-pressure accounting (merge_matcher_checked
            # semantics, replicated): insertions per shard per window ----
            inserted = stacked.total_inserted - snap.total_inserted[None]
            hw = jnp.maximum(hw, jnp.max(inserted))
            ov = ov | jnp.any(inserted >= cap_r)
            # ---- counters / per-query trace / continue flag ----
            step = step + jax.lax.psum(lstep, axis)
            results = results + jax.lax.psum(lres, axis)
            entry = jnp.stack([step, results], axis=-1)          # [Q, 2]
            idx = jnp.where(active, tn, cap)
            buf = jax.vmap(lambda bq, i, e: bq.at[i].set(e, mode="drop"))(
                buf, idx, entry
            )
            tn = jnp.minimum(tn + active.astype(jnp.int32), cap)
            cont = jnp.any(live_mask(step, results, n_l)) & (
                windows + 1 < wlimit
            )
            return (keys, n1_l, n_l, merged, merged, cache, step, results,
                    buf, tn, wcalls, whits, wihits, hw, ov, windows + 1,
                    cont)

        cont0 = jnp.any(live_mask(step0, results0, n_l)) & (wlimit > 0)
        init = (
            keys, n1_l, n_l, matcher0, matcher0, cache0, step0, results0,
            jnp.zeros((q_n, cap, 2), jnp.int32),
            jnp.zeros((q_n,), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), bool),
            jnp.zeros((), jnp.int32), cont0,
        )
        (keys, n1_l, n_l, matcher, _snap, cache_f, step, results, buf, tn,
         wcalls, whits, wihits, hw, ov, windows, _c) = jax.lax.while_loop(
            lambda st: st[-1], body, init
        )
        # final per-query checkpoint only where the trace would otherwise
        # miss the end state (mirrors the §8 tail, vmapped over Q)
        idx = jnp.where(
            (tn == 0) | (tn >= cap), jnp.minimum(tn, cap - 1), cap
        )
        buf = jax.vmap(lambda bq, i, e: bq.at[i].set(e, mode="drop"))(
            buf, idx, jnp.stack([step, results], axis=-1)
        )
        tn = jnp.clip(tn, 1, cap)
        calls = jax.lax.psum(wcalls, axis)
        hits = jax.lax.psum(whits, axis)
        ihits = jax.lax.psum(wihits, axis)
        outs = (n1_l, n_l, matcher, keys, step, results, buf, tn, calls,
                hits, ihits, hw, ov, windows)
        if cache_f is not None:
            # each shard returns only its 1/S of the hash-sharded logical
            # cache; concatenating over the sharded out-spec reproduces
            # the global shard-major layout, and the host wrapper's
            # unshard_cache_layout turns it back into the direct-mapped
            # cache the index publish path understands
            outs = outs + (cache_f,)
        return outs

    sh1, sh2, rep = P(axis), P(None, axis), P()
    out_specs = (
        sh2, sh2, rep, rep, rep, rep, rep, rep, rep, rep, rep, rep, rep,
        rep,
    )
    cache_spec = rep if cache is None else sh1
    if cache is not None:
        out_specs = out_specs + (sh1,)
    return get_shard_map()(
        shard_fn,
        mesh=mesh,
        in_specs=(rep, rep, rep, sh2, sh2, sh2, rep, rep, rep, cache_spec,
                  rep, rep),
        out_specs=out_specs,
        check_rep=False,
    )(keys, step0, results0, n1, n, frames, matcher, chunks, result_limits,
      cache, warm_tag, window_limit)


def run_search_multi_sharded(
    carries: ExSampleCarry,
    chunks: ChunkIndex,
    *,
    mesh,
    detector: DetectorFn,
    result_limits,
    max_steps: int,
    cohorts: int | None = None,
    sync_every: int = 1,
    axis: str = "data",
    select: SelectFn | None = None,
    cache_frames: int = 0,
    cache=None,
    warm_tag=None,
    window_limit: int | None = None,
):
    """Q concurrent queries × an M-sharded mesh, one deduplicated detector
    pass per round per shard (DESIGN.md §10) — the composed lowering behind
    ``SearchPlan`` plans with ``queries_axis`` + ``shards > 1``.

    ``carries`` is a stacked ``ExSampleCarry`` (leading [Q] axis,
    ``init_carry_multi`` / ``stack_carries``).  ``cohorts`` is each query's
    GLOBAL per-round batch (default: one frame per shard) and must divide
    over the mesh; chunk statistics are padded to the shard count with
    exhausted dummies and trimmed on the way out.  Returns
    ``(carries', traces, stats)`` with the same per-query trace semantics
    as the solo sharded driver and §9-style sharing stats.

    ``cache`` overrides internal cache construction (a repository-index
    preload, DESIGN.md §13); ``warm_tag`` — the preload's tag snapshot —
    splits ``index_hits`` out of ``cache_hits``.  Whenever a cache is in
    play its final state rides back in ``stats["final_cache"]``
    (direct-mapped layout; the hash-sharded device layout is internal).

    ``window_limit`` caps how many sync windows THIS call executes
    (default: unbounded).  A capped call returns at a sync boundary with a
    fully resumable state — carry + ``stats["final_cache"]`` feed straight
    back in — which is the drain point the elastic runner
    (:class:`repro.core.runtime.ElasticShardedRunner`) uses to reshard
    onto a shrunken mesh between calls.
    """
    num_shards = mesh.shape[axis]
    if cohorts is None:
        cohorts = num_shards
    if cohorts < num_shards or cohorts % num_shards:
        raise ValueError(
            f"cohorts={cohorts} must be a positive multiple of the "
            f"{num_shards} '{axis}' shards"
        )
    if sync_every < 1:
        raise ValueError(f"sync_every={sync_every} must be >= 1")
    from repro.core.distributed import pad_chunks

    q_n = int(carries.step.shape[0])
    m0 = int(carries.sampler.n1.shape[-1])
    padded = pad_chunks(carries.sampler, num_shards)
    n1, n, frames = padded.n1, padded.n, padded.frames

    if cache is None and cache_frames:
        from repro.serve.batcher import init_detection_cache

        # the hash-sharded placement needs capacity % shards == 0 to be a
        # pure transposition of the direct-mapped slot map; padding the
        # capacity up never loses entries (it only splits collision sets)
        cache_frames += (-cache_frames) % num_shards
        struct = jax.eval_shape(
            detector, jax.random.PRNGKey(0), jnp.zeros((), jnp.int32)
        )
        cache = init_detection_cache(struct, cache_frames)
    if cache is not None:
        from repro.serve.batcher import shard_cache_layout

        cache = shard_cache_layout(cache, num_shards)

    outs = _search_multi_sharded_device(
        carries.key,
        carries.step,
        carries.results,
        n1,
        n,
        frames,
        carries.matcher,
        chunks,
        jnp.broadcast_to(
            jnp.asarray(result_limits, jnp.int32), (q_n,)
        ),
        cache,
        warm_tag,
        jnp.asarray(
            np.iinfo(np.int32).max if window_limit is None
            else int(window_limit),
            jnp.int32,
        ),
        mesh=mesh,
        axis=axis,
        detector=detector,
        select=select,
        cohorts=cohorts,
        sync_every=sync_every,
        max_steps=max_steps,
        alpha0=carries.sampler.alpha0,
        beta0=carries.sampler.beta0,
    )
    (n1_out, n_out, matcher, keys, step, results, buf, tn, calls, hits,
     ihits, hw, ov, windows) = outs[:14]
    final_cache = None
    if cache is not None:
        from repro.serve.batcher import unshard_cache_layout

        final_cache = unshard_cache_layout(outs[14], num_shards)
    out = ExSampleCarry(
        sampler=dataclasses.replace(
            carries.sampler,
            n1=n1_out[:, :m0],
            n=n_out[:, :m0],
            frames=carries.sampler.frames,
        ),
        matcher=matcher,
        key=keys,
        step=step,
        results=results,
    )
    buf_host = np.asarray(buf)  # the single device→host sync
    tn_host = np.asarray(tn)
    traces = [
        [(int(s), int(r)) for s, r in buf_host[q][: int(tn_host[q])]]
        for q in range(q_n)
    ]
    stats = {
        "detector_invocations": int(calls),
        "cache_hits": int(hits),
        "index_hits": int(ihits),
        "rounds": int(windows) * sync_every,
        "frames_sampled": int(np.asarray(out.step).sum()),
        "merge_high_water": int(hw),
        "merge_overflow": bool(ov),
        "merges": int(windows),
        "final_cache": final_cache,
    }
    return out, traces, stats
