"""Per-chunk sampler statistics for ExSample (paper §3, Algorithm 1).

The sampler state is a dense, fixed-shape pytree so that every update is
jittable and shardable.  Per chunk j we track:

  * ``n1[j]``    — N¹_j: number of results seen *exactly once globally* whose
                   single sighting happened in chunk j (paper §3.4).
  * ``n[j]``     — number of frames sampled from chunk j so far.
  * ``frames[j]``— number of frames chunk j contains (for exhaustion masking).

All updates are additive and therefore commutative + associative, which is
the paper's §3.7.1 justification for batched/asynchronous execution; the
distributed runtime (``repro.core.distributed``) relies on exactly this.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

# Paper §3.3.1: Gamma prior smoothing constants.  "We used alpha0 = .1 and
# beta0 = 1 in practice, though we did not observe a strong dependence."
DEFAULT_ALPHA0: float = 0.1
DEFAULT_BETA0: float = 1.0


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SamplerState:
    """Dense ExSample statistics over M chunks."""

    n1: jax.Array          # f32[M]  — N¹ per chunk
    n: jax.Array           # f32[M]  — samples drawn per chunk
    frames: jax.Array      # i32[M]  — frames available per chunk
    alpha0: float = dataclasses.field(metadata=dict(static=True), default=DEFAULT_ALPHA0)
    beta0: float = dataclasses.field(metadata=dict(static=True), default=DEFAULT_BETA0)

    @property
    def num_chunks(self) -> int:
        return self.n1.shape[0]

    def exhausted(self) -> jax.Array:
        """bool[M] — True where every frame of the chunk has been sampled."""
        return self.n >= self.frames.astype(self.n.dtype)


def init_state(
    frames_per_chunk: jax.Array | Any,
    *,
    alpha0: float = DEFAULT_ALPHA0,
    beta0: float = DEFAULT_BETA0,
    dtype: jnp.dtype = jnp.float32,
) -> SamplerState:
    """Fresh state: all-zero statistics (Algorithm 1 lines 2-3)."""
    frames = jnp.asarray(frames_per_chunk, dtype=jnp.int32)
    zeros = jnp.zeros(frames.shape, dtype=dtype)
    return SamplerState(n1=zeros, n=zeros, frames=frames, alpha0=alpha0, beta0=beta0)


def apply_update(
    state: SamplerState,
    chunk_idx: jax.Array,
    d0: jax.Array,
    d1: jax.Array,
    *,
    samples: jax.Array | int = 1,
) -> SamplerState:
    """Algorithm 1 lines 13-14 for one (possibly batched) observation.

    Args:
      chunk_idx: i32[] or i32[B] — chunk(s) the frame(s) were drawn from.
      d0: number of detections that matched *no* previous result.
      d1: number of detections whose result now has exactly one prior match
          (i.e. results transitioning from seen-once to seen-twice).
      samples: frames consumed per entry (normally 1).

    ``N¹[j*] += |d0| - |d1|``; ``n[j*] += 1``.  Batched form uses
    scatter-add so colliding chunk indices accumulate, preserving
    commutativity.
    """
    chunk_idx = jnp.atleast_1d(jnp.asarray(chunk_idx))
    d0 = jnp.broadcast_to(jnp.asarray(d0, state.n1.dtype), chunk_idx.shape)
    d1 = jnp.broadcast_to(jnp.asarray(d1, state.n1.dtype), chunk_idx.shape)
    samples = jnp.broadcast_to(jnp.asarray(samples, state.n.dtype), chunk_idx.shape)
    n1 = state.n1.at[chunk_idx].add(d0 - d1)
    n = state.n.at[chunk_idx].add(samples)
    return dataclasses.replace(state, n1=n1, n=n)


def apply_cross_chunk_decrement(
    state: SamplerState, home_chunk: jax.Array, count: jax.Array
) -> SamplerState:
    """§3.4: a result first seen in chunk ``home_chunk`` was re-found in a
    *different* chunk — its contribution leaves N¹ of the home chunk."""
    home_chunk = jnp.atleast_1d(jnp.asarray(home_chunk))
    count = jnp.broadcast_to(jnp.asarray(count, state.n1.dtype), home_chunk.shape)
    return dataclasses.replace(state, n1=state.n1.at[home_chunk].add(-count))


def merge_states(a: SamplerState, b: SamplerState) -> SamplerState:
    """Merge two independently-updated replicas of the *same* initial state.

    Because all updates are additive, merged = init + (a - init) + (b - init)
    and init is zero, so the statistics simply add.  Used by the async /
    multi-pod runtime and by elastic resharding.
    """
    if a.num_chunks != b.num_chunks:
        raise ValueError(
            f"cannot merge states over {a.num_chunks} vs {b.num_chunks} chunks"
        )
    return dataclasses.replace(a, n1=a.n1 + b.n1, n=a.n + b.n)


def point_estimate(state: SamplerState) -> jax.Array:
    """Eq. 7 point estimate N¹_j / n_j with the prior-smoothed form used for
    decision making: (N¹+α₀)/(n+β₀).  Exhausted chunks score -inf."""
    est = (state.n1 + state.alpha0) / (state.n + state.beta0)
    return jnp.where(state.exhausted(), -jnp.inf, est)
