"""Asynchronous search runtime — the production service around Algorithm 1.

The paper sketches asynchronous distributed execution (§3.7.1: "workers
processing a batch of frames at a time without waiting for other workers…
all updates are commutative").  This module is that sketch made concrete,
at two tiers:

  * :class:`AsyncSearchDriver` — the legacy single-query tier: a driver
    owns the sampler/matcher state and a cohort queue; N workers pull
    whole-carry cohorts, process each as a SINGLE scanned device call
    (``_process_cohort``), and push delta statistics back whenever they
    finish — no barriers.  The driver merges deltas commutatively
    (`merge_deltas`), re-samples new cohorts from the freshest state,
    monitors worker health (`HeartbeatMonitor`) and re-issues cohorts
    from dead/straggling workers (at-most-once *effect*: a duplicated
    frame perturbs one sample, which the estimator tolerates —
    DESIGN.md §5).

  * :class:`AsyncMultiSearchDriver` — the slot-based elastic scheduler
    over a leading-``[Q]`` carry (DESIGN.md §11): workers check out
    per-query *cohort slots* (query id, chunk winners, rank base, key
    split — a precomputed :class:`~repro.core.exsample.RoundChoice`)
    instead of whole carries, process whichever slots are in flight
    through ONE shared dedup + :class:`DetectionCache` detector batch
    (``multi_round_process``), and the driver applies each query's delta
    back into its row under the pending-set/at-most-once discipline.  At
    most one slot per query is in flight, so per-query rounds serialize
    and every query's trajectory is bit-identical to its solo
    ``run_search_scan`` run at ANY worker count (deterministic detector).
    Finished queries retire their slots; new queries join mid-flight
    (``admit``) via the same finished-query masking machinery.

Both tiers spill matcher-ring evictions to an append-only host-side
:class:`~repro.core.matcher.ResultLog` at merge boundaries, so result
sets are unbounded while the device ring stays fixed (the ring-spill
contract, DESIGN.md §11).

The runtime is deterministic under a virtual clock for testing; the
worker pool is threads (the detector releases the GIL under jax) — on a
real deployment each worker is a pod client.
"""
from __future__ import annotations

import dataclasses
import math
import queue
import threading
import time
from functools import partial
from typing import Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chunks import ChunkIndex
from repro.core.distributed import merge_deltas
from repro.core.exsample import (
    ExSampleCarry,
    RoundAux,
    RoundChoice,
    SelectFn,
    _process_frame,
    multi_round_choose,
    multi_round_process,
    stack_carries,
)
from repro.core.matcher import (
    MatcherState,
    ResultLog,
    eviction_mask,
    merge_matcher_checked,
)
from repro.core.state import SamplerState
from repro.core.thompson import choose_chunks
from repro.distributed.fault_tolerance import HeartbeatMonitor
from repro.serve.batcher import cache_insert, init_detection_cache


class MatcherRingOverflow(RuntimeError):
    """A worker inserted ≥ capacity results between snapshot and merge: the
    SOURCE ring wrapped, entries were overwritten before they could be
    seen, and no spill can recover them.  Raised instead of silently
    under-counting (ROADMAP ring-wrap guard).  Evictions on the
    *destination* side are recoverable and spill to the host
    :class:`~repro.core.matcher.ResultLog` instead (DESIGN.md §11);
    deployments hitting this error should size ``max_results`` above the
    per-merge insertion bound (cohort size × detections per frame) or
    merge more often."""


@partial(jax.jit, static_argnames=("detector",))
def _process_cohort(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    chunk_ids: jax.Array,   # i32[B]
    det_keys: jax.Array,    # key[B]
    *,
    detector: Callable,
) -> ExSampleCarry:
    """Process a whole cohort as ONE device call (DESIGN.md §7).

    The per-frame Python loop this replaces paid one jit dispatch per
    frame; here the B matcher-sequential frame updates fold under a
    single ``lax.fori_loop`` so a worker's cohort costs one dispatch
    regardless of B.
    """
    def body(i, c):
        return _process_frame(c, chunks, detector, chunk_ids[i], det_keys[i])

    return jax.lax.fori_loop(0, chunk_ids.shape[0], body, carry)


@dataclasses.dataclass
class Cohort:
    cohort_id: int
    chunk_ids: np.ndarray      # i64[B]
    issue_count: int = 0       # >1 ⇒ re-issued (straggler/death)


@dataclasses.dataclass
class WorkerResult:
    cohort_id: int
    worker_id: int
    delta_n1: jax.Array
    delta_n: jax.Array
    new_results: int
    frames: int
    matcher: Optional[MatcherState] = None       # worker's final result memory
    snap_matcher: Optional[MatcherState] = None  # memory at the snapshot


class AsyncSearchDriver:
    """Cohort scheduler + state owner.  Thread-safe, barrier-free."""

    def __init__(
        self,
        carry: ExSampleCarry,
        chunks: ChunkIndex,
        detector: Callable,
        *,
        cohort_size: int = 8,
        num_workers: int = 4,
        result_limit: int = 50,
        max_frames: int = 100_000,
        straggler_factor: float = 4.0,
    ):
        self.carry = carry
        self.chunks = chunks
        self.detector = detector
        self.cohort_size = cohort_size
        self.result_limit = result_limit
        self.max_frames = max_frames
        self.monitor = HeartbeatMonitor(straggler_factor=straggler_factor)
        self._lock = threading.Lock()
        self._work: "queue.Queue[Optional[Cohort]]" = queue.Queue()
        self._results: "queue.Queue[WorkerResult]" = queue.Queue()
        self._next_cohort = 0
        self._inflight: dict[int, Cohort] = {}
        self.num_workers = num_workers
        self.result_log = ResultLog()
        # every counter exists from construction so LoweredPlan.run() can
        # package uniform SearchStats even for a run that never merged
        self.stats = {
            "cohorts": 0, "reissues": 0, "merges": 0, "duplicate_drops": 0,
            "merge_high_water": 0, "spilled": 0,
        }

    # ---- driver side -------------------------------------------------------

    def _issue_cohort(self) -> None:
        with self._lock:
            key = jax.random.fold_in(self.carry.key, self._next_cohort)
            chunk_ids = np.asarray(
                choose_chunks(key, self.carry.sampler, cohorts=self.cohort_size)
            )
            cohort = Cohort(self._next_cohort, chunk_ids)
            self._next_cohort += 1
            self._inflight[cohort.cohort_id] = cohort
            self.stats["cohorts"] += 1
        self._work.put(cohort)

    def _merge(self, res: WorkerResult) -> None:
        """Fold one worker result into the shared carry — sampler deltas,
        counters AND matcher memory under a single lock acquisition.
        The matcher is *merged* (new entries appended, seen-count bumps
        added — ``merge_matcher``), not replaced: a concurrent merge can
        neither double-count results nor drop another worker's matcher
        insertions.  Cross-worker duplicate detections remain possible —
        the at-most-once-*effect* tolerance, DESIGN.md §5.

        A cohort is merged AT MOST ONCE: ``HeartbeatMonitor`` re-issues a
        straggler's cohort, so two completions of the same cohort can
        land; folding both double-counts sampler deltas, ``step``,
        ``results`` and matcher insertions.  The pending set is
        ``self._inflight`` — the first completion removes the cohort under
        the lock, any later completion of the same cohort is dropped (and
        counted in ``stats["duplicate_drops"]``).

        Ring-spill contract (DESIGN.md §11): live destination entries the
        append window overwrites drain to ``self.result_log`` BEFORE the
        merge lands, so eviction loses nothing.  Only a SOURCE-ring wrap
        (``mstats.overflow``: ≥ capacity insertions between snapshot and
        merge, unrecoverable by construction) still raises
        ``MatcherRingOverflow``; the per-merge insertion count is
        surfaced as ``stats["merge_high_water"]``."""
        with self._lock:
            if res.cohort_id not in self._inflight:
                self.stats["duplicate_drops"] += 1
                return
            del self._inflight[res.cohort_id]
            sampler = merge_deltas(self.carry.sampler, res.delta_n1, res.delta_n)
            matcher = self.carry.matcher
            if res.matcher is not None:
                inserted = int(
                    res.matcher.total_inserted - res.snap_matcher.total_inserted
                )
                self.stats["merge_high_water"] = max(
                    self.stats["merge_high_water"], inserted
                )
                if inserted >= matcher.capacity:
                    raise MatcherRingOverflow(
                        f"cohort {res.cohort_id}: {inserted} insertions "
                        f"into a capacity-{matcher.capacity} result ring "
                        "wrapped the source ring (unrecoverable) — size "
                        "max_results above the per-cohort insertion bound"
                    )
                if inserted:
                    self.stats["spilled"] += self.result_log.spill(
                        matcher, eviction_mask(matcher, inserted)
                    )
                matcher, _mstats = merge_matcher_checked(
                    matcher, res.matcher, res.snap_matcher
                )
            self.carry = dataclasses.replace(
                self.carry,
                sampler=sampler,
                matcher=matcher,
                step=self.carry.step + res.frames,
                results=self.carry.results + res.new_results,
            )
            self.stats["merges"] += 1

    def _reissue(self, cohort_id: int) -> None:
        with self._lock:
            cohort = self._inflight.get(cohort_id)
            if cohort is None:
                return
            cohort.issue_count += 1
            self.stats["reissues"] += 1
        self._work.put(cohort)

    # ---- worker side -------------------------------------------------------

    def _process_one(self, wid: int, cohort: Cohort) -> WorkerResult:
        """Process one cohort against a locked snapshot of the shared carry.

        Snapshot the shared carry under the lock and compute EVERY delta
        against that snapshot — reading self.carry again after processing
        would race with concurrent merges (double-counted results / lost
        matcher updates).  Pure of scheduling concerns so tests can drive
        duplicate completions synchronously.
        """
        with self._lock:
            snapshot = self.carry
        b = len(cohort.chunk_ids)
        # nested fold_in: unique per (cohort, frame) for ANY cohort size
        # (a flat cohort_id*stride + i scheme collides once b > stride)
        base = jax.random.fold_in(jax.random.PRNGKey(7), cohort.cohort_id)
        det_keys = jax.vmap(
            lambda i: jax.random.fold_in(base, i)
        )(jnp.arange(b, dtype=jnp.int32))
        local = _process_cohort(
            snapshot,
            self.chunks,
            jnp.asarray(cohort.chunk_ids, jnp.int32),
            det_keys,
            detector=self.detector,
        )
        return WorkerResult(
            cohort_id=cohort.cohort_id,
            worker_id=wid,
            delta_n1=local.sampler.n1 - snapshot.sampler.n1,
            delta_n=local.sampler.n - snapshot.sampler.n,
            new_results=int(local.results - snapshot.results),
            frames=b,
            matcher=local.matcher,           # merged atomically…
            snap_matcher=snapshot.matcher,   # …against this baseline
        )

    def _worker(self, wid: int) -> None:
        self.monitor.register(wid, now=time.monotonic())
        while True:
            cohort = self._work.get()
            if cohort is None:
                return
            t0 = time.monotonic()
            self.monitor.assign(wid, cohort.cohort_id, now=t0)
            self._results.put(self._process_one(wid, cohort))
            now = time.monotonic()
            self.monitor.heartbeat(wid, now)
            self.monitor.record_completion(wid, now - t0, now=now)

    # ---- run loop ----------------------------------------------------------

    def run(self) -> ExSampleCarry:
        threads = [
            threading.Thread(target=self._worker, args=(w,), daemon=True)
            for w in range(self.num_workers)
        ]
        for t in threads:
            t.start()
        # keep the pipeline full: workers+1 outstanding cohorts
        for _ in range(self.num_workers + 1):
            self._issue_cohort()
        try:
            while (
                int(self.carry.results) < self.result_limit
                and int(self.carry.step) < self.max_frames
            ):
                try:
                    res = self._results.get(timeout=60.0)
                except queue.Empty:
                    break
                self._merge(res)
                actions = self.monitor.sweep(time.monotonic())
                for cid in actions["reissue_cohorts"]:
                    self._reissue(cid)
                self._issue_cohort()
        finally:
            # always shut the pool down — a raising merge (e.g.
            # MatcherRingOverflow) must not leak blocked worker threads
            for _ in threads:
                self._work.put(None)
            for t in threads:
                t.join(timeout=5.0)
        return self.carry


# ---------------------------------------------------------------------------
# Slot-based elastic scheduler over a leading-[Q] carry (DESIGN.md §11)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("cohorts", "method"))
def _issue_slots(
    sub: ExSampleCarry,
    chunks: ChunkIndex,
    *,
    cohorts: int,
    method: str,
) -> RoundChoice:
    """Choose phase for a gathered batch of query rows — the content of a
    cohort slot: chunk winners, random+ rank base, per-slot key split."""
    return multi_round_choose(sub, chunks, cohorts=cohorts, method=method)


@partial(jax.jit, static_argnames=("detector", "select"))
def _process_slots(
    sub: ExSampleCarry,
    cache,
    chunks: ChunkIndex,
    query_ids: jax.Array,
    active: jax.Array,
    choice: RoundChoice,
    *,
    detector: Callable,
    select: Optional[SelectFn],
):
    """Process phase for whichever slots are in flight: ONE shared dedup +
    ``DetectionCache`` detector batch for the gathered rows, then each
    query's sequential matcher/sampler fold.  Identical round body to the
    resident ``_search_multi_device`` loop (``multi_round_process``), so
    per-lane results are bit-identical to the solo drivers."""
    return multi_round_process(
        sub, cache, chunks, active, choice,
        detector=detector, select=select, query_ids=query_ids,
    )


@dataclasses.dataclass
class SlotBatch:
    """A checked-out set of per-query cohort slots (at most one per query).

    ``carry`` holds the gathered rows at issue time — authoritative, since
    a query has at most one slot in flight — and ``choice`` is the
    precomputed choose phase, so a re-issued straggler batch reprocesses
    the IDENTICAL work item."""

    batch_id: int
    query_rows: np.ndarray      # i32[B] — driver row index per lane
    carry: ExSampleCarry        # gathered rows, leading [B]
    choice: RoundChoice         # leading [B]
    active: np.ndarray          # bool[B] — False = padding lane
    select_ids: np.ndarray = None   # i32[B] — id handed to select() per lane
    issue_count: int = 0        # >1 ⇒ re-issued (straggler/death)


@dataclasses.dataclass
class SlotResult:
    batch_id: int
    worker_id: int
    carry: ExSampleCarry        # post-round rows, leading [B]
    fresh_calls: int            # unique, uncached frames detected
    cache_hits: int
    aux: RoundAux               # fresh detections for cache publication


@dataclasses.dataclass
class _QueryRow:
    """One query's slot in the elastic pool.

    Beyond the carry itself the row holds the per-tenant accounting the
    service front reports: detector economics attributed to this query
    (``fresh_calls``/``cache_hits`` — by dedup representative, so a frame
    two tenants sampled in one batch bills the first), wall-clock result
    stamps for SLO tracking, and the admission metadata.  ``select_id`` is
    the id handed to the ``select`` predicate instead of the row index, so
    a service can bind a tenant's predicate (e.g. its query class) at
    admission without recompiling anything; ``vacant`` marks a released
    slot ``admit()`` may reuse."""

    carry: ExSampleCarry        # single-query carry (scalar step/results)
    limit: int                  # distinct-result target
    budget: int                 # frame budget (max steps for THIS query)
    trace: list
    log: ResultLog
    active: bool = True         # False = retired (finished or failed)
    inflight: bool = False      # a slot for this query is checked out
    rounds: int = 0             # rounds merged so far
    vacant: bool = False        # released slot, reusable by admit()
    select_id: Optional[int] = None   # id passed to select() (default: row)
    fresh_calls: int = 0        # detector invocations attributed to this row
    cache_hits: int = 0         # cache hits attributed to this row
    index_hits: int = 0         # cache hits served by index-warmed frames
    warm_rounds_saved: int = 0  # prior-injection warm-up equivalent (rounds)
    admitted_s: float = 0.0     # monotonic wall-clock at admit/construction
    first_result_s: float = 0.0  # monotonic stamp of the first result merge
    finished_s: float = 0.0     # monotonic stamp at retire
    result_stamps: list = dataclasses.field(default_factory=list)
    # ^ (monotonic_s, cumulative_results) per merge that grew results


class AsyncMultiSearchDriver:
    """Elastic slot scheduler: async workers × a leading-[Q] carry.

    The driver owns Q query rows (sampler, matcher, key, counters — one
    lane of the §9 multi-query carry each).  ``_issue_ready`` checks out a
    *cohort slot* per issuable query — the precomputed
    :class:`~repro.core.exsample.RoundChoice` (chunk winners, rank base,
    key split) plus the row snapshot — and packs up to ``slots_per_batch``
    slots into one :class:`SlotBatch` work item.  Workers run the shared
    dedup + cache + detector batch (``_process_slots``) for whichever
    slots are in flight; ``_merge`` applies each query's post-round row
    back under the pending-set/at-most-once discipline, publishes fresh
    detections into the shared :class:`DetectionCache`, spills
    ring-evicted results to the per-query host
    :class:`~repro.core.matcher.ResultLog` and re-issues freed queries.

    Scheduling invariant: AT MOST ONE slot per query in flight — round
    r+1 of a query is only chosen after round r merged.  Per-query rounds
    therefore serialize, and with a deterministic detector each query's
    (step, results, trace, sampler, key) trajectory is bit-identical to
    its solo ``run_search_scan`` run at ANY worker count: concurrency
    comes from different queries' rounds overlapping, amortization from
    the shared per-batch dedup and the cross-round cache (which change
    WHICH detector invocations happen, never the values a query
    consumes).  Sampler deltas never cross queries and each row is
    replaced wholesale by its own serialized round, so Q-axis merges
    commute trivially (DESIGN.md §11 vs the §8/§9 argument for shared
    state).

    Elasticity: a finished query retires its row (masked out of issue,
    shape-stable); ``admit()`` installs a fresh query mid-flight with a
    frame budget debited by the pool rounds it missed.  Batch shapes are
    fixed at ``slots_per_batch`` (padded with inactive lanes), so neither
    retirement nor admission recompiles anything.

    The composed path cannot raise :class:`MatcherRingOverflow`: the
    constructor rejects configurations whose per-round insertion bound
    (cohorts × detector slots per frame) reaches the ring capacity, which
    is the only way a source ring can wrap between issue and merge.
    """

    def __init__(
        self,
        carries: ExSampleCarry,
        chunks: ChunkIndex,
        detector: Callable,
        *,
        cohorts: int = 1,
        num_workers: int = 4,
        result_limits: Union[int, Sequence[int]] = 50,
        max_steps: int = 100_000,
        method: str = "exact",
        select: Optional[SelectFn] = None,
        cache_frames: int = 0,
        trace_every: int = 0,
        slots_per_batch: Optional[int] = None,
        straggler_factor: float = 4.0,
        index=None,
    ):
        if jnp.ndim(carries.step) != 1:
            raise ValueError(
                "AsyncMultiSearchDriver needs a leading-[Q] carry "
                "(init_carry_multi / stack_carries); got a single-query "
                "carry"
            )
        q_n = int(carries.step.shape[0])
        if isinstance(result_limits, (int, np.integer)):
            limits = [int(result_limits)] * q_n
        else:
            limits = [int(v) for v in np.asarray(result_limits).reshape(-1)]
            if len(limits) != q_n:
                raise ValueError(
                    f"result_limits has {len(limits)} entries for a "
                    f"{q_n}-query carry"
                )
        self.chunks = chunks
        self.detector = detector
        self.select = select
        self.cohorts = cohorts
        self.method = method
        self.max_steps = max_steps
        self.trace_every = trace_every
        self.num_workers = num_workers
        self.slots_per_batch = (
            max(1, math.ceil(q_n / max(num_workers, 1)))
            if slots_per_batch is None
            else max(1, slots_per_batch)
        )
        self.monitor = HeartbeatMonitor(straggler_factor=straggler_factor)
        self._lock = threading.Lock()
        self._work: "queue.Queue[Optional[SlotBatch]]" = queue.Queue()
        self._results: "queue.Queue[SlotResult]" = queue.Queue()
        self._next_batch = 0
        self._inflight: dict[int, SlotBatch] = {}
        now0 = time.monotonic()
        self.rows = [
            _QueryRow(
                carry=jax.tree.map(lambda x, q=q: x[q], carries),
                limit=limits[q],
                budget=max_steps,
                trace=[],
                log=ResultLog(),
                admitted_s=now0,
            )
            for q in range(q_n)
        ]
        self._threads: list[threading.Thread] = []
        # no-overflow guarantee for the composed path: a round inserts at
        # most cohorts × (detector slots per frame) entries per query, and
        # a merge window is exactly one round — keep it under capacity so
        # the source ring can never wrap (MatcherRingOverflow-free)
        struct = jax.eval_shape(
            detector, jax.random.PRNGKey(0), jnp.zeros((), jnp.int32)
        )
        det_slots = (
            int(struct.valid.shape[-1]) if hasattr(struct, "valid") else None
        )
        capacity = int(carries.matcher.times_seen.shape[-1])
        if det_slots is not None and cohorts * det_slots >= capacity:
            raise ValueError(
                f"matcher capacity {capacity} does not cover one round's "
                f"insertion bound (cohorts={cohorts} × {det_slots} detector "
                "slots per frame): the ring could wrap inside a merge "
                "window, which no spill can recover — raise max_results or "
                "lower cohorts"
            )
        self.index = index
        self._warm_frames: frozenset = frozenset()
        if cache_frames:
            if index is not None:
                # preload the device tier from the repository index — an
                # empty tier yields a cache bit-identical to
                # init_detection_cache (the cold-path contract, §13)
                self.cache, self._warm_frames = index.warm(
                    struct, cache_frames
                )
            else:
                self.cache = init_detection_cache(struct, cache_frames)
        else:
            self.cache = None
        self._warm_arr = (
            np.asarray(sorted(self._warm_frames), np.int64)
            if self._warm_frames else None
        )
        # every counter exists from construction so LoweredPlan.run() can
        # package uniform SearchStats even for a run that never merged
        self.stats = {
            "slots": 0, "merges": 0, "reissues": 0, "duplicate_drops": 0,
            "merge_high_water": 0, "rounds": 0, "spilled": 0,
            "detector_invocations": 0, "cache_hits": 0, "index_hits": 0,
            # detector-batch occupancy accounting (RequestBatcher semantics
            # over slot lanes): how many lanes of each emitted SlotBatch
            # carried a live query vs sentinel padding
            "lanes_issued": 0, "lanes_padded": 0,
        }

    # ---- row liveness / elasticity ----------------------------------------

    def _row_live(self, row: _QueryRow) -> bool:
        """The solo driver's continue condition, per row (checked before
        each round, exactly like ``_search_scan_device``'s ``cond``)."""
        return (
            int(row.carry.results) < row.limit
            and int(row.carry.step) < row.budget
            and not bool(jnp.all(row.carry.sampler.exhausted()))
        )

    def _retire(self, row: _QueryRow) -> None:
        """Mask a finished query out of issue and close its trace with the
        unconditional final checkpoint (``run_search_scan`` semantics)."""
        row.active = False
        row.finished_s = time.monotonic()
        row.trace.append((int(row.carry.step), int(row.carry.results)))

    def vacate(self, q: int) -> _QueryRow:
        """Release row ``q``'s slot for reuse by a later ``admit()``.

        The caller (a persistent service) harvests the row's results
        first — the returned row object keeps its carry/trace/log, but the
        SLOT index now belongs to whichever tenant ``admit()`` installs
        next.  Only a row with no slot in flight can be vacated; an
        active row is force-retired (masked out of issue) without the
        final trace checkpoint, which is the prototype-row case of a
        service that starts with an empty pool."""
        with self._lock:
            row = self.rows[q]
            if row.inflight:
                raise RuntimeError(
                    f"row {q} has a slot in flight; merge it before vacating"
                )
            row.active = False
            row.vacant = True
            return row

    def pool_rounds(self) -> int:
        """Pool progress clock: rounds completed by the furthest-ahead
        query.  ``admit`` debits a late joiner's default frame budget by
        ``cohorts × pool_rounds()`` — the frames it missed."""
        return max((r.rounds for r in self.rows), default=0)

    def admit(
        self,
        key: jax.Array,
        *,
        result_limit: int,
        max_steps: Optional[int] = None,
        base_max_steps: Optional[int] = None,
        select_id: Optional[int] = None,
        sampler_init: Optional[SamplerState] = None,
        warm_rounds_saved: int = 0,
    ) -> int:
        """Join a fresh query mid-flight; returns its row index.

        The new row starts from zeroed sampler statistics and an empty
        matcher (same geometry/thresholds as the pool) and is issuable
        from the next ``_issue_ready`` call.  Its frame budget defaults to
        ``base − cohorts × pool_rounds()`` where ``base`` is
        ``base_max_steps`` (a tenant's own requested budget) or the
        pool's ``max_steps`` — a query admitted at round r behaves exactly
        like one present from round 0 whose budget was reduced by the
        frames it missed (the join/retire property,
        tests/test_async_compose.py).  ``max_steps`` overrides the debit
        entirely.  ``select_id`` is handed to the ``select`` predicate in
        place of the row index (tenant→predicate binding, no recompile).
        ``sampler_init`` replaces the zeroed sampler statistics wholesale
        (the index warm-start path: the service injects Thompson priors
        and remains responsible for subtracting them back out when it
        records evidence); ``warm_rounds_saved`` annotates the row's
        accounting.  Vacated slots (``vacate``) are reused before the
        pool grows."""
        proto = self.rows[0].carry
        m0 = proto.matcher
        fresh_matcher = dataclasses.replace(
            m0,
            boxes=jnp.zeros_like(m0.boxes),
            feats=jnp.zeros_like(m0.feats),
            video=jnp.full_like(m0.video, -1),
            frame=jnp.full_like(m0.frame, -(10**9)),
            chunk=jnp.full_like(m0.chunk, -1),
            times_seen=jnp.zeros_like(m0.times_seen),
            cursor=jnp.zeros((), jnp.int32),
            total_inserted=jnp.zeros((), jnp.int32),
        )
        s0 = proto.sampler
        fresh_sampler = dataclasses.replace(
            s0, n1=jnp.zeros_like(s0.n1), n=jnp.zeros_like(s0.n)
        )
        if sampler_init is not None:
            fresh_sampler = sampler_init
        carry = ExSampleCarry(
            sampler=fresh_sampler,
            matcher=fresh_matcher,
            key=key,
            step=jnp.zeros((), jnp.int32),
            results=jnp.zeros((), jnp.int32),
        )
        with self._lock:
            base = self.max_steps if base_max_steps is None else base_max_steps
            budget = (
                max(0, base - self.cohorts * self.pool_rounds())
                if max_steps is None
                else max_steps
            )
            row = _QueryRow(
                carry=carry, limit=int(result_limit), budget=budget,
                trace=[], log=ResultLog(), select_id=select_id,
                admitted_s=time.monotonic(),
                warm_rounds_saved=int(warm_rounds_saved),
            )
            slot = next(
                (i for i, r in enumerate(self.rows) if r.vacant), None
            )
            if slot is None:
                self.rows.append(row)
                return len(self.rows) - 1
            self.rows[slot] = row
            return slot

    # ---- driver side -------------------------------------------------------

    def _issue_ready(self) -> list:
        """Check out a cohort slot for every issuable query (active, live,
        no slot in flight), packed into fixed-shape batches.  Queries that
        are no longer live retire here instead of issuing."""
        with self._lock:
            issuable = []
            for i, row in enumerate(self.rows):
                if not row.active or row.inflight:
                    continue
                if not self._row_live(row):
                    self._retire(row)
                    continue
                issuable.append(i)
            batches = []
            bsz = self.slots_per_batch
            for g in range(0, len(issuable), bsz):
                group = issuable[g:g + bsz]
                pad = bsz - len(group)
                lanes = group + [group[0]] * pad
                active = np.asarray([True] * len(group) + [False] * pad)
                sub = stack_carries([self.rows[i].carry for i in lanes])
                choice = _issue_slots(
                    sub, self.chunks, cohorts=self.cohorts, method=self.method
                )
                select_ids = np.asarray(
                    [
                        self.rows[i].select_id
                        if self.rows[i].select_id is not None
                        else i
                        for i in lanes
                    ],
                    np.int32,
                )
                batch = SlotBatch(
                    batch_id=self._next_batch,
                    query_rows=np.asarray(lanes, np.int32),
                    carry=sub,
                    choice=choice,
                    active=active,
                    select_ids=select_ids,
                )
                self._next_batch += 1
                self.stats["lanes_issued"] += len(group)
                self.stats["lanes_padded"] += pad
                for i in group:
                    self.rows[i].inflight = True
                self._inflight[batch.batch_id] = batch
                self.stats["slots"] += 1
                batches.append(batch)
        for batch in batches:
            self._work.put(batch)
        return batches

    def _merge(self, res: SlotResult) -> None:
        """Apply one slot batch back into the Q-axis rows — at most once.

        The pending set is ``self._inflight``: the first completion of a
        batch removes it under the lock, any later completion (straggler
        re-issue) is dropped and counted.  Fresh detections publish into
        the shared cache (first-write-wins; a concurrent worker detecting
        the same frame re-inserts identical values under a deterministic
        detector), then every active lane's row is REPLACED by its
        post-round state — sound because that lane's rounds are
        serialized, so the worker's output is the row's unique successor.
        Live ring entries the round evicted spill to the row's host
        ``ResultLog`` before the replacement lands."""
        now = time.monotonic()
        with self._lock:
            batch = self._inflight.pop(res.batch_id, None)
            if batch is None:
                self.stats["duplicate_drops"] += 1
                return
            if self.cache is not None:
                self.cache = cache_insert(
                    self.cache, res.aux.flat_frames, res.aux.fresh,
                    res.aux.need,
                )
            self.stats["detector_invocations"] += res.fresh_calls
            self.stats["cache_hits"] += res.cache_hits
            self.stats["merges"] += 1
            self.stats["rounds"] += 1
            # per-lane detector economics: reshape the flat [B = lanes*C]
            # dedup bookkeeping back to (lanes, cohorts) and attribute each
            # fresh detector call / cache hit to the lane that REPRESENTED
            # the frame (duplicates within the batch ride for free, which
            # is exactly the shared-ingest story the service reports).
            lanes_n = len(batch.query_rows)
            need_l = np.asarray(res.aux.need).reshape(lanes_n, -1)
            rep_hit_l = np.asarray(res.aux.rep_hit).reshape(lanes_n, -1)
            if self._warm_arr is not None:
                frames_l = np.asarray(res.aux.flat_frames).reshape(
                    lanes_n, -1
                )
                warm_l = rep_hit_l & np.isin(frames_l, self._warm_arr)
            else:
                warm_l = None
            for lane, qrow in enumerate(batch.query_rows):
                if not batch.active[lane]:
                    continue
                row = self.rows[int(qrow)]
                row.fresh_calls += int(need_l[lane].sum())
                row.cache_hits += int(rep_hit_l[lane].sum())
                if warm_l is not None:
                    lane_ihits = int(warm_l[lane].sum())
                    row.index_hits += lane_ihits
                    self.stats["index_hits"] += lane_ihits
                new_carry = jax.tree.map(
                    lambda x, lane=lane: x[lane], res.carry
                )
                inserted = int(
                    new_carry.matcher.total_inserted
                    - row.carry.matcher.total_inserted
                )
                self.stats["merge_high_water"] = max(
                    self.stats["merge_high_water"], inserted
                )
                if inserted:
                    self.stats["spilled"] += row.log.spill(
                        row.carry.matcher,
                        eviction_mask(row.carry.matcher, inserted),
                    )
                if self.trace_every:
                    s0, s1 = int(row.carry.step), int(new_carry.step)
                    if (s1 // self.trace_every) > (s0 // self.trace_every):
                        row.trace.append((s1, int(new_carry.results)))
                grew = int(new_carry.results) > int(row.carry.results)
                if grew:
                    if not row.first_result_s:
                        row.first_result_s = now
                    row.result_stamps.append((now, int(new_carry.results)))
                row.carry = new_carry
                row.rounds += 1
                row.inflight = False
                if not self._row_live(row):
                    self._retire(row)

    def _reissue(self, batch_id: int) -> None:
        with self._lock:
            batch = self._inflight.get(batch_id)
            if batch is None:
                return
            batch.issue_count += 1
            self.stats["reissues"] += 1
        self._work.put(batch)

    # ---- worker side -------------------------------------------------------

    def _process_batch(self, wid: int, batch: SlotBatch) -> SlotResult:
        """Run the shared dedup + cache + detector round for the slots in
        flight.  Pure of scheduling concerns (tests drive duplicate
        completions synchronously); reads only the batch's own row
        snapshots plus a cache snapshot — never the live rows, which may
        be mid-merge on another thread."""
        with self._lock:
            cache = self.cache
        # query_ids only feeds ``select(qi, dets)`` in the round body, so a
        # tenant's select_id re-binds which predicate its lane evaluates
        # without changing shapes (no recompile); None falls back to the
        # row index, preserving the solo-parity contract.
        qids = jnp.asarray(
            batch.select_ids
            if batch.select_ids is not None
            else batch.query_rows,
            jnp.int32,
        )
        active = jnp.asarray(batch.active)
        out, _cache, fresh_calls, cache_hits, aux = _process_slots(
            batch.carry, cache, self.chunks, qids, active, batch.choice,
            detector=self.detector, select=self.select,
        )
        return SlotResult(
            batch_id=batch.batch_id,
            worker_id=wid,
            carry=out,
            fresh_calls=int(fresh_calls),
            cache_hits=int(cache_hits),
            aux=aux,
        )

    def _worker(self, wid: int) -> None:
        self.monitor.register(wid, now=time.monotonic())
        while True:
            batch = self._work.get()
            if batch is None:
                return
            t0 = time.monotonic()
            self.monitor.assign(wid, batch.batch_id, now=t0)
            self._results.put(self._process_batch(wid, batch))
            now = time.monotonic()
            self.monitor.heartbeat(wid, now)
            self.monitor.record_completion(wid, now - t0, now=now)

    # ---- run loop ----------------------------------------------------------

    def start(self) -> None:
        """Spawn the worker pool once; idempotent.  Service mode keeps the
        pool alive across many ``admit``/``vacate`` cycles — workers block
        on the work queue between batches, they do not poll."""
        if self._threads:
            return
        self._threads = [
            threading.Thread(target=self._worker, args=(w,), daemon=True)
            for w in range(self.num_workers)
        ]
        for t in self._threads:
            t.start()

    def stop(self) -> None:
        """Drain the worker pool (None sentinels) and join; idempotent."""
        threads, self._threads = self._threads, []
        for _ in threads:
            self._work.put(None)
        for t in threads:
            t.join(timeout=5.0)

    def idle(self) -> bool:
        """True when nothing is in flight and no row wants more rounds."""
        with self._lock:
            return not self._inflight and not any(
                r.active for r in self.rows
            )

    def service_tick(self, timeout: float = 0.1) -> bool:
        """One scheduler heartbeat: issue what is issuable, merge at most
        one completed batch, sweep for stragglers.  Returns True if a
        result was merged (False = the wait timed out — callers use this
        to interleave admission work without busy-spinning)."""
        self._issue_ready()
        try:
            res = self._results.get(timeout=timeout)
        except queue.Empty:
            return False
        self._merge(res)
        actions = self.monitor.sweep(time.monotonic())
        for bid in actions["reissue_cohorts"]:
            self._reissue(bid)
        self._issue_ready()
        return True

    def run(self) -> ExSampleCarry:
        """Drive every query to completion; returns the stacked [Q] carry
        (retired rows keep their final state).  Per-query traces are in
        ``self.traces``, spilled results in ``self.logs``."""
        self.start()
        try:
            self._issue_ready()
            while not self.idle():
                if not self.service_tick(timeout=60.0):
                    break
        finally:
            self.stop()
        # rows still active (abnormal exit) close their trace like the
        # scan driver's unconditional final checkpoint
        for row in self.rows:
            if row.active and not row.inflight:
                row.trace.append(
                    (int(row.carry.step), int(row.carry.results))
                )
        return stack_carries([row.carry for row in self.rows])

    @property
    def traces(self) -> list:
        return [row.trace for row in self.rows]

    @property
    def logs(self) -> list:
        return [row.log for row in self.rows]


class ElasticShardedRunner:
    """Elastic mesh-shrink recovery for the composed sharded driver
    (DESIGN.md §14).

    Runs ``run_search_multi_sharded`` in bounded slices of ``sync_windows``
    sync windows.  Every slice returns a fully resumable state (carry +
    hash-sharded cache in direct-mapped layout), so between slices the
    runner heartbeats the live workers and sweeps the
    :class:`~repro.distributed.fault_tolerance.HeartbeatMonitor`.  When a
    sweep returns a dead verdict the runner *drains at the boundary it is
    already standing on* — the in-flight window always completes and its
    merged results are never lost — then shrinks the mesh:

      1. pick the largest shard count ``k`` ≤ surviving workers with
         ``cohorts % k == 0``, validated through
         :func:`repro.distributed.elastic.plan_resize` (empty schema — the
         search carries no sharded params; the check is the data-parallel
         batch divisibility);
      2. re-place the sampler chunk statistics with
         :func:`repro.distributed.elastic.resize_chunk_stats` (strip the
         old shard padding, re-pad for ``k`` — padding never stacks
         across successive shrinks);
      3. re-place the detection cache: the direct-mapped snapshot is
         carried forward as-is when its capacity already divides by ``k``,
         otherwise :func:`repro.serve.batcher.reshard_cache_host` re-hashes
         it to the padded capacity (memoization state — a collision under
         the new modulus costs a future detector call, never correctness);
         ``warm_tag`` is left untouched (its index-hit check uses its own
         capacity modulus);
      4. rebuild a ``("data",)`` mesh over the first ``k`` devices and
         resume — the next slice re-lowers for the new mesh automatically.

    Because dead verdicts are only *acted on* at slice boundaries, a
    worker dying mid-window is deferred to the next boundary by
    construction, and a death during the final window simply never
    triggers a reshard — the search completes on the survivors' already
    merged state.

    Determinism: the random+ sampling stream is keyed per query/round,
    not per shard, and the hash-sharded cache content is a pure
    re-placement of the direct-mapped layout — so replaying the same
    death schedule yields the same result multiset.
    """

    def __init__(
        self,
        carries: ExSampleCarry,
        chunks: ChunkIndex,
        *,
        detector: Callable,
        result_limits,
        max_steps: int,
        num_shards: int,
        cohorts: Optional[int] = None,
        sync_every: int = 1,
        select: Optional[SelectFn] = None,
        cache_frames: int = 0,
        cache=None,
        warm_tag=None,
        monitor: Optional[HeartbeatMonitor] = None,
        clock: Callable[[], float] = time.monotonic,
        sync_windows: int = 1,
    ):
        from repro.launch.mesh import make_data_mesh

        if sync_windows < 1:
            raise ValueError(f"sync_windows={sync_windows} must be >= 1")
        self.carry = carries
        self.chunks = chunks
        self.detector = detector
        self.max_steps = int(max_steps)
        self.num_shards = int(num_shards)
        self.cohorts = int(cohorts) if cohorts is not None else self.num_shards
        self.sync_every = int(sync_every)
        self.select = select
        self.cache_frames = int(cache_frames)
        self.warm_tag = warm_tag
        self.sync_windows = int(sync_windows)
        self.clock = clock
        self.monitor = monitor if monitor is not None else HeartbeatMonitor()
        self.mesh = make_data_mesh(self.num_shards)
        q_n = int(carries.step.shape[0])
        self.result_limits = np.broadcast_to(
            np.asarray(result_limits, np.int32), (q_n,)
        ).copy()
        # workers currently heartbeating; kill_worker() silences one (on a
        # real cluster the process died — heartbeats simply stop arriving)
        self.alive: set[int] = set(range(self.num_shards))
        now = self.clock()
        for w in sorted(self.alive):
            self.monitor.register(w, now)
        self._cache = cache          # direct-mapped snapshot between slices
        if cache is not None:
            from repro.serve.batcher import reshard_cache_host

            cap = int(cache.tag.shape[0])
            self._cache = reshard_cache_host(
                cache, cap + (-cap) % self.num_shards
            )
        self._first_call = True
        self.traces: list[list] = [[] for _ in range(q_n)]
        self.stats = {
            "detector_invocations": 0, "cache_hits": 0, "index_hits": 0,
            "rounds": 0, "merges": 0, "merge_high_water": 0,
            "merge_overflow": False, "frames_sampled": 0,
            "reshard_events": [], "final_cache": None,
        }

    # ---- liveness ----------------------------------------------------------

    def kill_worker(self, worker: int) -> None:
        """Stop heartbeating ``worker`` — the monitor's silence window
        starts now; the dead verdict lands at a later boundary sweep."""
        self.alive.discard(worker)

    def _live_queries(self) -> np.ndarray:
        """Host mirror of the device ``live_mask`` predicate."""
        res = np.asarray(self.carry.results)
        step = np.asarray(self.carry.step)
        n = np.asarray(self.carry.sampler.n)
        frames = np.asarray(self.carry.sampler.frames).astype(n.dtype)
        exhausted = (n >= frames).all(axis=-1)
        return (res < self.result_limits) & (step < self.max_steps) & ~exhausted

    # ---- mesh shrink -------------------------------------------------------

    def _shrink(self, dead: list) -> None:
        from repro.distributed.elastic import plan_resize, resize_chunk_stats
        from repro.launch.mesh import make_data_mesh

        survivors = sorted(self.alive)
        if not survivors:
            raise RuntimeError("elastic shrink: no surviving workers")
        new_shards = None
        for k in range(min(len(survivors), self.num_shards), 0, -1):
            if self.cohorts % k:
                continue
            plan = plan_resize(
                {}, make_data_mesh(k), global_batch=self.cohorts
            )
            if plan.feasible:
                new_shards = k
                break
        if new_shards is None:
            raise RuntimeError(
                f"elastic shrink: no feasible shard count <= "
                f"{len(survivors)} survivors for cohorts={self.cohorts}"
            )
        n1, n, frames = resize_chunk_stats(
            self.carry.sampler.n1,
            self.carry.sampler.n,
            self.carry.sampler.frames,
            new_shards,
        )
        # every leaf still lives on the OLD mesh's devices; pull to host so
        # the next slice's lowering re-places it on the survivors' mesh
        self.carry = jax.tree.map(
            np.asarray,
            dataclasses.replace(
                self.carry,
                sampler=dataclasses.replace(
                    self.carry.sampler, n1=n1, n=n, frames=frames
                ),
            ),
        )
        if self._cache is not None:
            from repro.serve.batcher import reshard_cache_host

            self._cache = jax.tree.map(np.asarray, self._cache)
            cap = int(self._cache.tag.shape[0])
            self._cache = reshard_cache_host(
                self._cache, cap + (-cap) % new_shards
            )
        if self.warm_tag is not None:
            self.warm_tag = np.asarray(self.warm_tag)
        self.stats["reshard_events"].append({
            "window": self.stats["merges"],
            "from_shards": self.num_shards,
            "to_shards": new_shards,
            "dead": sorted(dead),
        })
        self.num_shards = new_shards
        self.mesh = make_data_mesh(new_shards)

    # ---- execution ---------------------------------------------------------

    def step(self) -> bool:
        """Run one bounded slice + one boundary sweep.  Returns True while
        live queries remain."""
        from repro.core.executor import run_search_multi_sharded

        out, traces, stats = run_search_multi_sharded(
            self.carry,
            self.chunks,
            mesh=self.mesh,
            detector=self.detector,
            result_limits=self.result_limits,
            max_steps=self.max_steps,
            cohorts=self.cohorts,
            sync_every=self.sync_every,
            select=self.select,
            cache_frames=self.cache_frames if self._first_call else 0,
            cache=self._cache,
            warm_tag=self.warm_tag,
            window_limit=self.sync_windows,
        )
        self._first_call = False
        self.carry = out
        self._cache = stats["final_cache"]
        for q, t in enumerate(traces):
            self.traces[q].extend(t)
        self.stats["detector_invocations"] += stats["detector_invocations"]
        self.stats["cache_hits"] += stats["cache_hits"]
        self.stats["index_hits"] += stats["index_hits"]
        self.stats["rounds"] += stats["rounds"]
        self.stats["merges"] += stats["merges"]
        self.stats["merge_high_water"] = max(
            self.stats["merge_high_water"], stats["merge_high_water"]
        )
        self.stats["merge_overflow"] |= stats["merge_overflow"]
        if not self._live_queries().any():
            return False
        now = self.clock()
        for w in sorted(self.alive):
            self.monitor.heartbeat(w, now)
        verdict = self.monitor.sweep(now)
        dead = [w for w in verdict["dead"] if w < self.num_shards]
        if dead:
            self._shrink(dead)
        return True

    def run(self):
        """Drive every query to completion; returns ``(carry, traces,
        stats)`` with the same shapes as ``run_search_multi_sharded`` plus
        ``stats["reshard_events"]``."""
        # a live query advances `cohorts` steps every window, so this many
        # slices always suffice; exceeding it means the driver stalled
        budget = self.max_steps // (self.cohorts * self.sync_windows) + 2
        while self.step():
            budget -= 1
            if budget < 0:
                raise RuntimeError("elastic runner made no progress")
        self.stats["frames_sampled"] = int(
            np.asarray(self.carry.step).sum()
        )
        self.stats["final_cache"] = self._cache
        return self.carry, self.traces, self.stats
