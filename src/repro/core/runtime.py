"""Asynchronous search runtime — the production service around Algorithm 1.

The paper sketches asynchronous distributed execution (§3.7.1: "workers
processing a batch of frames at a time without waiting for other workers…
all updates are commutative").  This module is that sketch made concrete:

  * a driver owns the sampler/matcher state and a cohort queue;
  * N workers pull cohorts and process each one as a SINGLE scanned
    device call (``_process_cohort``: a ``lax.fori_loop`` over the
    cohort's frames — one dispatch per cohort, not per frame), then push
    delta statistics back whenever they finish — no barriers;
  * the driver merges deltas commutatively (`merge_deltas`), re-samples
    new cohorts from the freshest state, monitors worker health
    (`HeartbeatMonitor`) and re-issues cohorts from dead/straggling
    workers (at-most-once *effect*: a duplicated frame perturbs one
    sample, which the estimator tolerates — DESIGN.md §5).

The runtime is deterministic under a virtual clock for testing; the
worker pool is threads (the detector releases the GIL under jax) — on a
real deployment each worker is a pod client.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chunks import ChunkIndex
from repro.core.distributed import merge_deltas
from repro.core.exsample import ExSampleCarry, _process_frame
from repro.core.matcher import MatcherState, merge_matcher_checked
from repro.core.thompson import choose_chunks
from repro.distributed.fault_tolerance import HeartbeatMonitor


class MatcherRingOverflow(RuntimeError):
    """A worker inserted ≥ capacity results between snapshot and merge: the
    ring wrapped, entries are unrecoverable, and a silent merge would
    under-count.  Raised instead of wrapping (ROADMAP ring-wrap guard);
    deployments should size ``max_results`` ≫ cohort result rates or merge
    more often."""


@partial(jax.jit, static_argnames=("detector",))
def _process_cohort(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    chunk_ids: jax.Array,   # i32[B]
    det_keys: jax.Array,    # key[B]
    *,
    detector: Callable,
) -> ExSampleCarry:
    """Process a whole cohort as ONE device call (DESIGN.md §7).

    The per-frame Python loop this replaces paid one jit dispatch per
    frame; here the B matcher-sequential frame updates fold under a
    single ``lax.fori_loop`` so a worker's cohort costs one dispatch
    regardless of B.
    """
    def body(i, c):
        return _process_frame(c, chunks, detector, chunk_ids[i], det_keys[i])

    return jax.lax.fori_loop(0, chunk_ids.shape[0], body, carry)


@dataclasses.dataclass
class Cohort:
    cohort_id: int
    chunk_ids: np.ndarray      # i64[B]
    issue_count: int = 0       # >1 ⇒ re-issued (straggler/death)


@dataclasses.dataclass
class WorkerResult:
    cohort_id: int
    worker_id: int
    delta_n1: jax.Array
    delta_n: jax.Array
    new_results: int
    frames: int
    matcher: Optional[MatcherState] = None       # worker's final result memory
    snap_matcher: Optional[MatcherState] = None  # memory at the snapshot


class AsyncSearchDriver:
    """Cohort scheduler + state owner.  Thread-safe, barrier-free."""

    def __init__(
        self,
        carry: ExSampleCarry,
        chunks: ChunkIndex,
        detector: Callable,
        *,
        cohort_size: int = 8,
        num_workers: int = 4,
        result_limit: int = 50,
        max_frames: int = 100_000,
        straggler_factor: float = 4.0,
    ):
        self.carry = carry
        self.chunks = chunks
        self.detector = detector
        self.cohort_size = cohort_size
        self.result_limit = result_limit
        self.max_frames = max_frames
        self.monitor = HeartbeatMonitor(straggler_factor=straggler_factor)
        self._lock = threading.Lock()
        self._work: "queue.Queue[Optional[Cohort]]" = queue.Queue()
        self._results: "queue.Queue[WorkerResult]" = queue.Queue()
        self._next_cohort = 0
        self._inflight: dict[int, Cohort] = {}
        self.num_workers = num_workers
        self.stats = {
            "cohorts": 0, "reissues": 0, "merges": 0, "duplicate_drops": 0,
            "merge_high_water": 0,
        }

    # ---- driver side -------------------------------------------------------

    def _issue_cohort(self) -> None:
        with self._lock:
            key = jax.random.fold_in(self.carry.key, self._next_cohort)
            chunk_ids = np.asarray(
                choose_chunks(key, self.carry.sampler, cohorts=self.cohort_size)
            )
            cohort = Cohort(self._next_cohort, chunk_ids)
            self._next_cohort += 1
            self._inflight[cohort.cohort_id] = cohort
            self.stats["cohorts"] += 1
        self._work.put(cohort)

    def _merge(self, res: WorkerResult) -> None:
        """Fold one worker result into the shared carry — sampler deltas,
        counters AND matcher memory under a single lock acquisition.
        The matcher is *merged* (new entries appended, seen-count bumps
        added — ``merge_matcher``), not replaced: a concurrent merge can
        neither double-count results nor drop another worker's matcher
        insertions.  Cross-worker duplicate detections remain possible —
        the at-most-once-*effect* tolerance, DESIGN.md §5.

        A cohort is merged AT MOST ONCE: ``HeartbeatMonitor`` re-issues a
        straggler's cohort, so two completions of the same cohort can
        land; folding both double-counts sampler deltas, ``step``,
        ``results`` and matcher insertions.  The pending set is
        ``self._inflight`` — the first completion removes the cohort under
        the lock, any later completion of the same cohort is dropped (and
        counted in ``stats["duplicate_drops"]``).

        Ring-wrap guard (ROADMAP): the per-merge insertion count is
        surfaced as ``stats["merge_high_water"]`` and a merge whose
        insertions reached the ring capacity raises
        ``MatcherRingOverflow`` instead of silently aliasing the append
        window."""
        with self._lock:
            if res.cohort_id not in self._inflight:
                self.stats["duplicate_drops"] += 1
                return
            del self._inflight[res.cohort_id]
            sampler = merge_deltas(self.carry.sampler, res.delta_n1, res.delta_n)
            matcher = self.carry.matcher
            if res.matcher is not None:
                matcher, mstats = merge_matcher_checked(
                    matcher, res.matcher, res.snap_matcher
                )
                self.stats["merge_high_water"] = max(
                    self.stats["merge_high_water"], int(mstats.inserted)
                )
                if bool(mstats.overflow):
                    raise MatcherRingOverflow(
                        f"cohort {res.cohort_id}: {int(mstats.inserted)} "
                        f"insertions into a capacity-"
                        f"{matcher.capacity} result ring"
                    )
            self.carry = dataclasses.replace(
                self.carry,
                sampler=sampler,
                matcher=matcher,
                step=self.carry.step + res.frames,
                results=self.carry.results + res.new_results,
            )
            self.stats["merges"] += 1

    def _reissue(self, cohort_id: int) -> None:
        with self._lock:
            cohort = self._inflight.get(cohort_id)
            if cohort is None:
                return
            cohort.issue_count += 1
            self.stats["reissues"] += 1
        self._work.put(cohort)

    # ---- worker side -------------------------------------------------------

    def _process_one(self, wid: int, cohort: Cohort) -> WorkerResult:
        """Process one cohort against a locked snapshot of the shared carry.

        Snapshot the shared carry under the lock and compute EVERY delta
        against that snapshot — reading self.carry again after processing
        would race with concurrent merges (double-counted results / lost
        matcher updates).  Pure of scheduling concerns so tests can drive
        duplicate completions synchronously.
        """
        with self._lock:
            snapshot = self.carry
        b = len(cohort.chunk_ids)
        # nested fold_in: unique per (cohort, frame) for ANY cohort size
        # (a flat cohort_id*stride + i scheme collides once b > stride)
        base = jax.random.fold_in(jax.random.PRNGKey(7), cohort.cohort_id)
        det_keys = jax.vmap(
            lambda i: jax.random.fold_in(base, i)
        )(jnp.arange(b, dtype=jnp.int32))
        local = _process_cohort(
            snapshot,
            self.chunks,
            jnp.asarray(cohort.chunk_ids, jnp.int32),
            det_keys,
            detector=self.detector,
        )
        return WorkerResult(
            cohort_id=cohort.cohort_id,
            worker_id=wid,
            delta_n1=local.sampler.n1 - snapshot.sampler.n1,
            delta_n=local.sampler.n - snapshot.sampler.n,
            new_results=int(local.results - snapshot.results),
            frames=b,
            matcher=local.matcher,           # merged atomically…
            snap_matcher=snapshot.matcher,   # …against this baseline
        )

    def _worker(self, wid: int) -> None:
        self.monitor.register(wid, now=time.monotonic())
        while True:
            cohort = self._work.get()
            if cohort is None:
                return
            self.monitor.assign(wid, cohort.cohort_id)
            t0 = time.monotonic()
            self._results.put(self._process_one(wid, cohort))
            now = time.monotonic()
            self.monitor.heartbeat(wid, now)
            self.monitor.record_completion(wid, now - t0)

    # ---- run loop ----------------------------------------------------------

    def run(self) -> ExSampleCarry:
        threads = [
            threading.Thread(target=self._worker, args=(w,), daemon=True)
            for w in range(self.num_workers)
        ]
        for t in threads:
            t.start()
        # keep the pipeline full: workers+1 outstanding cohorts
        for _ in range(self.num_workers + 1):
            self._issue_cohort()
        try:
            while (
                int(self.carry.results) < self.result_limit
                and int(self.carry.step) < self.max_frames
            ):
                try:
                    res = self._results.get(timeout=60.0)
                except queue.Empty:
                    break
                self._merge(res)
                actions = self.monitor.sweep(time.monotonic())
                for cid in actions["reissue_cohorts"]:
                    self._reissue(cid)
                self._issue_cohort()
        finally:
            # always shut the pool down — a raising merge (e.g.
            # MatcherRingOverflow) must not leak blocked worker threads
            for _ in threads:
                self._work.put(None)
            for t in threads:
                t.join(timeout=5.0)
        return self.carry
