"""Distributed ExSample runtime (paper §3.7.1 extended to multi-pod).

The paper observes all sampler updates are additive/commutative and sketches
an asynchronous distributed execution.  This module realizes it on a JAX
mesh:

  * chunk statistics are sharded over the ``data`` axis (and replicated over
    ``model`` / ``pod``) — each data shard owns M/|data| chunks;
  * cohort selection runs under ``shard_map``: every shard Thompson-samples
    its local chunks, then the *global* top cohort indices are recovered with
    an all-gather of per-shard (score, index) winners — collective volume is
    O(cohorts × |data|) scalars, negligible next to detector compute;
  * workers accumulate *delta* statistics locally and merge them with a
    `psum` every ``sync_every`` rounds ("eventual-consistency Thompson") —
    staleness only widens the posterior noise, which Thompson tolerates; the
    merge schedule is the straggler-mitigation lever: a late worker's delta
    joins whenever it lands, nobody barriers inside a round.

These functions are written against an abstract mesh so the same code runs
on the 2-device test mesh and the 512-chip production mesh.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.state import SamplerState
from repro.core.thompson import gamma_params, wilson_hilferty


def get_shard_map():
    """``shard_map`` across JAX versions: newer releases promote it to
    ``jax.shard_map`` AND rename the ``check_rep`` kwarg to ``check_vma``;
    older ones only have ``jax.experimental.shard_map``.  Callers keep the
    old ``check_rep=...`` spelling and the returned wrapper translates (or
    drops) it when the resolved function doesn't accept it.  Same
    feature-detect pattern as ``launch/mesh.py`` (AxisType) and
    ``distributed/compression.py`` (``lax.axis_size``)."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm

    import inspect

    try:
        params = inspect.signature(sm).parameters
    except (TypeError, ValueError):  # C-level/odd callables: pass through
        return sm

    def shard_map_compat(f, **kwargs):
        if "check_rep" in kwargs and "check_rep" not in params:
            v = kwargs.pop("check_rep")
            if "check_vma" in params:
                kwargs["check_vma"] = v
        return sm(f, **kwargs)

    return shard_map_compat


def shard_sampler_state(state: SamplerState, mesh: Mesh, axis: str = "data"):
    """Place chunk-stat arrays sharded over ``axis`` (M must divide evenly;
    pad_chunks() handles ragged M)."""
    sh = NamedSharding(mesh, P(axis))
    return jax.tree.map(
        lambda x: jax.device_put(x, sh) if x.ndim == 1 else x, state
    )


def pad_chunks(state: SamplerState, multiple: int) -> SamplerState:
    """Pad chunk arrays to a multiple of the shard count with exhausted
    dummy chunks (frames=0 ⇒ never selected).  Pads the LAST axis, so the
    same helper serves the solo sharded driver ([M] stats) and the
    composed multi-query driver ([Q, M] stats) — one fill-value contract
    for both (the composed bit-parity tests pin it)."""
    m = state.n1.shape[-1]
    pad = (-m) % multiple
    if pad == 0:
        return state
    import dataclasses as _dc

    f = lambda x, fill: jnp.concatenate(
        [x, jnp.full(x.shape[:-1] + (pad,), fill, x.dtype)], axis=-1
    )
    return _dc.replace(
        state,
        n1=f(state.n1, 0),
        n=f(state.n, 1),       # n>0, frames=0 ⇒ exhausted
        frames=f(state.frames, 0),
    )


def local_cohort_winners(
    key: jax.Array,
    alpha_l: jax.Array,      # f32[local_m] — this shard's slice
    beta_l: jax.Array,       # f32[local_m]
    exhausted_l: jax.Array,  # bool[local_m]
    n_l: jax.Array,          # f32[local_m] — samples drawn per local chunk
    *,
    axis: str,
    cohorts: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-shard body of the globally-consistent Thompson choice — called
    INSIDE ``shard_map`` (by ``distributed_choose`` and by the sharded
    search driver's resident loop, which cannot nest another shard_map).

    Every shard draws WH-approximate gamma scores for its local chunks and
    reduces to its per-cohort local winner; the (score, global index,
    winner's n) triples are all-gathered and the global argmax is computed
    redundantly on all shards (deterministic).  Collective volume is
    O(cohorts × |shards|) scalars.  Returns replicated
    (i32[cohorts] global chunk ids, f32[cohorts] winning scores — −inf iff
    every chunk everywhere is exhausted, f32[cohorts] the owning shard's
    sample count for each winner — the random+ rank base).
    """
    local_m = alpha_l.shape[0]
    shard_id = jax.lax.axis_index(axis)
    # decorrelate shards; fold_in is cheap and deterministic
    k = jax.random.fold_in(key, shard_id)
    z = jax.random.normal(k, (cohorts, local_m), alpha_l.dtype)
    scores = wilson_hilferty(alpha_l[None, :], z) / beta_l[None, :]
    scores = jnp.where(exhausted_l[None, :], -jnp.inf, scores)
    local_best = jnp.argmax(scores, axis=-1)                    # [C]
    local_score = jnp.take_along_axis(
        scores, local_best[:, None], axis=-1
    )[:, 0]                                                     # [C]
    global_idx = shard_id * local_m + local_best
    local_n = n_l[local_best]
    # gather winners from every shard: [shards, C]
    all_scores = jax.lax.all_gather(local_score, axis)
    all_idx = jax.lax.all_gather(global_idx, axis)
    all_n = jax.lax.all_gather(local_n, axis)
    win = jnp.argmax(all_scores, axis=0)                        # [C]
    pick = lambda a: jnp.take_along_axis(a, win[None, :], axis=0)[0]
    return (
        pick(all_idx).astype(jnp.int32),
        pick(all_scores),
        pick(all_n),
    )


def local_cohort_winners_batched(
    keys: jax.Array,         # key[Q] — one PRNG key per query
    alpha_l: jax.Array,      # f32[Q, local_m] — this shard's slice, per query
    beta_l: jax.Array,       # f32[Q, local_m]
    exhausted_l: jax.Array,  # bool[Q, local_m]
    n_l: jax.Array,          # f32[Q, local_m]
    *,
    axis: str,
    cohorts: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Leading-[Q] ``local_cohort_winners`` for the composed multi-query ×
    sharded driver (DESIGN.md §10): Q queries' globally-consistent Thompson
    choices in ONE pass of collectives — the all-gathers carry [S, Q, C]
    instead of vmapping a collective per query.

    Contract: row q is bit-identical to ``local_cohort_winners(keys[q],
    alpha_l[q], …)`` — same per-query fold_in(key, shard_id) decorrelation,
    same WH draw shapes, same replicated global argmax — which is what
    makes the composed driver's per-query parity with
    the solo sharded driver testable.  Returns replicated
    (i32[Q, cohorts], f32[Q, cohorts] scores, f32[Q, cohorts] rank bases).
    """
    local_m = alpha_l.shape[-1]
    shard_id = jax.lax.axis_index(axis)
    k = jax.vmap(lambda kk: jax.random.fold_in(kk, shard_id))(keys)
    z = jax.vmap(
        lambda kk: jax.random.normal(kk, (cohorts, local_m), alpha_l.dtype)
    )(k)                                                        # [Q, C, lm]
    scores = wilson_hilferty(alpha_l[:, None, :], z) / beta_l[:, None, :]
    scores = jnp.where(exhausted_l[:, None, :], -jnp.inf, scores)
    local_best = jnp.argmax(scores, axis=-1)                    # [Q, C]
    local_score = jnp.take_along_axis(
        scores, local_best[..., None], axis=-1
    )[..., 0]                                                   # [Q, C]
    global_idx = shard_id * local_m + local_best
    local_n = jnp.take_along_axis(n_l, local_best, axis=-1)
    all_scores = jax.lax.all_gather(local_score, axis)          # [S, Q, C]
    all_idx = jax.lax.all_gather(global_idx, axis)
    all_n = jax.lax.all_gather(local_n, axis)
    win = jnp.argmax(all_scores, axis=0)                        # [Q, C]
    pick = lambda a: jnp.take_along_axis(a, win[None], axis=0)[0]
    return (
        pick(all_idx).astype(jnp.int32),
        pick(all_scores),
        pick(all_n),
    )


@partial(jax.jit, static_argnames=("cohorts", "axis", "mesh"))
def distributed_choose(
    key: jax.Array,
    state: SamplerState,
    *,
    mesh: Mesh,
    cohorts: int,
    axis: str = "data",
) -> jax.Array:
    """Globally-consistent batched Thompson choice over sharded stats
    (the standalone shard_map wrapper around ``local_cohort_winners``).
    Returns replicated i32[cohorts] of *global* chunk ids.
    """
    num_shards = mesh.shape[axis]
    m = state.num_chunks
    assert m % num_shards == 0, "call pad_chunks() first"

    alpha, beta = gamma_params(state)
    exhausted = state.exhausted()

    def local_choice(key, alpha_l, beta_l, exhausted_l, n_l):
        idx, _, _ = local_cohort_winners(
            key, alpha_l, beta_l, exhausted_l, n_l, axis=axis, cohorts=cohorts
        )
        return idx

    specs = P(axis)
    choice = get_shard_map()(
        local_choice,
        mesh=mesh,
        in_specs=(P(), specs, specs, specs, specs),
        out_specs=P(),
        check_rep=False,
    )(key, alpha, beta, exhausted, state.n)
    return choice


@jax.jit
def merge_deltas(
    state: SamplerState, delta_n1: jax.Array, delta_n: jax.Array
) -> SamplerState:
    """Merge per-worker delta statistics into the state.

    ``delta_*`` are stacked per-worker updates ``[W, M]`` (or a single
    ``[M]`` delta).  Additivity makes the merge exact regardless of
    interleaving — the §3.7.1 argument.  On a multi-controller deployment
    the identical reduction is one ``psum`` over the ``data`` axis of each
    process's local delta buffer (shard_map with replicated specs); in the
    single-controller runtime the workers' buffers arrive stacked, so the
    merge is a plain sum over the worker axis — same semantics, no
    collective theater.
    """
    import dataclasses as _dc

    d1 = jnp.atleast_2d(delta_n1).sum(axis=0)
    dn = jnp.atleast_2d(delta_n).sum(axis=0)
    return _dc.replace(state, n1=state.n1 + d1, n=state.n + dn)


def straggler_robust_rounds(
    worker_latencies: jnp.ndarray, sync_every: int, round_time: float
) -> jnp.ndarray:
    """Analytic model used by tests/benchmarks: with barrier-per-round, the
    round time is max(latencies); with commutative async merge the effective
    round time is mean(latencies) + sync cost amortized over sync_every.
    Returns (barrier_time, async_time) per round."""
    barrier = jnp.max(worker_latencies)
    async_ = jnp.mean(worker_latencies) + round_time / max(sync_every, 1)
    return jnp.stack([barrier, async_])
