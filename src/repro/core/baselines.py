"""Baseline frame-selection policies (paper §2.3, §4).

All baselines share ExSample's frame-processing path (detector + matcher +
stats) and differ only in *which frame is processed next*:

  * ``random``      — uniform with replacement over all frames.
  * ``randomplus``  — §3.7.2 stratified bit-reversal order over the dataset
                      (the paper's strongest non-adaptive baseline and the
                      denominator of every savings number).
  * ``sequential``  — scan frames in order (the naive full-scan).
  * ``skip``        — sequential with a fixed stride (e.g. 1 frame/second).
  * ``greedy``      — argmax of the raw N¹/n point estimate (no Thompson
                      noise); the ablation showing why randomization matters.
  * ``surrogate``   — BlazeIt-style: scores every frame with a cheap model
                      (descending-score processing) after a labelling +
                      training + scoring preamble; cost accounting for the
                      preamble lives in ``repro.sim.costmodel``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chunks import ChunkIndex, global_randomplus_order
from repro.core.exsample import DetectorFn, ExSampleCarry, _process_frame
from repro.core.state import point_estimate


def _chunk_of_frame(chunks: ChunkIndex, frame: jax.Array) -> jax.Array:
    """Map a global frame id to its chunk id (searchsorted over starts)."""
    return (
        jnp.searchsorted(chunks.start, frame, side="right").astype(jnp.int32) - 1
    )


@partial(jax.jit, static_argnames=("detector",))
def fixed_frame_step(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    frame_id: jax.Array,
    *,
    detector: DetectorFn,
) -> ExSampleCarry:
    """Process one externally-chosen frame (drives every static policy)."""
    key, k_det = jax.random.split(carry.key)
    carry = dataclasses.replace(carry, key=key)
    chunk_id = _chunk_of_frame(chunks, frame_id)
    return _process_frame(carry, chunks, detector, chunk_id, k_det)


@partial(jax.jit, static_argnames=("detector",))
def greedy_step(
    carry: ExSampleCarry, chunks: ChunkIndex, *, detector: DetectorFn
) -> ExSampleCarry:
    """Greedy point-estimate policy (ties broken by chunk id)."""
    key, k_det = jax.random.split(carry.key)
    carry = dataclasses.replace(carry, key=key)
    chunk_id = jnp.argmax(point_estimate(carry.sampler)).astype(jnp.int32)
    return _process_frame(carry, chunks, detector, chunk_id, k_det)


class FrameSchedule:
    """Host-side frame-order generators for the static policies."""

    @staticmethod
    def random(total_frames: int, max_steps: int, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        return rng.integers(0, total_frames, size=max_steps, dtype=np.int64)

    @staticmethod
    def randomplus(total_frames: int, max_steps: int, seed: int = 0) -> np.ndarray:
        order = global_randomplus_order(total_frames, seed=seed)
        reps = int(np.ceil(max_steps / len(order)))
        return np.tile(order, reps)[:max_steps]

    @staticmethod
    def sequential(total_frames: int, max_steps: int, seed: int = 0) -> np.ndarray:
        return np.arange(max_steps, dtype=np.int64) % total_frames

    @staticmethod
    def skip(
        total_frames: int, max_steps: int, stride: int = 30, seed: int = 0
    ) -> np.ndarray:
        return (np.arange(max_steps, dtype=np.int64) * stride) % total_frames


def run_schedule(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    schedule: np.ndarray,
    *,
    detector: DetectorFn,
    result_limit: int,
    trace_every: int = 0,
):
    """Drive a static policy until result_limit / schedule exhaustion."""
    trace = []
    for frame in schedule:
        carry = fixed_frame_step(
            carry, chunks, jnp.asarray(int(frame), jnp.int32), detector=detector
        )
        if trace_every and int(carry.step) % trace_every == 0:
            trace.append((int(carry.step), int(carry.results)))
        if int(carry.results) >= result_limit:
            break
    trace.append((int(carry.step), int(carry.results)))
    return carry, trace


def run_greedy(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    *,
    detector: DetectorFn,
    result_limit: int,
    max_steps: int,
    trace_every: int = 0,
):
    trace = []
    while int(carry.results) < result_limit and int(carry.step) < max_steps:
        carry = greedy_step(carry, chunks, detector=detector)
        if trace_every and int(carry.step) % trace_every == 0:
            trace.append((int(carry.step), int(carry.results)))
    trace.append((int(carry.step), int(carry.results)))
    return carry, trace


def surrogate_schedule(
    scores: np.ndarray, *, dedup_window: int = 0
) -> np.ndarray:
    """BlazeIt-style descending-score order with optional fixed-time
    dedup suppression (the paper notes BlazeIt skips a fixed window around
    returned frames to avoid obvious duplicates)."""
    order = np.argsort(-scores, kind="stable")
    if dedup_window <= 1:
        return order.astype(np.int64)
    taken: list[int] = []
    blocked = np.zeros(len(scores), bool)
    for f in order:
        if not blocked[f]:
            taken.append(int(f))
            lo = max(0, f - dedup_window)
            hi = min(len(scores), f + dedup_window)
            blocked[lo:hi] = True
    # after suppression rounds, append remaining frames by score
    rest = [int(f) for f in order if int(f) not in set(taken)]
    return np.asarray(taken + rest, dtype=np.int64)
