"""Good-Turing machinery from paper §3.1 and §3.3.

Implements the estimator, its bias bounds (Theorem *Bias*), the variance
bound (Theorem *Variance*), and the Poisson characterization of N¹(n) —
both as analysis utilities and as invariants exercised by the property
tests (``tests/test_good_turing.py``).

Everything here is pure jnp and differentiable where meaningful.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def estimator(n1: jax.Array, n: jax.Array) -> jax.Array:
    """R(n+1) ≈ N¹(n)/n   (Eq. 1 / Eq. 7)."""
    return n1 / jnp.maximum(n, 1.0)


def pi_first_at(p: jax.Array, n: jax.Array) -> jax.Array:
    """π_i(n) = p_i (1-p_i)^(n-1): chance result i appears first at sample n."""
    return p * (1.0 - p) ** (n - 1.0)


def expected_new(p: jax.Array, n: jax.Array) -> jax.Array:
    """E[R(n+1)] = Σ_i p_i (1-p_i)^n  — expected new results on sample n+1."""
    return jnp.sum(p * (1.0 - p) ** n)


def expected_n1(p: jax.Array, n: jax.Array) -> jax.Array:
    """E[N¹(n)] = n Σ_i π_i(n) = n Σ_i p_i (1-p_i)^(n-1)."""
    return n * jnp.sum(pi_first_at(p, n))


def expected_estimate(p: jax.Array, n: jax.Array) -> jax.Array:
    """E[N¹(n)]/n = Σ_i π_i(n)."""
    return jnp.sum(pi_first_at(p, n))


class BiasBounds(NamedTuple):
    """rel.err bounds of Theorem (Bias): 0 ≤ rel.err ≤ min(max_p, sqrtN_term)."""

    rel_err: jax.Array        # exact relative bias (needs ground-truth p)
    max_p_bound: jax.Array    # Eq. 3:  max_i p_i
    moment_bound: jax.Array   # Eq. 4:  sqrt(N) (mu_p + sigma_p)


def bias_bounds(p: jax.Array, n: jax.Array) -> BiasBounds:
    """Evaluate the exact relative bias and both paper bounds.

    rel.err = (E[N¹(n)]/n − E[R(n+1)]) / (E[N¹(n)]/n)
    """
    est = expected_estimate(p, n)
    truth = expected_new(p, n)
    rel_err = (est - truth) / jnp.maximum(est, jnp.finfo(est.dtype).tiny)
    num_results = jnp.asarray(p.shape[0], p.dtype)
    mu = jnp.mean(p)
    sigma = jnp.std(p)
    return BiasBounds(
        rel_err=rel_err,
        max_p_bound=jnp.max(p),
        moment_bound=jnp.sqrt(num_results) * (mu + sigma),
    )


def variance_bound(p: jax.Array, n: jax.Array) -> jax.Array:
    """Theorem (Variance): Var[N¹(n)/n] ≤ E[N¹(n)]/n²  (under independence)."""
    return expected_n1(p, n) / jnp.maximum(n, 1.0) ** 2


def exact_variance(p: jax.Array, n: jax.Array) -> jax.Array:
    """Exact Var[N¹(n)/n] under independent Bernoulli instances:
    Σ_i π_i(n)(1−π_i(n)) / n²."""
    pi = pi_first_at(p, n)
    return jnp.sum(pi * (1.0 - pi)) / jnp.maximum(n, 1.0) ** 2


def poisson_rate(p: jax.Array, n: jax.Array) -> jax.Array:
    """λ of the limiting Poisson law of N¹(n):  λ = E[N¹(n)] = n·Σ_i π_i(n).

    (The paper's §3.3 proof uses π_i to mean n·p_i(1-p_i)^{n-1} — the
    probability instance i was seen *exactly once in n draws* — while its
    Appendix A defines π_i without the n factor; the Poisson parameter is
    the exactly-once total, i.e. E[N¹].)
    """
    return n * jnp.sum(pi_first_at(p, n))


def simulate_counts(
    key: jax.Array, p: jax.Array, num_samples: int
) -> tuple[jax.Array, jax.Array]:
    """Monte-Carlo draw of (N¹(n), seen-set size) after ``num_samples``
    random frames, used by the §3.3.2-style validation benchmarks.

    Each frame shows instance i independently with probability p_i.  Returns
    (times_seen i32[N], n).  Runs as one vectorized binomial draw per
    instance — statistically identical to the frame-by-frame loop because
    per-frame occupancy draws are i.i.d. across frames.
    """
    times_seen = jax.random.binomial(key, num_samples, p).astype(jnp.int32)
    return times_seen, jnp.asarray(num_samples, jnp.int32)


def n1_from_counts(times_seen: jax.Array) -> jax.Array:
    return jnp.sum(times_seen == 1).astype(jnp.float32)


def remaining_value(p: jax.Array, times_seen: jax.Array) -> jax.Array:
    """True R(n+1) = Σ_i [i ∉ seen] p_i given simulated sighting counts."""
    return jnp.sum(jnp.where(times_seen == 0, p, 0.0))
