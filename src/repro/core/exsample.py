"""ExSample Algorithm 1 — single-step, batched-cohort and scanned drivers.

The loop is expressed as a pure step function over an ``ExSampleCarry``
pytree so it can be (a) jitted and scanned for simulation-scale benchmarks,
(b) driven frame-by-frame from the host around a real serving stack, and
(c) sharded (see ``repro.core.distributed``).

Four driver implementations share the step/process machinery (DESIGN.md
§7-§9): ``_host_search`` is the host reference loop (one dispatch + one
sync per step), ``_scan_search`` is the device-resident
``lax.while_loop`` production driver — identical (step, results)
trajectory, one host sync total — ``_sharded_search`` is the mesh-scale
variant: the same resident loop under ``shard_map`` with chunk
statistics sharded over the ``data`` axis and per-shard matchers merged
every ``sync_every`` rounds (eventual-consistency Thompson, DESIGN.md
§8) — and ``_multi_search`` advances Q concurrent queries (leading-[Q]
carry) sharing one deduplicated + cached detector pass per round
(DESIGN.md §9).  The ONE public entry point over all of them (plus the
composed Q×shards lowering and the async runtime) is
``repro.core.plan.SearchPlan`` (DESIGN.md §10); the legacy
``run_search*`` functions at the bottom of this module are deprecated
shims over the equivalent plans.

Detector plug-in protocol:  ``detector(key, frame_id) -> Detections``
(see ``repro.sim.oracle.Detections``).  The oracle/noisy/neural detectors
all satisfy it.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import TYPE_CHECKING, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import thompson
from repro.core.chunks import ChunkIndex, randomplus_frame
from repro.core.matcher import MatcherState, match_and_update, merge_matcher
from repro.core.state import (
    SamplerState,
    apply_cross_chunk_decrement,
    apply_update,
)

if TYPE_CHECKING:  # avoid core ↔ sim import cycle; Detections is a pytree
    from repro.sim.oracle import Detections

DetectorFn = Callable[[jax.Array, jax.Array], "Detections"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ExSampleCarry:
    sampler: SamplerState
    matcher: MatcherState
    key: jax.Array
    step: jax.Array            # i32[] — total frames processed
    results: jax.Array         # i32[] — distinct results found so far


def init_carry(
    sampler: SamplerState, matcher: MatcherState, key: jax.Array
) -> ExSampleCarry:
    return ExSampleCarry(
        sampler=sampler,
        matcher=matcher,
        key=key,
        step=jnp.zeros((), jnp.int32),
        results=jnp.zeros((), jnp.int32),
    )


def _process_frame(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    detector: DetectorFn,
    chunk_id: jax.Array,
    det_key: jax.Array,
) -> ExSampleCarry:
    """Algorithm 1 lines 9-16 for one frame of ``chunk_id``."""
    # line 9: within-chunk random+ sample; the per-chunk counter n doubles
    # as the low-discrepancy rank so no extra state is needed.
    rank = carry.sampler.n[chunk_id].astype(jnp.int32)
    frame_id = randomplus_frame(chunks, chunk_id, rank)
    video_id = chunks.video_id[chunk_id]

    # lines 10-11: io + decode + detect (the expensive part)
    dets = detector(det_key, frame_id)

    # line 12: matcher
    m = match_and_update(
        carry.matcher,
        dets.boxes,
        dets.feats,
        dets.valid,
        video_id,
        frame_id,
        chunk_id,
    )

    # lines 13-14: state update.  §3.4: matches whose first sighting lives in
    # a different chunk decrement *that* chunk's N¹, not this one's.
    d1_local = m.d1 - m.cross_chunk
    sampler = apply_update(carry.sampler, chunk_id, m.d0, d1_local)
    valid_home = m.cross_home >= 0
    sampler = apply_cross_chunk_decrement(
        sampler,
        jnp.where(valid_home, m.cross_home, 0),
        valid_home.astype(sampler.n1.dtype),
    )
    return dataclasses.replace(
        carry,
        sampler=sampler,
        matcher=m.new_state,
        step=carry.step + 1,
        results=carry.results + m.d0,
    )


@partial(jax.jit, static_argnames=("detector", "method"))
def exsample_step(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    *,
    detector: DetectorFn,
    method: str = "exact",
) -> ExSampleCarry:
    """One full iteration of Algorithm 1 (choose → process → update)."""
    key, k_choice, k_det = jax.random.split(carry.key, 3)
    carry = dataclasses.replace(carry, key=key)
    chunk_id = thompson.choose_chunks(
        k_choice, carry.sampler, cohorts=1, method=method
    )[0]
    return _process_frame(carry, chunks, detector, chunk_id, k_det)


@partial(jax.jit, static_argnames=("detector", "cohorts", "method"))
def exsample_batch_step(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    *,
    detector: DetectorFn,
    cohorts: int,
    method: str = "exact",
) -> ExSampleCarry:
    """§3.7.1 batched execution: B Thompson cohorts pick B frames which are
    processed as one device batch; statistics update once at the end
    (additive, order-independent).

    The matcher update is inherently sequential in its ring buffer, so the
    B frames' detections are folded with ``lax.fori_loop`` — the expensive
    detector work is still batched, matching the paper's GPU batching story.
    """
    key, k_choice, k_det = jax.random.split(carry.key, 3)
    carry = dataclasses.replace(carry, key=key)
    chunk_ids = thompson.choose_chunks(
        k_choice, carry.sampler, cohorts=cohorts, method=method
    )
    det_keys = jax.random.split(k_det, cohorts)

    def body(i, c):
        return _process_frame(c, chunks, detector, chunk_ids[i], det_keys[i])

    return jax.lax.fori_loop(0, cohorts, body, carry)


def _host_search(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    *,
    detector: DetectorFn,
    result_limit: int,
    max_steps: int,
    cohorts: int = 1,
    method: str = "exact",
    trace_every: int = 0,
):
    """Host driver: iterate until ``result_limit`` distinct results,
    ``max_steps`` frames, or repository exhaustion.  Returns
    (final_carry, trace) where trace is a list of (frames_processed,
    results) checkpoints for recall curves.

    One jitted step is dispatched per iteration and ``carry.results`` is
    synced to the host every step, so framework overhead dominates at
    simulation scale — kept as the reference/debugging driver; use
    ``run_search_scan`` (DESIGN.md §7) when throughput matters.

    Checkpoints fire on *boundary crossings* of ``trace_every`` (the step
    counter advances by ``cohorts`` per iteration, so ``step %
    trace_every == 0`` could silently skip every boundary).
    """
    trace = []
    step_fn = (
        partial(exsample_step, detector=detector, method=method)
        if cohorts == 1
        else partial(
            exsample_batch_step, detector=detector, cohorts=cohorts, method=method
        )
    )
    while (
        int(carry.results) < result_limit
        and int(carry.step) < max_steps
        and not bool(jnp.all(carry.sampler.exhausted()))
    ):
        prev_step = int(carry.step)
        carry = step_fn(carry, chunks)
        if trace_every and (int(carry.step) // trace_every) > (prev_step // trace_every):
            trace.append((int(carry.step), int(carry.results)))
    trace.append((int(carry.step), int(carry.results)))
    return carry, trace


@partial(
    jax.jit,
    static_argnames=("detector", "cohorts", "method", "max_steps", "trace_every"),
)
def _search_scan_device(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    result_limit: jax.Array,
    *,
    detector: DetectorFn,
    cohorts: int,
    method: str,
    max_steps: int,
    trace_every: int,
):
    """Device-resident search loop (DESIGN.md §7).

    The whole choose→process→update iteration runs under one
    ``lax.while_loop`` so no per-step host round-trip or dispatch happens.
    Early exit mirrors ``run_search`` exactly: stop when ``results ≥
    result_limit`` OR ``step ≥ max_steps`` OR every chunk is exhausted,
    checked *before* each (cohort) step.  Recall-curve checkpoints are
    scattered into a preallocated i32[cap, 2] buffer on boundary
    crossings of ``trace_every``; the host syncs the buffer once at the
    end.
    """
    # worst case one crossing per trace_every frames, final step may
    # overshoot max_steps by cohorts-1, plus the unconditional final entry
    cap = (max_steps + cohorts - 1) // trace_every + 1 if trace_every else 1
    buf0 = jnp.zeros((cap, 2), jnp.int32)
    n0 = jnp.zeros((), jnp.int32)

    if cohorts == 1:
        step_fn = partial(exsample_step, detector=detector, method=method)
    else:
        step_fn = partial(
            exsample_batch_step, detector=detector, cohorts=cohorts, method=method
        )

    def cond(state):
        c, _, _ = state
        return (
            (c.results < result_limit)
            & (c.step < max_steps)
            & ~jnp.all(c.sampler.exhausted())
        )

    def body(state):
        c, buf, n = state
        c2 = step_fn(c, chunks)
        if trace_every:
            crossed = (c2.step // trace_every) > (c.step // trace_every)
            entry = jnp.stack([c2.step, c2.results])
            buf = buf.at[jnp.where(crossed, n, cap)].set(entry, mode="drop")
            n = n + crossed.astype(jnp.int32)
        return c2, buf, n

    carry, buf, n = jax.lax.while_loop(cond, body, (carry, buf0, n0))
    # unconditional final checkpoint, as in run_search
    final = jnp.stack([carry.step, carry.results])
    buf = buf.at[jnp.minimum(n, cap - 1)].set(final, mode="drop")
    n = jnp.minimum(n + 1, cap)
    return carry, buf, n


def _scan_search(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    *,
    detector: DetectorFn,
    result_limit: int,
    max_steps: int,
    cohorts: int = 1,
    method: str = "exact",
    trace_every: int = 0,
):
    """Device-resident drop-in for the host driver — same signature, same
    (step, results) trajectory for the same PRNG key, one host sync total.

    ``max_steps``/``cohorts``/``trace_every`` are compile-time constants
    (they size the trace buffer and the cohort batch); ``result_limit``
    stays dynamic so sweeping recall targets reuses one executable.
    """
    carry, buf, n = _search_scan_device(
        carry,
        chunks,
        jnp.asarray(result_limit, jnp.int32),
        detector=detector,
        cohorts=cohorts,
        method=method,
        max_steps=max_steps,
        trace_every=trace_every,
    )
    buf_host = np.asarray(buf)  # the single device→host sync
    trace = [(int(s), int(r)) for s, r in buf_host[: int(n)]]
    return carry, trace


# ---------------------------------------------------------------------------
# Sharded device-resident driver (paper §3.7.1 distributed, DESIGN.md §8)
# ---------------------------------------------------------------------------


@partial(
    jax.jit,
    static_argnames=(
        "mesh", "axis", "detector", "cohorts", "sync_every", "max_steps",
        "alpha0", "beta0",
    ),
)
def _search_sharded_device(
    key: jax.Array,
    step0: jax.Array,
    results0: jax.Array,
    n1: jax.Array,          # f32[M] — sharded over `axis` (M % shards == 0)
    n: jax.Array,           # f32[M] — sharded
    frames: jax.Array,      # i32[M] — sharded
    matcher: MatcherState,  # replicated
    chunks: ChunkIndex,     # replicated
    result_limit: jax.Array,
    *,
    mesh,
    axis: str,
    detector: DetectorFn,
    cohorts: int,
    sync_every: int,
    max_steps: int,
    alpha0: float,
    beta0: float,
):
    """Mesh-resident search loop (DESIGN.md §8).

    One ``shard_map`` call contains the whole search: every shard owns an
    M/S slice of the chunk statistics plus a full-width ``[M]`` *delta*
    buffer of its unsynced updates (updates can target remote chunks via
    §3.4 cross-chunk decrements and remote-cohort processing) and a
    shard-local matcher.  Per round, the globally-consistent Thompson
    choice (``local_cohort_winners`` — all-gather of per-shard winners
    carrying the owner's sample count as the random+ rank base) picks
    ``cohorts`` chunks; shard s processes cohorts
    ``[s·C/S, (s+1)·C/S)``.  Every ``sync_every`` rounds the deltas merge
    with one ``psum`` (additive ⇒ exact regardless of interleaving,
    §3.7.1) and the S matcher states fold pairwise through
    ``merge_matcher`` against the shared snapshot, which then becomes the
    new snapshot on every shard.  Termination is evaluated at sync
    boundaries only — the run can overshoot ``result_limit`` by at most
    one sync window, the eventual-consistency analogue of the batching
    caveat.  The trace records (step, results) at every sync; the host
    syncs once, after the loop exits.
    """
    from repro.core.distributed import get_shard_map, local_cohort_winners
    from jax.sharding import PartitionSpec as P

    num_shards = mesh.shape[axis]
    m = n1.shape[0]
    local_m = m // num_shards
    per_shard = cohorts // num_shards
    per_sync = cohorts * sync_every
    # one trace entry per sync, bounded so a huge max_steps budget doesn't
    # carry a huge buffer through the loop; past the cap, intermediate
    # syncs drop and the final state overwrites the last slot
    cap = min(max_steps // max(per_sync, 1) + 3, 4096)

    def shard_fn(key, step0, results0, n1_l, n_l, frames_l, matcher0, chks, rlimit):
        shard_id = jax.lax.axis_index(axis)
        fdt = n_l.dtype
        my_slice = lambda full: jax.lax.dynamic_slice(
            full, (shard_id * local_m,), (local_m,)
        )

        def one_round(base_n1, base_n, rstate):
            # base_* are the while-carry's CURRENT synced slices — closing
            # over shard_fn's arguments instead would pin every round's
            # view (and random+ ranks) to the initial statistics
            key, delta_n1, delta_n, foreign, matcher, lstep, lres = rstate
            key, k_choice, k_det = jax.random.split(key, 3)
            # this shard's view: authoritative slice + own pending deltas
            # (other shards' deltas become visible at the next sync)
            view = SamplerState(
                n1=base_n1 + my_slice(delta_n1),
                n=base_n + my_slice(delta_n),
                frames=frames_l,
                alpha0=alpha0,
                beta0=beta0,
            )
            a_l, b_l = thompson.gamma_params(view)
            c_ids, c_scores, c_n = local_cohort_winners(
                k_choice, a_l, b_l, view.exhausted(), view.n,
                axis=axis, cohorts=cohorts,
            )
            # Within-window random+ rank dedup.  Thompson concentrates on
            # hot chunks, so several cohorts routinely pick the SAME chunk
            # in one round; the owner's view gives them all the same rank
            # base, and colliding ranks resample the identical frame on
            # different shards (duplicated results, wasted detector work).
            # The winner list is replicated, so every shard computes the
            # same fix redundantly: cohort g adds its within-round
            # occurrence index, and `foreign` counts earlier-round picks
            # by NON-owner shards (the owner's own picks are already in
            # its view).  Every pick of a chunk inside one sync window
            # therefore gets a distinct rank.
            live_c = jnp.isfinite(c_scores)                      # [C]
            owner = c_ids // local_m                             # [C]
            pshard = jnp.arange(cohorts, dtype=jnp.int32) // per_shard
            same_before = jnp.tril(c_ids[:, None] == c_ids[None, :], -1)
            occ = jnp.sum(same_before & live_c[None, :], axis=1)  # [C]
            ranks = (c_n + foreign[c_ids].astype(fdt) + occ.astype(fdt)).astype(
                jnp.int32
            )
            foreign = foreign.at[c_ids].add(
                ((pshard != owner) & live_c).astype(jnp.int32)
            )

            def proc(j, pst):
                delta_n1, delta_n, matcher, lstep, lres = pst
                g = shard_id * per_shard + j          # my global cohort index
                cid = c_ids[g]
                # −inf winner ⇔ every chunk everywhere exhausted: run the
                # (harmless) detector but gate every state update off
                live = live_c[g]
                frame_id = randomplus_frame(chks, cid, ranks[g])
                dets = detector(jax.random.fold_in(k_det, g), frame_id)
                mres = match_and_update(
                    matcher,
                    dets.boxes,
                    dets.feats,
                    dets.valid & live,
                    chks.video_id[cid],
                    frame_id,
                    cid,
                )
                # §3.4: cross-chunk d₁ decrements the HOME chunk's N¹ — the
                # home chunk may live on another shard, which is exactly why
                # the delta buffer is full-width [M]
                d1_local = mres.d1 - mres.cross_chunk
                upd = live.astype(delta_n1.dtype)
                delta_n1 = delta_n1.at[cid].add(
                    (mres.d0 - d1_local).astype(delta_n1.dtype) * upd
                )
                delta_n = delta_n.at[cid].add(upd)
                valid_home = mres.cross_home >= 0
                delta_n1 = delta_n1.at[
                    jnp.where(valid_home, mres.cross_home, 0)
                ].add(-valid_home.astype(delta_n1.dtype))
                return (
                    delta_n1,
                    delta_n,
                    mres.new_state,
                    lstep + live.astype(jnp.int32),
                    lres + mres.d0,
                )

            delta_n1, delta_n, matcher, lstep, lres = jax.lax.fori_loop(
                0, per_shard, proc, (delta_n1, delta_n, matcher, lstep, lres)
            )
            return (key, delta_n1, delta_n, foreign, matcher, lstep, lres)

        def all_exhausted(n_l):
            exh = jnp.all(n_l >= frames_l.astype(fdt)).astype(jnp.int32)
            return jax.lax.psum(exh, axis) == num_shards

        def body(st):
            (key, n1_l, n_l, matcher, snap, step, results, buf, tn, hw, ov,
             windows, cont) = st
            rst = (
                key,
                jnp.zeros((m,), n1_l.dtype),
                jnp.zeros((m,), fdt),
                jnp.zeros((m,), jnp.int32),   # foreign-pick counts, replicated
                matcher,
                jnp.zeros((), jnp.int32),
                jnp.zeros((), jnp.int32),
            )
            key, dn1, dn, _foreign, matcher, lstep, lres = jax.lax.fori_loop(
                0, sync_every, lambda r, s: one_round(n1_l, n_l, s), rst
            )
            # ---- sampler sync: one psum, exact by additivity (§3.7.1) ----
            n1_l = n1_l + my_slice(jax.lax.psum(dn1, axis))
            n_l = n_l + my_slice(jax.lax.psum(dn, axis))
            # ---- matcher sync: fold every shard's matcher against the
            # shared snapshot; all shards compute the identical merged
            # state, which becomes the next snapshot ----
            stacked = jax.tree.map(lambda x: jax.lax.all_gather(x, axis), matcher)
            # Exact cross-shard d₁ dedup: the shards' matchers are replicas
            # of the snapshot, so k shards can each fire the SAME entry's
            # seen-once → seen-twice transition inside one window and the
            # psum above then decremented the entry's home chunk's N¹ k
            # times for one global transition.  Left uncorrected this
            # drives N¹ negative repository-wide and flattens the Thompson
            # posterior into uniform sampling.  The gathered stack is
            # replicated, so every shard computes the identical k per
            # snapshot entry and adds back the k−1 over-decrements.
            same_e = (stacked.video == snap.video[None, :]) & (
                stacked.frame == snap.frame[None, :]
            )
            trans = (
                same_e
                & (snap.times_seen[None, :] == 1)
                & (stacked.times_seen >= 2)
            )                                                   # [S, R]
            k = jnp.sum(trans, axis=0)                          # [R]
            over = jnp.maximum(k - 1, 0).astype(n1_l.dtype)
            corr = jnp.zeros((m,), n1_l.dtype).at[
                jnp.where(k > 0, snap.chunk, 0)
            ].add(jnp.where(k > 0, over, jnp.zeros((), n1_l.dtype)))
            n1_l = n1_l + my_slice(corr)
            merged = jax.lax.fori_loop(
                1,
                num_shards,
                lambda s, dst: merge_matcher(
                    dst, jax.tree.map(lambda x: x[s], stacked), snap
                ),
                jax.tree.map(lambda x: x[0], stacked),
            )
            # ---- ring-pressure accounting (merge_matcher_checked
            # semantics): per-shard insertions folded this window; the
            # gathered stack is replicated so every shard agrees ----
            inserted = stacked.total_inserted - snap.total_inserted  # [S]
            hw = jnp.maximum(hw, jnp.max(inserted))
            ov = ov | jnp.any(inserted >= snap.capacity)
            # ---- counters / trace / continue flag ----
            step = step + jax.lax.psum(lstep, axis)
            results = results + jax.lax.psum(lres, axis)
            entry = jnp.stack([step, results])
            buf = buf.at[tn].set(entry, mode="drop")  # index == cap: dropped
            tn = jnp.minimum(tn + 1, cap)
            cont = (
                (results < rlimit)
                & (step < max_steps)
                & ~all_exhausted(n_l)
            )
            return (key, n1_l, n_l, merged, merged, step, results, buf, tn,
                    hw, ov, windows + 1, cont)

        cont0 = (
            (results0 < rlimit)
            & (step0 < max_steps)
            & ~all_exhausted(n_l)
        )
        init = (
            key, n1_l, n_l, matcher0, matcher0, step0, results0,
            jnp.zeros((cap, 2), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), bool),
            jnp.zeros((), jnp.int32), cont0,
        )
        (key, n1_l, n_l, matcher, _snap, step, results, buf, tn, hw, ov,
         windows, _) = jax.lax.while_loop(lambda st: st[-1], body, init)
        # every sync already checkpointed itself; write a final entry only
        # when the trace would otherwise miss the end state — a run whose
        # very first continue-check failed (empty trace), or one that
        # outran the buffer cap (overwrite the last slot)
        idx = jnp.where(
            (tn == 0) | (tn >= cap), jnp.minimum(tn, cap - 1), cap
        )
        buf = buf.at[idx].set(jnp.stack([step, results]), mode="drop")
        tn = jnp.clip(tn, 1, cap)
        return n1_l, n_l, matcher, key, step, results, buf, tn, hw, ov, windows

    sh, rep = P(axis), P()
    return get_shard_map()(
        shard_fn,
        mesh=mesh,
        in_specs=(rep, rep, rep, sh, sh, sh, rep, rep, rep),
        out_specs=(sh, sh, rep, rep, rep, rep, rep, rep, rep, rep, rep),
        check_rep=False,
    )(key, step0, results0, n1, n, frames, matcher, chunks, result_limit)


def _sharded_search(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    *,
    mesh,
    detector: DetectorFn,
    result_limit: int,
    max_steps: int,
    cohorts: int | None = None,
    sync_every: int = 1,
    axis: str = "data",
):
    """Mesh-scale drop-in for the scanned driver (DESIGN.md §8): the full
    choose → sample → detect → match → update loop device-resident under
    ``shard_map``, chunk statistics sharded over ``axis``, per-shard
    matchers merged every ``sync_every`` rounds, one host sync total.

    ``cohorts`` is the GLOBAL batch size per round (default: one frame per
    shard) and must divide evenly over the mesh's ``axis`` extent; chunk
    statistics are padded to the shard count with exhausted dummies
    (``pad_chunks``) and trimmed again on the way out.  The Thompson
    choice is the Wilson–Hilferty sharded path (DESIGN.md §3) — there is
    no ``method`` knob here because the exact-Gamma sampler never runs on
    the resident path.  Statistics match the single-device drivers up to
    merge staleness: with ``sync_every=1`` every round starts from fully
    merged state and the trajectory is statistically indistinguishable
    from ``run_search_scan`` at the same cohort size (±5% result count on
    the paper configs — asserted by ``benchmarks/bench_sharded.py`` and
    ``tests/test_sharded_driver.py``).
    """
    from repro.core.distributed import pad_chunks, shard_sampler_state

    num_shards = mesh.shape[axis]
    if cohorts is None:
        cohorts = num_shards
    if cohorts < num_shards or cohorts % num_shards:
        raise ValueError(
            f"cohorts={cohorts} must be a positive multiple of the "
            f"{num_shards} '{axis}' shards"
        )
    if sync_every < 1:
        # sync_every == 0 would make the resident while_loop spin forever
        # (no rounds run, counters never advance, cond stays true)
        raise ValueError(f"sync_every={sync_every} must be >= 1")
    m0 = carry.sampler.num_chunks
    state = pad_chunks(carry.sampler, num_shards)
    state = shard_sampler_state(state, mesh, axis)

    (n1, n, matcher, key, step, results, buf, tn, hw, ov, windows) = (
        _search_sharded_device(
        carry.key,
        carry.step,
        carry.results,
        state.n1,
        state.n,
        state.frames,
        carry.matcher,
        chunks,
        jnp.asarray(result_limit, jnp.int32),
        mesh=mesh,
        axis=axis,
        detector=detector,
        cohorts=cohorts,
        sync_every=sync_every,
        max_steps=max_steps,
        alpha0=carry.sampler.alpha0,
        beta0=carry.sampler.beta0,
    ))
    out = ExSampleCarry(
        sampler=dataclasses.replace(
            carry.sampler, n1=n1[:m0], n=n[:m0], frames=carry.sampler.frames
        ),
        matcher=matcher,
        key=key,
        step=step,
        results=results,
    )
    buf_host = np.asarray(buf)  # the single device→host sync
    trace = [(int(s), int(r)) for s, r in buf_host[: int(tn)]]
    stats = {
        "merge_high_water": int(hw),
        "merge_overflow": bool(ov),
        "merges": int(windows),
    }
    return out, trace, stats


# ---------------------------------------------------------------------------
# Multi-query batched driver (§3.7.1 amortized across queries, DESIGN.md §9)
# ---------------------------------------------------------------------------

# per-query detection predicate: (query index i32[], single-frame Detections)
# -> bool[D] keep-mask, applied on top of the detector's own validity
SelectFn = Callable[[jax.Array, "Detections"], jax.Array]


def stack_carries(carries) -> ExSampleCarry:
    """Stack Q independent ``ExSampleCarry`` trees into one multi-query
    carry with a leading [Q] axis on every leaf (static fields must agree)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *carries)


def init_carry_multi(
    sampler: SamplerState, matcher: MatcherState, keys: jax.Array
) -> ExSampleCarry:
    """Fresh Q-query carry: ``keys`` is a [Q]-leading PRNG key array; the
    (single-query) sampler and matcher are broadcast to every query
    (``matcher.broadcast_leading``, same layout as ``init_matcher_multi``)."""
    from repro.core.matcher import broadcast_leading

    q = keys.shape[0]
    return ExSampleCarry(
        sampler=broadcast_leading(sampler, q),
        matcher=broadcast_leading(matcher, q),
        key=keys,
        step=jnp.zeros((q,), jnp.int32),
        results=jnp.zeros((q,), jnp.int32),
    )


class RoundChoice(NamedTuple):
    """The choose half of one multi-query round (DESIGN.md §9/§11): every
    per-query decision that depends only on round-start state.  Precomputing
    it is what lets the async slot scheduler issue a *cohort slot* — chunk
    winners, rank base, key split — and hand the expensive process half to
    a worker while the driver state stays authoritative."""

    key_next: jax.Array    # key[Q] — per-query key after this round
    chunk_ids: jax.Array   # i32[Q, C] — Thompson winners
    ranks: jax.Array       # i32[Q, C] — random+ rank (n0 + within-round occ)
    frame_ids: jax.Array   # i32[Q, C] — sampled frames
    det_keys: jax.Array    # key[Q, C] — per-slot detector keys


class RoundAux(NamedTuple):
    """Process-half byproducts the resident loop discards but the async
    merge needs: the flat frame batch, which slots were freshly detected
    (``need`` — unique, uncached, live representatives) and the raw
    detector outputs, so fresh detections can be published into the shared
    :class:`~repro.serve.batcher.DetectionCache` at the merge boundary."""

    flat_frames: jax.Array   # i32[Q*C]
    need: jax.Array          # bool[Q*C]
    fresh: "Detections"      # detector output, leading [Q*C]
    rep_hit: jax.Array       # bool[Q*C] — representatives served by the cache


def multi_round_choose(
    mc: ExSampleCarry,
    chunks: ChunkIndex,
    *,
    cohorts: int,
    method: str,
) -> RoundChoice:
    """Choose phase of one multi-query round: split every query's key,
    draw ``cohorts`` Thompson winners per query from round-start
    statistics, advance within-round random+ ranks (``occ``) and derive
    the per-slot detector keys.  Pure function of the carry — bit-for-bit
    the choice ``_multi_round`` used to compute inline."""
    c = cohorts
    keys = jax.vmap(lambda k: jax.random.split(k, 3))(mc.key)
    key_next, k_choice, k_det = keys[:, 0], keys[:, 1], keys[:, 2]

    chunk_ids = thompson.choose_chunks_batched(
        k_choice, mc.sampler, cohorts=c, method=method
    )                                                        # i32[Q, C]
    # within-round rank advance: cohort j of query q reads n AFTER its own
    # earlier same-chunk picks incremented it (exsample_batch_step's
    # sequential _process_frame order), so occ is the per-query count of
    # earlier cohorts that picked the same chunk
    eq = chunk_ids[:, :, None] == chunk_ids[:, None, :]      # [Q, C, C]
    occ = jnp.sum(jnp.tril(eq, -1), axis=-1)                 # [Q, C]
    n0 = jnp.take_along_axis(mc.sampler.n, chunk_ids, axis=-1)
    ranks = (n0 + occ.astype(n0.dtype)).astype(jnp.int32)
    frame_ids = randomplus_frame(chunks, chunk_ids, ranks)   # i32[Q, C]

    if c == 1:
        det_keys = k_det[:, None]        # exsample_step uses k_det unsplit
    else:
        det_keys = jax.vmap(lambda k: jax.random.split(k, c))(k_det)
    return RoundChoice(
        key_next=key_next, chunk_ids=chunk_ids, ranks=ranks,
        frame_ids=frame_ids, det_keys=det_keys,
    )


def multi_round_process(
    mc: ExSampleCarry,
    cache,
    chunks: ChunkIndex,
    active: jax.Array,       # bool[Q] — round-start liveness per query
    choice: RoundChoice,
    *,
    detector: DetectorFn,
    select: SelectFn | None,
    query_ids: jax.Array | None = None,   # i32[Q] — global query indices
):
    """Process phase of one multi-query round: dedup the union of the Q·C
    chosen frames, resolve them through the shared ``DetectionCache``, run
    one detector batch and fold each query's slots sequentially into its
    own matcher/sampler.  ``query_ids`` carries the GLOBAL query index of
    each carry row into ``select`` (the async scheduler processes gathered
    row subsets, whose positional index is not the query id; the resident
    loop passes ``arange(Q)`` implicitly).

    Returns ``(mc', cache', fresh_calls, cache_hits, aux)`` — see
    :class:`RoundAux`."""
    from repro.serve.batcher import cache_insert, cache_lookup, dedup_first_index

    q_n = mc.key.shape[0]
    c = choice.chunk_ids.shape[1]
    b = q_n * c
    if query_ids is None:
        query_ids = jnp.arange(q_n, dtype=jnp.int32)
    key_next = choice.key_next
    chunk_ids, frame_ids, det_keys = (
        choice.chunk_ids, choice.frame_ids, choice.det_keys
    )
    det_keys_flat = det_keys.reshape((b,) + det_keys.shape[2:])
    flat_frames = frame_ids.reshape(b)
    flat_valid = jnp.repeat(active, c)

    # ---- cross-query dedup + cache: one detector batch for the union ----
    first_idx = dedup_first_index(flat_frames, flat_valid)
    is_rep = (first_idx == jnp.arange(b, dtype=jnp.int32)) & flat_valid
    fresh = jax.vmap(detector)(det_keys_flat, flat_frames)
    if cache is not None:
        hit, cached = cache_lookup(cache, flat_frames)
        expand = lambda m, x: m.reshape(m.shape + (1,) * (x.ndim - 1))
        resolved = jax.tree.map(
            lambda cv, fv: jnp.where(expand(hit, fv), cv, fv), cached, fresh
        )
        need = is_rep & ~hit
        cache = cache_insert(cache, flat_frames, fresh, need)
    else:
        hit = jnp.zeros((b,), bool)
        resolved = fresh
        need = is_rep
    # scatter-back: every slot gathers its representative's detections, so
    # each query consumes detections of exactly the frame it sampled
    dets_flat = jax.tree.map(lambda x: x[first_idx], resolved)
    fresh_calls = jnp.sum(need).astype(jnp.int32)
    cache_hits = jnp.sum(is_rep & hit).astype(jnp.int32)

    # ---- per-query sequential matcher/sampler fold over own slots only ----
    dets_q = jax.tree.map(
        lambda x: x.reshape((q_n, c) + x.shape[1:]), dets_flat
    )

    def fold_query(qi, sampler, matcher, results, dets_c, cids, fids, act):
        def bodyj(j, st):
            sampler, matcher, results = st
            d = jax.tree.map(lambda x: x[j], dets_c)
            valid = d.valid & act
            if select is not None:
                valid = valid & select(qi, d)
            mres = match_and_update(
                matcher, d.boxes, d.feats, valid,
                chunks.video_id[cids[j]], fids[j], cids[j],
            )
            d1_local = mres.d1 - mres.cross_chunk
            sampler = apply_update(
                sampler, cids[j], mres.d0, d1_local,
                samples=act.astype(sampler.n.dtype),
            )
            valid_home = mres.cross_home >= 0
            sampler = apply_cross_chunk_decrement(
                sampler,
                jnp.where(valid_home, mres.cross_home, 0),
                valid_home.astype(sampler.n1.dtype),
            )
            return sampler, mres.new_state, results + mres.d0

        return jax.lax.fori_loop(0, c, bodyj, (sampler, matcher, results))

    sampler, matcher, results = jax.vmap(fold_query)(
        query_ids, mc.sampler, mc.matcher, mc.results,
        dets_q, chunk_ids, frame_ids, active,
    )
    mc = ExSampleCarry(
        sampler=sampler,
        matcher=matcher,
        # finished queries keep their key frozen so their final carry is
        # bit-identical to their own solo run
        key=jnp.where(active[:, None], key_next, mc.key),
        step=mc.step + c * active.astype(jnp.int32),
        results=results,
    )
    aux = RoundAux(
        flat_frames=flat_frames, need=need, fresh=fresh,
        rep_hit=is_rep & hit,
    )
    return mc, cache, fresh_calls, cache_hits, aux


def _multi_round(
    mc: ExSampleCarry,
    cache,
    chunks: ChunkIndex,
    active: jax.Array,       # bool[Q] — round-start liveness per query
    *,
    detector: DetectorFn,
    select: SelectFn | None,
    cohorts: int,
    method: str,
):
    """One synchronized multi-query round (DESIGN.md §9).

    Every active query draws ``cohorts`` Thompson picks from ITS OWN
    statistics (one batched ``choose_chunks_batched`` call), the union of
    the Q·C sampled frames is deduplicated — and filtered through the
    shared ``DetectionCache`` when enabled — into one detector pass, and
    the detections scatter back so each query matches/updates against
    exactly its own cohort's slots.  Per query the fold replicates
    ``exsample_batch_step`` bit-for-bit: chunk choice from round-start
    statistics, within-round random+ ranks advancing sequentially
    (``occ``), matcher folded frame-by-frame, additive sampler deltas.

    Finished queries stay shape-stable: their slots are excluded from the
    dedup (never detected on their behalf), their detections are masked
    invalid, their sampler/step/key updates are gated to zero.

    The round is the composition of :func:`multi_round_choose` and
    :func:`multi_round_process` — the same two halves the async slot
    scheduler (DESIGN.md §11) runs at issue / process time, so the
    resident loop and the async workers share one round body.

    Returns ``(mc', cache', fresh_detections i32[], cache_hits i32[],
    aux)`` — ``fresh_detections`` counts what a real deployment would
    actually send through the detector this round (unique, uncached, live
    frames); the simulator still evaluates the full padded batch for
    static shapes.  ``aux`` is the round's :class:`RoundAux` (the resident
    loop uses it to attribute cache hits to a warm repository-index
    preload, DESIGN.md §13).
    """
    choice = multi_round_choose(mc, chunks, cohorts=cohorts, method=method)
    mc, cache, fresh_calls, cache_hits, aux = multi_round_process(
        mc, cache, chunks, active, choice, detector=detector, select=select,
    )
    return mc, cache, fresh_calls, cache_hits, aux


@partial(
    jax.jit,
    static_argnames=(
        "detector", "select", "cohorts", "method", "max_steps", "trace_every",
    ),
)
def _search_multi_device(
    mc: ExSampleCarry,
    chunks: ChunkIndex,
    result_limits: jax.Array,    # i32[Q]
    cache,
    warm_tag,                    # i32[S] index-preload tag snapshot, or None
    *,
    detector: DetectorFn,
    select: SelectFn | None,
    cohorts: int,
    method: str,
    max_steps: int,
    trace_every: int,
):
    """Device-resident multi-query loop: runs rounds until EVERY query is
    finished; per query the continue / trace semantics mirror
    ``_search_scan_device`` exactly (same cap formula, boundary-crossing
    checkpoints, unconditional final entry).

    ``warm_tag`` is a snapshot of the cache tag as the repository index
    preloaded it (DESIGN.md §13): a cache hit whose slot still tags the
    preloaded frame is an INDEX hit (a detector call a past search paid
    for), counted separately from within-run reuse.  Eviction-correct by
    construction — an evicted preload cannot hit at all, and a colliding
    run-inserted frame fails the ``warm_tag`` compare."""
    q_n = mc.step.shape[0]
    cap = (max_steps + cohorts - 1) // trace_every + 1 if trace_every else 1
    buf0 = jnp.zeros((q_n, cap, 2), jnp.int32)
    n0 = jnp.zeros((q_n,), jnp.int32)
    z32 = jnp.zeros((), jnp.int32)

    def live_mask(c):
        return (
            (c.results < result_limits)
            & (c.step < max_steps)
            & ~jnp.all(c.sampler.exhausted(), axis=-1)
        )

    def cond(state):
        return jnp.any(live_mask(state[0]))

    def body(state):
        c, cache, buf, n, calls, hits, ihits, rounds = state
        active = live_mask(c)
        c2, cache, fresh, hit, aux = _multi_round(
            c, cache, chunks, active,
            detector=detector, select=select, cohorts=cohorts, method=method,
        )
        if warm_tag is not None:
            wslot = aux.flat_frames % warm_tag.shape[0]
            whit = aux.rep_hit & (warm_tag[wslot] == aux.flat_frames)
            ihits = ihits + jnp.sum(whit).astype(jnp.int32)
        if trace_every:
            crossed = (c2.step // trace_every) > (c.step // trace_every)
            entry = jnp.stack([c2.step, c2.results], axis=-1)   # [Q, 2]
            idx = jnp.where(crossed, n, cap)
            buf = jax.vmap(lambda bq, i, e: bq.at[i].set(e, mode="drop"))(
                buf, idx, entry
            )
            n = n + crossed.astype(jnp.int32)
        return c2, cache, buf, n, calls + fresh, hits + hit, ihits, rounds + 1

    c, cache, buf, n, calls, hits, ihits, rounds = jax.lax.while_loop(
        cond, body, (mc, cache, buf0, n0, z32, z32, z32, z32)
    )
    final = jnp.stack([c.step, c.results], axis=-1)
    buf = jax.vmap(lambda bq, i, e: bq.at[i].set(e, mode="drop"))(
        buf, jnp.minimum(n, cap - 1), final
    )
    n = jnp.minimum(n + 1, cap)
    return c, cache, buf, n, calls, hits, ihits, rounds


def _multi_search(
    carries: ExSampleCarry,
    chunks: ChunkIndex,
    *,
    detector: DetectorFn,
    result_limits,
    max_steps: int,
    cohorts: int = 1,
    method: str = "exact",
    trace_every: int = 0,
    select: SelectFn | None = None,
    cache_frames: int = 0,
    cache=None,
    warm_tag=None,
):
    """Q concurrent queries over one repository, one decode/detect pass per
    round (DESIGN.md §9).

    ``carries`` is a stacked ``ExSampleCarry`` (leading [Q] axis on every
    leaf — ``init_carry_multi`` / ``stack_carries``); each query owns its
    sampler statistics, matcher memory, PRNG key, result counter and
    ``result_limits[q]``.  Per round the union of the Q cohorts' frames is
    deduplicated (plus an optional cross-round ``DetectionCache`` of
    ``cache_frames`` slots) into one detector batch; each query then
    matches and updates against its own cohort's slots only.  Queries that
    hit their limit / the step budget / exhaustion mask out of
    choose/sample but stay shape-stable until every query finishes.

    ``select(q, dets) -> bool[D]`` optionally restricts a shared
    class-agnostic detector to each query's predicate (the Focus-style
    share-one-ingest-pass economics); ``None`` keeps the detector's own
    validity.

    Per query the trajectory is bit-identical to its own
    ``run_search_scan`` run with the same key and a deterministic detector
    — dedup and caching change WHICH invocations happen, never the values
    a query consumes (with stochastic detectors, frames shared within a
    round or served from cache reuse one draw; that sharing is the point).

    Returns ``(carries', traces, stats)``: per-query recall traces (same
    semantics as ``run_search_scan``) and accounting —
    ``detector_invocations`` (unique, uncached frames actually detected),
    ``cache_hits``, ``rounds``, ``frames_sampled`` (Σ per-query steps,
    what Q sequential runs would have paid).

    ``cache`` overrides internal cache construction (a repository-index
    preload, DESIGN.md §13) and ``warm_tag`` — the preloaded cache's tag
    snapshot — splits ``index_hits`` out of ``cache_hits``; the final
    cache rides back in ``stats["final_cache"]`` so the executor can
    publish fresh detections into the index.
    """
    q_n = int(carries.step.shape[0])
    limits = jnp.broadcast_to(
        jnp.asarray(result_limits, jnp.int32), (q_n,)
    )
    if cache is None and cache_frames:
        from repro.serve.batcher import init_detection_cache

        struct = jax.eval_shape(
            detector, jax.random.PRNGKey(0), jnp.zeros((), jnp.int32)
        )
        cache = init_detection_cache(struct, cache_frames)
    out, cache, buf, n, calls, hits, ihits, rounds = _search_multi_device(
        carries,
        chunks,
        limits,
        cache,
        warm_tag,
        detector=detector,
        select=select,
        cohorts=cohorts,
        method=method,
        max_steps=max_steps,
        trace_every=trace_every,
    )
    buf_host = np.asarray(buf)  # the single device→host sync
    n_host = np.asarray(n)
    traces = [
        [(int(s), int(r)) for s, r in buf_host[q][: int(n_host[q])]]
        for q in range(q_n)
    ]
    stats = {
        "detector_invocations": int(calls),
        "cache_hits": int(hits),
        "index_hits": int(ihits),
        "rounds": int(rounds),
        "frames_sampled": int(np.asarray(out.step).sum()),
        "final_cache": cache,
    }
    return out, traces, stats


# ---------------------------------------------------------------------------
# Deprecated shims — the five legacy entry points now lower through ONE
# SearchPlan (repro.core.plan, DESIGN.md §10).  Each shim builds the plan
# whose home-config lowering is the identical driver, so results stay
# bit-for-bit what the legacy function returned.
# ---------------------------------------------------------------------------


def _warn_deprecated(name: str) -> None:
    warnings.warn(
        f"{name}() is deprecated: build a repro.core.plan.SearchPlan and "
        "call .run() (DESIGN.md §10) — this shim lowers to the identical "
        "driver",
        DeprecationWarning,
        stacklevel=3,
    )


def run_search(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    *,
    detector: DetectorFn,
    result_limit: int,
    max_steps: int,
    cohorts: int = 1,
    method: str = "exact",
    trace_every: int = 0,
):
    """Deprecated shim over ``SearchPlan`` (strategy='host'); identical
    semantics to the legacy host reference loop."""
    from repro.core.plan import Execution, SearchPlan

    _warn_deprecated("run_search")
    res = SearchPlan(
        result_limit=result_limit, max_steps=max_steps, cohorts=cohorts,
        method=method, trace_every=trace_every,
        execution=Execution(strategy="host"),
    ).run(carry, chunks, detector=detector)
    return res.carry, res.traces[0]


def run_search_scan(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    *,
    detector: DetectorFn,
    result_limit: int,
    max_steps: int,
    cohorts: int = 1,
    method: str = "exact",
    trace_every: int = 0,
):
    """Deprecated shim over ``SearchPlan`` (strategy='scan'); identical
    semantics to the legacy device-resident driver (DESIGN.md §7)."""
    from repro.core.plan import Execution, SearchPlan

    _warn_deprecated("run_search_scan")
    res = SearchPlan(
        result_limit=result_limit, max_steps=max_steps, cohorts=cohorts,
        method=method, trace_every=trace_every,
        execution=Execution(strategy="scan"),
    ).run(carry, chunks, detector=detector)
    return res.carry, res.traces[0]


def run_search_sharded(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    *,
    mesh,
    detector: DetectorFn,
    result_limit: int,
    max_steps: int,
    cohorts: int | None = None,
    sync_every: int = 1,
    axis: str = "data",
):
    """Deprecated shim over ``SearchPlan`` (strategy='sharded'); identical
    semantics to the legacy mesh-resident driver (DESIGN.md §8).  The
    caller's ``mesh`` is passed through unchanged."""
    from repro.core.plan import Execution, SearchPlan

    _warn_deprecated("run_search_sharded")
    num_shards = mesh.shape[axis]
    res = SearchPlan(
        result_limit=result_limit, max_steps=max_steps,
        cohorts=num_shards if cohorts is None else cohorts,
        execution=Execution(
            strategy="sharded", shards=num_shards, axis=axis,
            sync_every=sync_every,
        ),
    ).run(carry, chunks, detector=detector, mesh=mesh)
    return res.carry, res.traces[0]


def run_search_multi(
    carries: ExSampleCarry,
    chunks: ChunkIndex,
    *,
    detector: DetectorFn,
    result_limits,
    max_steps: int,
    cohorts: int = 1,
    method: str = "exact",
    trace_every: int = 0,
    select: SelectFn | None = None,
    cache_frames: int = 0,
):
    """Deprecated shim over ``SearchPlan`` (queries_axis=True); identical
    semantics to the legacy Q-batched driver (DESIGN.md §9), including the
    legacy ``stats`` dict shape."""
    from repro.core.plan import Execution, SearchPlan

    _warn_deprecated("run_search_multi")
    q_n = int(carries.step.shape[0])
    if isinstance(result_limits, int):
        limits: int | tuple = result_limits
    else:
        vals = np.asarray(result_limits).reshape(-1)
        limits = int(vals[0]) if vals.size == 1 else tuple(
            int(v) for v in vals
        )
    res = SearchPlan(
        queries=q_n, result_limit=limits, max_steps=max_steps,
        cohorts=cohorts, method=method, trace_every=trace_every,
        execution=Execution(
            queries_axis=True,
            cache=cache_frames if cache_frames else None,
        ),
    ).run(carries, chunks, detector=detector, select=select)
    stats = {
        "detector_invocations": res.stats.detector_invocations,
        "cache_hits": res.stats.cache_hits,
        "rounds": res.stats.rounds,
        "frames_sampled": res.stats.frames_sampled,
    }
    return res.carry, res.traces, stats
