"""ExSample Algorithm 1 — single-step, batched-cohort and scanned drivers.

The loop is expressed as a pure step function over an ``ExSampleCarry``
pytree so it can be (a) jitted and scanned for simulation-scale benchmarks,
(b) driven frame-by-frame from the host around a real serving stack, and
(c) sharded (see ``repro.core.distributed``).

Two drivers share the step function (DESIGN.md §7): ``run_search`` is the
host reference loop (one dispatch + one sync per step), ``run_search_scan``
is the device-resident ``lax.while_loop`` production driver — identical
(step, results) trajectory, one host sync total.

Detector plug-in protocol:  ``detector(key, frame_id) -> Detections``
(see ``repro.sim.oracle.Detections``).  The oracle/noisy/neural detectors
all satisfy it.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import TYPE_CHECKING, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import thompson
from repro.core.chunks import ChunkIndex, randomplus_frame
from repro.core.matcher import MatcherState, match_and_update
from repro.core.state import (
    SamplerState,
    apply_cross_chunk_decrement,
    apply_update,
)

if TYPE_CHECKING:  # avoid core ↔ sim import cycle; Detections is a pytree
    from repro.sim.oracle import Detections

DetectorFn = Callable[[jax.Array, jax.Array], "Detections"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ExSampleCarry:
    sampler: SamplerState
    matcher: MatcherState
    key: jax.Array
    step: jax.Array            # i32[] — total frames processed
    results: jax.Array         # i32[] — distinct results found so far


def init_carry(
    sampler: SamplerState, matcher: MatcherState, key: jax.Array
) -> ExSampleCarry:
    return ExSampleCarry(
        sampler=sampler,
        matcher=matcher,
        key=key,
        step=jnp.zeros((), jnp.int32),
        results=jnp.zeros((), jnp.int32),
    )


def _process_frame(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    detector: DetectorFn,
    chunk_id: jax.Array,
    det_key: jax.Array,
) -> ExSampleCarry:
    """Algorithm 1 lines 9-16 for one frame of ``chunk_id``."""
    # line 9: within-chunk random+ sample; the per-chunk counter n doubles
    # as the low-discrepancy rank so no extra state is needed.
    rank = carry.sampler.n[chunk_id].astype(jnp.int32)
    frame_id = randomplus_frame(chunks, chunk_id, rank)
    video_id = chunks.video_id[chunk_id]

    # lines 10-11: io + decode + detect (the expensive part)
    dets = detector(det_key, frame_id)

    # line 12: matcher
    m = match_and_update(
        carry.matcher,
        dets.boxes,
        dets.feats,
        dets.valid,
        video_id,
        frame_id,
        chunk_id,
    )

    # lines 13-14: state update.  §3.4: matches whose first sighting lives in
    # a different chunk decrement *that* chunk's N¹, not this one's.
    d1_local = m.d1 - m.cross_chunk
    sampler = apply_update(carry.sampler, chunk_id, m.d0, d1_local)
    valid_home = m.cross_home >= 0
    sampler = apply_cross_chunk_decrement(
        sampler,
        jnp.where(valid_home, m.cross_home, 0),
        valid_home.astype(sampler.n1.dtype),
    )
    return dataclasses.replace(
        carry,
        sampler=sampler,
        matcher=m.new_state,
        step=carry.step + 1,
        results=carry.results + m.d0,
    )


@partial(jax.jit, static_argnames=("detector", "method"))
def exsample_step(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    *,
    detector: DetectorFn,
    method: str = "exact",
) -> ExSampleCarry:
    """One full iteration of Algorithm 1 (choose → process → update)."""
    key, k_choice, k_det = jax.random.split(carry.key, 3)
    carry = dataclasses.replace(carry, key=key)
    chunk_id = thompson.choose_chunks(
        k_choice, carry.sampler, cohorts=1, method=method
    )[0]
    return _process_frame(carry, chunks, detector, chunk_id, k_det)


@partial(jax.jit, static_argnames=("detector", "cohorts", "method"))
def exsample_batch_step(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    *,
    detector: DetectorFn,
    cohorts: int,
    method: str = "exact",
) -> ExSampleCarry:
    """§3.7.1 batched execution: B Thompson cohorts pick B frames which are
    processed as one device batch; statistics update once at the end
    (additive, order-independent).

    The matcher update is inherently sequential in its ring buffer, so the
    B frames' detections are folded with ``lax.fori_loop`` — the expensive
    detector work is still batched, matching the paper's GPU batching story.
    """
    key, k_choice, k_det = jax.random.split(carry.key, 3)
    carry = dataclasses.replace(carry, key=key)
    chunk_ids = thompson.choose_chunks(
        k_choice, carry.sampler, cohorts=cohorts, method=method
    )
    det_keys = jax.random.split(k_det, cohorts)

    def body(i, c):
        return _process_frame(c, chunks, detector, chunk_ids[i], det_keys[i])

    return jax.lax.fori_loop(0, cohorts, body, carry)


def run_search(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    *,
    detector: DetectorFn,
    result_limit: int,
    max_steps: int,
    cohorts: int = 1,
    method: str = "exact",
    trace_every: int = 0,
):
    """Host driver: iterate until ``result_limit`` distinct results,
    ``max_steps`` frames, or repository exhaustion.  Returns
    (final_carry, trace) where trace is a list of (frames_processed,
    results) checkpoints for recall curves.

    One jitted step is dispatched per iteration and ``carry.results`` is
    synced to the host every step, so framework overhead dominates at
    simulation scale — kept as the reference/debugging driver; use
    ``run_search_scan`` (DESIGN.md §7) when throughput matters.

    Checkpoints fire on *boundary crossings* of ``trace_every`` (the step
    counter advances by ``cohorts`` per iteration, so ``step %
    trace_every == 0`` could silently skip every boundary).
    """
    trace = []
    step_fn = (
        partial(exsample_step, detector=detector, method=method)
        if cohorts == 1
        else partial(
            exsample_batch_step, detector=detector, cohorts=cohorts, method=method
        )
    )
    while (
        int(carry.results) < result_limit
        and int(carry.step) < max_steps
        and not bool(jnp.all(carry.sampler.exhausted()))
    ):
        prev_step = int(carry.step)
        carry = step_fn(carry, chunks)
        if trace_every and (int(carry.step) // trace_every) > (prev_step // trace_every):
            trace.append((int(carry.step), int(carry.results)))
    trace.append((int(carry.step), int(carry.results)))
    return carry, trace


@partial(
    jax.jit,
    static_argnames=("detector", "cohorts", "method", "max_steps", "trace_every"),
)
def _search_scan_device(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    result_limit: jax.Array,
    *,
    detector: DetectorFn,
    cohorts: int,
    method: str,
    max_steps: int,
    trace_every: int,
):
    """Device-resident search loop (DESIGN.md §7).

    The whole choose→process→update iteration runs under one
    ``lax.while_loop`` so no per-step host round-trip or dispatch happens.
    Early exit mirrors ``run_search`` exactly: stop when ``results ≥
    result_limit`` OR ``step ≥ max_steps`` OR every chunk is exhausted,
    checked *before* each (cohort) step.  Recall-curve checkpoints are
    scattered into a preallocated i32[cap, 2] buffer on boundary
    crossings of ``trace_every``; the host syncs the buffer once at the
    end.
    """
    # worst case one crossing per trace_every frames, final step may
    # overshoot max_steps by cohorts-1, plus the unconditional final entry
    cap = (max_steps + cohorts - 1) // trace_every + 1 if trace_every else 1
    buf0 = jnp.zeros((cap, 2), jnp.int32)
    n0 = jnp.zeros((), jnp.int32)

    if cohorts == 1:
        step_fn = partial(exsample_step, detector=detector, method=method)
    else:
        step_fn = partial(
            exsample_batch_step, detector=detector, cohorts=cohorts, method=method
        )

    def cond(state):
        c, _, _ = state
        return (
            (c.results < result_limit)
            & (c.step < max_steps)
            & ~jnp.all(c.sampler.exhausted())
        )

    def body(state):
        c, buf, n = state
        c2 = step_fn(c, chunks)
        if trace_every:
            crossed = (c2.step // trace_every) > (c.step // trace_every)
            entry = jnp.stack([c2.step, c2.results])
            buf = buf.at[jnp.where(crossed, n, cap)].set(entry, mode="drop")
            n = n + crossed.astype(jnp.int32)
        return c2, buf, n

    carry, buf, n = jax.lax.while_loop(cond, body, (carry, buf0, n0))
    # unconditional final checkpoint, as in run_search
    final = jnp.stack([carry.step, carry.results])
    buf = buf.at[jnp.minimum(n, cap - 1)].set(final, mode="drop")
    n = jnp.minimum(n + 1, cap)
    return carry, buf, n


def run_search_scan(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    *,
    detector: DetectorFn,
    result_limit: int,
    max_steps: int,
    cohorts: int = 1,
    method: str = "exact",
    trace_every: int = 0,
):
    """Device-resident drop-in for ``run_search`` — same signature, same
    (step, results) trajectory for the same PRNG key, one host sync total.

    ``max_steps``/``cohorts``/``trace_every`` are compile-time constants
    (they size the trace buffer and the cohort batch); ``result_limit``
    stays dynamic so sweeping recall targets reuses one executable.
    """
    carry, buf, n = _search_scan_device(
        carry,
        chunks,
        jnp.asarray(result_limit, jnp.int32),
        detector=detector,
        cohorts=cohorts,
        method=method,
        max_steps=max_steps,
        trace_every=trace_every,
    )
    buf_host = np.asarray(buf)  # the single device→host sync
    trace = [(int(s), int(r)) for s, r in buf_host[: int(n)]]
    return carry, trace
