"""ExSample Algorithm 1 — single-step, batched-cohort and scanned drivers.

The loop is expressed as a pure step function over an ``ExSampleCarry``
pytree so it can be (a) jitted and scanned for simulation-scale benchmarks,
(b) driven frame-by-frame from the host around a real serving stack, and
(c) sharded (see ``repro.core.distributed``).

Detector plug-in protocol:  ``detector(key, frame_id) -> Detections``
(see ``repro.sim.oracle.Detections``).  The oracle/noisy/neural detectors
all satisfy it.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import TYPE_CHECKING, Callable

import jax
import jax.numpy as jnp

from repro.core import thompson
from repro.core.chunks import ChunkIndex, randomplus_frame
from repro.core.matcher import MatcherState, match_and_update
from repro.core.state import (
    SamplerState,
    apply_cross_chunk_decrement,
    apply_update,
)

if TYPE_CHECKING:  # avoid core ↔ sim import cycle; Detections is a pytree
    from repro.sim.oracle import Detections

DetectorFn = Callable[[jax.Array, jax.Array], "Detections"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ExSampleCarry:
    sampler: SamplerState
    matcher: MatcherState
    key: jax.Array
    step: jax.Array            # i32[] — total frames processed
    results: jax.Array         # i32[] — distinct results found so far


def init_carry(
    sampler: SamplerState, matcher: MatcherState, key: jax.Array
) -> ExSampleCarry:
    return ExSampleCarry(
        sampler=sampler,
        matcher=matcher,
        key=key,
        step=jnp.zeros((), jnp.int32),
        results=jnp.zeros((), jnp.int32),
    )


def _process_frame(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    detector: DetectorFn,
    chunk_id: jax.Array,
    det_key: jax.Array,
) -> ExSampleCarry:
    """Algorithm 1 lines 9-16 for one frame of ``chunk_id``."""
    # line 9: within-chunk random+ sample; the per-chunk counter n doubles
    # as the low-discrepancy rank so no extra state is needed.
    rank = carry.sampler.n[chunk_id].astype(jnp.int32)
    frame_id = randomplus_frame(chunks, chunk_id, rank)
    video_id = chunks.video_id[chunk_id]

    # lines 10-11: io + decode + detect (the expensive part)
    dets = detector(det_key, frame_id)

    # line 12: matcher
    m = match_and_update(
        carry.matcher,
        dets.boxes,
        dets.feats,
        dets.valid,
        video_id,
        frame_id,
        chunk_id,
    )

    # lines 13-14: state update.  §3.4: matches whose first sighting lives in
    # a different chunk decrement *that* chunk's N¹, not this one's.
    d1_local = m.d1 - m.cross_chunk
    sampler = apply_update(carry.sampler, chunk_id, m.d0, d1_local)
    valid_home = m.cross_home >= 0
    sampler = apply_cross_chunk_decrement(
        sampler,
        jnp.where(valid_home, m.cross_home, 0),
        valid_home.astype(sampler.n1.dtype),
    )
    return dataclasses.replace(
        carry,
        sampler=sampler,
        matcher=m.new_state,
        step=carry.step + 1,
        results=carry.results + m.d0,
    )


@partial(jax.jit, static_argnames=("detector", "method"))
def exsample_step(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    *,
    detector: DetectorFn,
    method: str = "exact",
) -> ExSampleCarry:
    """One full iteration of Algorithm 1 (choose → process → update)."""
    key, k_choice, k_det = jax.random.split(carry.key, 3)
    carry = dataclasses.replace(carry, key=key)
    chunk_id = thompson.choose_chunks(
        k_choice, carry.sampler, cohorts=1, method=method
    )[0]
    return _process_frame(carry, chunks, detector, chunk_id, k_det)


@partial(jax.jit, static_argnames=("detector", "cohorts", "method"))
def exsample_batch_step(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    *,
    detector: DetectorFn,
    cohorts: int,
    method: str = "exact",
) -> ExSampleCarry:
    """§3.7.1 batched execution: B Thompson cohorts pick B frames which are
    processed as one device batch; statistics update once at the end
    (additive, order-independent).

    The matcher update is inherently sequential in its ring buffer, so the
    B frames' detections are folded with ``lax.fori_loop`` — the expensive
    detector work is still batched, matching the paper's GPU batching story.
    """
    key, k_choice, k_det = jax.random.split(carry.key, 3)
    carry = dataclasses.replace(carry, key=key)
    chunk_ids = thompson.choose_chunks(
        k_choice, carry.sampler, cohorts=cohorts, method=method
    )
    det_keys = jax.random.split(k_det, cohorts)

    def body(i, c):
        return _process_frame(c, chunks, detector, chunk_ids[i], det_keys[i])

    return jax.lax.fori_loop(0, cohorts, body, carry)


def run_search(
    carry: ExSampleCarry,
    chunks: ChunkIndex,
    *,
    detector: DetectorFn,
    result_limit: int,
    max_steps: int,
    cohorts: int = 1,
    method: str = "exact",
    trace_every: int = 0,
):
    """Host driver: iterate until ``result_limit`` distinct results or
    ``max_steps`` frames.  Returns (final_carry, trace) where trace is a
    list of (frames_processed, results) checkpoints for recall curves."""
    trace = []
    step_fn = (
        partial(exsample_step, detector=detector, method=method)
        if cohorts == 1
        else partial(
            exsample_batch_step, detector=detector, cohorts=cohorts, method=method
        )
    )
    while int(carry.results) < result_limit and int(carry.step) < max_steps:
        carry = step_fn(carry, chunks)
        if trace_every and int(carry.step) % trace_every == 0:
            trace.append((int(carry.step), int(carry.results)))
    trace.append((int(carry.step), int(carry.results)))
    return carry, trace
