"""Thompson sampling over Gamma beliefs (paper §3.3.1, Eq. 9-10).

Three interchangeable samplers:

  * ``draw_scores``           — exact Gamma draws via ``jax.random.gamma``.
  * ``draw_scores_wilson_hilferty`` — branch-free Wilson-Hilferty cube-normal
    approximation, the transform used inside the Pallas kernel
    (``repro.kernels.thompson``).  See DESIGN.md §3 for why rejection
    sampling (Marsaglia-Tsang) is replaced on TPU.
  * ``method="pallas"`` in ``choose_chunks`` — the fused VMEM-resident
    kernel (``repro.kernels.thompson.ops.choose``): same WH transform and
    the same ``gamma_params`` clamping, with exhaustion encoded as an
    ``alpha < 0`` sentinel (DESIGN.md §3).  Bit-identical chunk choices to
    ``"wilson_hilferty"`` for the same key.

``choose_chunks`` implements the batched-cohort selection of §3.7.1: B
independent Thompson draws per chunk yield B chunk indices, biased toward
promising chunks but diversified by the posterior noise.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.state import SamplerState


def gamma_params(state: SamplerState) -> tuple[jax.Array, jax.Array]:
    """(α, β) of Eq. 10:  α = N¹_j + α₀,  β = n_j + β₀."""
    alpha = state.n1 + state.alpha0
    beta = state.n + state.beta0
    # N¹ can transiently dip below 0 only through cross-chunk decrements of
    # results later re-found; clamp so the belief stays a valid Gamma.
    return jnp.maximum(alpha, state.alpha0 * 0.5), beta


def draw_scores(key: jax.Array, state: SamplerState, *, cohorts: int = 1) -> jax.Array:
    """Exact Thompson draws.  Returns f32[cohorts, M]."""
    alpha, beta = gamma_params(state)
    draws = jax.random.gamma(key, alpha[None, :].repeat(cohorts, axis=0))
    scores = draws / beta[None, :]
    return jnp.where(state.exhausted()[None, :], -jnp.inf, scores)


def wilson_hilferty(alpha: jax.Array, z: jax.Array) -> jax.Array:
    """Wilson-Hilferty: if X ~ Γ(α, 1) then (X/α)^(1/3) ≈ N(1 − 1/(9α), 1/(9α)).

    Inverting:  X ≈ α · (1 − 1/(9α) + z/(3√α))³, clamped at 0.  Branch-free,
    uses only mul/add/rsqrt — VPU friendly.  Relative quantile error < 1e-2
    for α ≥ 0.3 and the sampler only consumes *ordinal* information.
    """
    c = 1.0 - 1.0 / (9.0 * alpha) + z / (3.0 * jnp.sqrt(alpha))
    return alpha * jnp.maximum(c, 0.0) ** 3


def draw_scores_wilson_hilferty(
    key: jax.Array, state: SamplerState, *, cohorts: int = 1
) -> jax.Array:
    """Approximate Thompson draws via the WH transform.  f32[cohorts, M]."""
    alpha, beta = gamma_params(state)
    z = jax.random.normal(key, (cohorts, alpha.shape[0]), dtype=alpha.dtype)
    scores = wilson_hilferty(alpha[None, :], z) / beta[None, :]
    return jnp.where(state.exhausted()[None, :], -jnp.inf, scores)


@partial(jax.jit, static_argnames=("cohorts", "method"))
def choose_chunks(
    key: jax.Array,
    state: SamplerState,
    *,
    cohorts: int = 1,
    method: str = "exact",
) -> jax.Array:
    """Algorithm 1 lines 5-8, batched (§3.7.1).  Returns i32[cohorts]."""
    if method == "exact":
        scores = draw_scores(key, state, cohorts=cohorts)
    elif method == "wilson_hilferty":
        scores = draw_scores_wilson_hilferty(key, state, cohorts=cohorts)
    elif method == "pallas":
        # deferred import: kernels.thompson.ref imports this module
        from repro.kernels.thompson.ops import choose

        alpha, beta = gamma_params(state)  # already clamped ≥ alpha0/2 > 0
        alpha = jnp.where(state.exhausted(), -1.0, alpha)
        z = jax.random.normal(key, (cohorts, alpha.shape[0]), dtype=alpha.dtype)
        idx, _ = choose(alpha, beta, z)
        return idx
    else:
        raise ValueError(f"unknown Thompson method: {method!r}")
    return jnp.argmax(scores, axis=-1).astype(jnp.int32)


@partial(jax.jit, static_argnames=("cohorts", "method"))
def choose_chunks_batched(
    keys: jax.Array,
    state: SamplerState,
    *,
    cohorts: int = 1,
    method: str = "exact",
) -> jax.Array:
    """Leading-[Q] batched ``choose_chunks`` for the multi-query driver
    (DESIGN.md §9): per-query keys ``keys[Q]`` and per-query statistics
    (every ``state`` leaf carries a leading [Q] axis) decided in ONE
    batched call.  Returns i32[Q, cohorts].

    Contract: row q is bit-identical to ``choose_chunks(keys[q],
    state_q, cohorts, method)`` — ``vmap`` of the PRNG + score path is
    per-lane exact, which is what makes the Q=1 multi-query parity test
    meaningful.  The pallas path stays ONE kernel launch (per-query alpha
    rows, grid [Q·C, M-blocks]) rather than Q serial kernel calls.
    """
    if method in ("exact", "wilson_hilferty"):
        f = partial(choose_chunks, cohorts=cohorts, method=method)
        return jax.vmap(f)(keys, state)
    if method == "pallas":
        from repro.kernels.thompson.ops import choose_batched

        alpha, beta = gamma_params(state)            # [Q, M], pre-clamped
        alpha = jnp.where(state.exhausted(), -1.0, alpha)
        m = alpha.shape[-1]
        z = jax.vmap(
            lambda k: jax.random.normal(k, (cohorts, m), dtype=alpha.dtype)
        )(keys)
        idx, _ = choose_batched(alpha, beta, z)
        return idx
    raise ValueError(f"unknown Thompson method: {method!r}")


def greedy_chunks(state: SamplerState, *, cohorts: int = 1) -> jax.Array:
    """Greedy baseline: always argmax of the point estimate (no posterior
    noise).  The paper shows this underperforms Thompson because it cannot
    diversify; kept as a benchmark arm."""
    from repro.core.state import point_estimate

    idx = jnp.argmax(point_estimate(state)).astype(jnp.int32)
    return jnp.broadcast_to(idx, (cohorts,))


def expected_regret_proxy(state: SamplerState, true_r: jax.Array) -> jax.Array:
    """Diagnostic: gap between the value of the chosen chunk distribution and
    the best chunk, under ground-truth per-chunk new-result rates ``true_r``
    (available in simulation only)."""
    alpha, beta = gamma_params(state)
    mean_scores = alpha / beta
    chosen = jnp.argmax(mean_scores)
    return jnp.max(true_r) - true_r[chosen]
