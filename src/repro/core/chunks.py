"""Chunk partitioning and within-chunk sampling order (paper §3.5, §3.7.2).

A *chunk* is a contiguous span of frames of one video file (default: up to
30 minutes of video — the setting the paper found robust).  ``ChunkIndex``
maps a dense chunk id to its (video, frame offset, length).

``random+`` (§3.7.2) — hierarchically stratified random order — is realized
as a **bit-reversal low-discrepancy permutation**: visiting frame offsets in
bit-reversed order samples one frame per half, then per quarter, … exactly
the "one per hour, then per half hour, …" refinement the paper describes,
with O(1) state (a counter) per chunk.  A per-chunk random rotation keeps
the order unpredictable while preserving stratification.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


def bit_reverse(i: jax.Array, bits: jax.Array) -> jax.Array:
    """Reverse the low ``bits`` bits of i (vectorized, i32 in / i32 out)."""
    i = jnp.asarray(i, jnp.uint32)
    i = ((i & 0x55555555) << 1) | ((i >> 1) & 0x55555555)
    i = ((i & 0x33333333) << 2) | ((i >> 2) & 0x33333333)
    i = ((i & 0x0F0F0F0F) << 4) | ((i >> 4) & 0x0F0F0F0F)
    i = ((i & 0x00FF00FF) << 8) | ((i >> 8) & 0x00FF00FF)
    i = (i << 16) | (i >> 16)
    bits = jnp.asarray(bits, jnp.uint32)
    return jnp.where(bits > 0, i >> (32 - bits), 0).astype(jnp.int32)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ChunkIndex:
    """Static geometry of the chunked repository (M chunks)."""

    video_id: jax.Array      # i32[M] — owning video file
    start: jax.Array         # i32[M] — first global frame id of the chunk
    length: jax.Array        # i32[M] — frames in the chunk
    pow2: jax.Array          # i32[M] — next_pow2(length), for bit-reversal
    bits: jax.Array          # i32[M] — log2(pow2)
    rotation: jax.Array      # i32[M] — per-chunk random rotation offset

    @property
    def num_chunks(self) -> int:
        return self.video_id.shape[0]

    @property
    def total_frames(self) -> int:
        return int(np.asarray(self.start[-1] + self.length[-1]))


def build_chunks(
    video_lengths: Sequence[int],
    *,
    chunk_frames: int,
    seed: int = 0,
) -> ChunkIndex:
    """Split each video into ceil(len/chunk_frames) chunks (§3.5: by file,
    then into ≤30-minute intervals)."""
    vids, starts, lengths = [], [], []
    base = 0
    for v, flen in enumerate(video_lengths):
        off = 0
        while off < flen:
            clen = min(chunk_frames, flen - off)
            vids.append(v)
            starts.append(base + off)
            lengths.append(clen)
            off += clen
        base += flen
    lengths_np = np.asarray(lengths, np.int32)
    pow2 = np.asarray([_next_pow2(l) for l in lengths], np.int32)
    bits = np.asarray([int(p).bit_length() - 1 for p in pow2], np.int32)
    rng = np.random.default_rng(seed)
    rotation = rng.integers(0, np.maximum(lengths_np, 1), dtype=np.int64).astype(np.int32)
    return ChunkIndex(
        video_id=jnp.asarray(vids, jnp.int32),
        start=jnp.asarray(starts, jnp.int32),
        length=jnp.asarray(lengths_np),
        pow2=jnp.asarray(pow2),
        bits=jnp.asarray(bits),
        rotation=jnp.asarray(rotation),
    )


def randomplus_offset(index: ChunkIndex, chunk: jax.Array, k: jax.Array) -> jax.Array:
    """Frame offset (within the chunk) of the k-th random+ sample.

    ``bit_reverse(k mod pow2)`` enumerates [0, pow2) in stratified order;
    non-power-of-two lengths are handled by *cycle-walking* the van der
    Corput permutation: a candidate ≥ length is re-permuted until it lands
    in [0, length).  Because ``bit_reverse`` is an involution the walk
    terminates after one step (``rev(rev(raw)) = raw < length``), so the
    whole thing is a single ``where`` — branch-free and vectorized.
    Cycle-walking a bijection of the superset restricted to [0, length) is
    itself a bijection, so the first ``length`` ranks enumerate every
    offset exactly once — rescaling (``floor(frac·length)``) collided for
    non-power-of-two lengths, firing ``exhausted()`` before some offsets
    were ever visited while revisiting others.  A per-chunk rotation
    decorrelates chunks.
    """
    chunk = jnp.asarray(chunk, jnp.int32)
    length = index.length[chunk]
    pow2 = jnp.maximum(index.pow2[chunk], 1)
    bits = index.bits[chunk]
    rot = index.rotation[chunk]
    raw = jnp.asarray(k, jnp.int32) % pow2
    cand = bit_reverse(raw, bits)
    # raw ≥ length only for ranks past exhaustion (the chunk fully
    # sampled); the final modulo wraps those back in range
    offset = jnp.where(cand < length, cand, raw)
    return (offset + rot) % jnp.maximum(length, 1)


def randomplus_frame(index: ChunkIndex, chunk: jax.Array, k: jax.Array) -> jax.Array:
    """Global frame id of the k-th random+ sample from ``chunk``
    (Algorithm 1 line 9 with the §3.7.2 within-chunk sampler)."""
    return index.start[chunk] + randomplus_offset(index, chunk, k)


def global_randomplus_order(total_frames: int, *, seed: int = 0) -> np.ndarray:
    """random+ over the *whole* dataset (the paper's strongest non-adaptive
    baseline): a bit-reversal permutation of [0, total) with random rotation.

    Host-side (numpy) — used by baseline drivers and benchmarks.
    """
    pow2 = _next_pow2(total_frames)
    bits = int(pow2).bit_length() - 1
    idx = np.arange(pow2, dtype=np.uint64)
    rev = np.zeros(pow2, dtype=np.uint64)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    rev = rev[rev < total_frames].astype(np.int64)
    rng = np.random.default_rng(seed)
    rot = int(rng.integers(0, max(total_frames, 1)))
    return ((rev + rot) % total_frames).astype(np.int64)
