"""Persistent repository index (DESIGN.md §13): cross-query reuse economics.

The paper's sampler optimizes ONE query; a repository answers many over
its lifetime, and every query today re-detects frames the repository has
already paid for.  This bench measures what the persistent index buys:

* **warm replay** — the identical query twice through the ``SearchPlan``
  API with a snapshot directory between runs: run 2 preloads the device
  cache from the host tier and must produce the IDENTICAL result count
  with ≥5× fewer detector invocations (the headline gate; the
  deterministic replay typically hits 100% and invokes the detector
  zero times),
* **warm service** — a second :class:`SearchService` constructed over
  the index the first service's tenant populated (the process-restart
  story): its tenant's per-tenant attributed detector economics must
  show the same ≥5× saving, visible as ``index_hits``.

Gates: identical result counts cold vs warm, warm detector invocations
≤ cold/5 in BOTH scenarios.
"""
from __future__ import annotations

import tempfile

import jax
import jax.numpy as jnp


def main(quick: bool = False) -> None:
    from repro.configs.exsample_paper import dashcam
    from repro.core import (
        Execution,
        SearchPlan,
        init_carry_multi,
        init_matcher,
        init_state,
    )
    from repro.core.plan import IndexSpec
    from repro.index import RepositoryIndex
    from repro.serve.service import SearchService
    from repro.sim import generate
    from repro.sim.oracle import oracle_detect

    scale = 0.02 if quick else 0.05
    limit = 10 if quick else 25
    max_steps = 1_500 if quick else 4_000
    cohorts = 4
    setup = dashcam(seed=0, scale=scale)
    repo, chunks = generate(setup.repo)
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    print(f"repository: {chunks.total_frames:,} frames, "
          f"{chunks.length.shape[0]} chunks (scale {scale})")

    fresh = lambda: init_carry_multi(
        init_state(chunks.length), init_matcher(max_results=512),
        jnp.stack([jax.random.PRNGKey(0)]),
    )

    # ---- scenario 1: the identical query, cold then warm ----------------
    with tempfile.TemporaryDirectory() as tmp:
        spec = IndexSpec(path=tmp)
        plan = lambda: SearchPlan(
            result_limit=limit, max_steps=max_steps, cohorts=cohorts,
            execution=Execution(queries_axis=True, cache=-1, index=spec),
        )
        cold = plan().run(fresh(), chunks, detector=det)
        warm = plan().run(fresh(), chunks, detector=det)
        c_inv = cold.stats.detector_invocations
        w_inv = warm.stats.detector_invocations
        print(f"cold run : {cold.results[0]} results / "
              f"{cold.steps[0]:,} frames / {c_inv:,} detector invocations "
              f"({cold.stats.persisted_detections:,} persisted)")
        print(f"warm run : {warm.results[0]} results / "
              f"{warm.steps[0]:,} frames / {w_inv:,} detector invocations "
              f"({warm.stats.index_hits:,} index hits)")
        assert warm.results[0] == cold.results[0], "replay must be exact"
        assert c_inv >= 5 * max(w_inv, 1) or w_inv == 0, (
            f"warm run must invoke the detector >=5x less: {c_inv} vs {w_inv}")
        ratio = c_inv / w_inv if w_inv else float("inf")
        print(f"GATE OK  : warm reuse {ratio:.0f}x "
              f"({c_inv:,} -> {w_inv:,} invocations)")

    # ---- scenario 2: second tenant over a warm service ------------------
    index = RepositoryIndex(detector_version="v0")
    svc_plan = SearchPlan(
        result_limit=limit, max_steps=max_steps, cohorts=cohorts,
        execution=Execution(queries_axis=True),
    )

    def run_tenant(tid, seed):
        svc = SearchService(
            fresh(), chunks, det, cohorts=cohorts, num_workers=2,
            slots_per_batch=2, cache_frames=chunks.total_frames,
            index=index,
        )
        tenant = svc.submit(tid, svc_plan, seed=seed)
        svc.start(pump=False)
        svc.drain()
        svc.stop()
        return tenant.to_dict()

    t1 = run_tenant("cold-tenant", seed=1)   # populates the shared index
    t2 = run_tenant("warm-tenant", seed=1)   # fresh service, warm index
    print(f"tenant 1 : {t1['results']} results / "
          f"{t1['detector_invocations']:,} fresh detections")
    print(f"tenant 2 : {t2['results']} results / "
          f"{t2['detector_invocations']:,} fresh detections / "
          f"{t2['index_hits']:,} index hits")
    assert t2["results"] == t1["results"]
    assert t2["index_hits"] > 0
    assert t1["detector_invocations"] >= 5 * max(
        t2["detector_invocations"], 1
    ) or t2["detector_invocations"] == 0, (
        "second tenant over a warm service must save >=5x: "
        f"{t1['detector_invocations']} vs {t2['detector_invocations']}")
    ratio = (
        t1["detector_invocations"] / t2["detector_invocations"]
        if t2["detector_invocations"] else float("inf")
    )
    print(f"GATE OK  : warm-service reuse {ratio:.0f}x, "
          f"index holds {len(index):,} detections")


if __name__ == "__main__":
    main()
