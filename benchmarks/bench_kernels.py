"""Kernel microbenchmarks: wall time of the pure-JAX reference paths on CPU
(the kernels themselves target TPU; interpret-mode timing is meaningless),
plus the analytic VMEM working set + arithmetic intensity per kernel —
the quantities the BlockSpec choices were made against."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode.ref import decode_ref
from repro.kernels.iou_match.ref import iou_ref
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.thompson.ref import thompson_ref


def main():
    key = jax.random.PRNGKey(0)
    rnd = lambda i, s: jax.random.normal(jax.random.fold_in(key, i), s)

    # flash attention ref
    q, k, v = rnd(1, (1, 512, 8, 64)), rnd(2, (1, 512, 2, 64)), rnd(3, (1, 512, 2, 64))
    f = jax.jit(lambda a, b, c: attention_ref(a, b, c, causal=True))
    us = timed(f, q, k, v)
    flops = 2 * 512 * 512 * 8 * 64 * 2 / 2
    emit("flash_attention_ref_512", us, f"vmem_tile=2.4MB@bq256 ai={flops/(512*8*64*4*3):.0f}")

    # flash decode ref
    qd = rnd(4, (4, 8, 64))
    kc, vc = rnd(5, (4, 2048, 2, 64)), rnd(6, (4, 2048, 2, 64))
    cl = jnp.full((4,), 2048, jnp.int32)
    f = jax.jit(decode_ref)
    emit("flash_decode_ref_2k", timed(f, qd, kc, vc, cl), "vmem_cell<1MB@bk512")

    # ssd scan ref
    x = rnd(7, (8, 1024, 64))
    dt = jax.nn.softplus(rnd(8, (8, 1024)))
    bm, cm = rnd(9, (8, 1024, 128)) * 0.3, rnd(10, (8, 1024, 128)) * 0.3
    a = -jnp.exp(rnd(11, (8,)))
    f = jax.jit(lambda *t: ssd_ref(*t, chunk=128))
    emit("ssd_scan_ref_1k", timed(f, x, dt, bm, cm, a), "vmem_cell=0.3MB@Q128")

    # thompson ref — the paper's per-step decision at 10^5 chunks
    alpha = jnp.abs(rnd(12, (100_000,))) + 0.1
    beta = jnp.abs(rnd(13, (100_000,))) * 10 + 1
    z = rnd(14, (50, 100_000))
    f = jax.jit(thompson_ref)
    emit("thompson_ref_100k_chunks_50_cohorts", timed(f, alpha, beta, z),
         "fused-kernel streams 4B/chunk/cohort")

    # iou ref
    a_boxes = jax.random.uniform(jax.random.fold_in(key, 15), (64, 4))
    b_boxes = jax.random.uniform(jax.random.fold_in(key, 16), (4096, 4))
    f = jax.jit(iou_ref)
    emit("iou_ref_64x4096", timed(f, a_boxes, b_boxes), "tile=128x512")


if __name__ == "__main__":
    main()
