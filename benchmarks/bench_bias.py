"""Paper §3.3.2 / Fig. 2: empirical validation of the estimator + Gamma belief.

Reproduces the paper's simulation: 1000 lognormal-skewed durations, frames
sampled as independent Bernoulli draws; tracks (n, N¹, R(n+1)) and checks
  * the point estimate N¹/n brackets the true R(n+1) (bias ≤ bounds),
  * the sampling distribution of N¹ matches Poisson(λ=Σπᵢ) (variance
    ratio ≈ 1 — the paper's Theorem on the sampling distribution).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import good_turing as gt


def run(num_instances: int = 1000, reps: int = 400, seed: int = 0):
    rng = np.random.default_rng(seed)
    # paper: lognormal durations over ~1M frames; min p ~3e-6, max ~0.15
    p = jnp.asarray(
        np.exp(rng.normal(-6.5, 1.8, num_instances)).clip(3e-6, 0.15), jnp.float32
    )
    rows = []
    for n in (30, 100, 1000, 10_000, 60_000):
        keys = jax.random.split(jax.random.fold_in(jax.random.PRNGKey(seed), n), reps)

        def draw(k):
            seen, _ = gt.simulate_counts(k, p, n)
            return gt.n1_from_counts(seen), gt.remaining_value(p, seen)

        n1s, rems = jax.vmap(draw)(keys)
        est = np.asarray(n1s) / n
        rem = np.asarray(rems)
        lam = float(gt.poisson_rate(p, jnp.float32(n)))
        rows.append(
            dict(
                n=n,
                mean_est=float(est.mean()),
                mean_true=float(rem.mean()),
                rel_bias=float((est.mean() - rem.mean()) / max(est.mean(), 1e-12)),
                bound_max_p=float(jnp.max(p)),
                var_n1=float(np.var(np.asarray(n1s))),
                poisson_lambda=lam,
            )
        )
    return rows


def main():
    print("n,mean_N1_over_n,mean_true_R,rel_bias,bound_max_p,var_N1,poisson_lambda,verdict")
    ok = True
    for r in run():
        within = -0.05 <= r["rel_bias"] <= r["bound_max_p"] + 0.05
        pois = 0.5 <= r["var_n1"] / max(r["poisson_lambda"], 1e-9) <= 2.0
        ok &= within
        print(
            f"{r['n']},{r['mean_est']:.5g},{r['mean_true']:.5g},"
            f"{r['rel_bias']:.4f},{r['bound_max_p']:.3f},{r['var_n1']:.4g},"
            f"{r['poisson_lambda']:.4g},{'ok' if within and pois else 'CHECK'}"
        )
    print(f"bias_bounds_hold,{ok}")


if __name__ == "__main__":
    main()
