"""Multi-query amortization (DESIGN.md §9): detector invocations per result.

ExSample's cost model (paper §3.7.1) assumes detector invocations dominate,
so serving Q concurrent queries over the same repository should amortize
one decode/detect pass across all of them.  This bench runs the acceptance
comparison: Q = 8 overlapping dashcam queries (two predicates, four users
each — the Focus/EKO shared-ingest scenario) through ``run_search_multi``
with cross-query dedup + a repository-sized detection cache, against the
same Q queries run sequentially through ``run_search_scan`` — identical
per-query keys, identical result limits, identical frame budget.

With the oracle detector the per-query trajectories are bit-identical
between the two arms (dedup/caching change WHICH detector invocations
happen, never the values a query consumes), so the ratio of detector
invocations per result is exactly the amortization factor.  Acceptance
gate: ≥ 2x fewer detector invocations per result at Q=8.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

Q_CLASSES = (0, 0, 0, 0, 1, 1, 1, 1)   # two predicates × four users


def main(quick: bool = False) -> None:
    from repro.configs.exsample_paper import dashcam
    from repro.core import (
        Execution,
        SearchPlan,
        init_carry,
        init_carry_multi,
        init_matcher,
        init_state,
    )
    from repro.sim import generate
    from repro.sim.oracle import class_select, filter_class, oracle_detect

    scale = 0.02 if quick else 0.05
    limit = 15 if quick else 40
    budget = 2_048 if quick else 8_192
    cohorts = 8
    setup = dashcam(seed=0, scale=scale)
    repo, chunks = generate(setup.repo)
    q_n = len(Q_CLASSES)

    det_all = lambda key, frame: oracle_detect(repo, frame, query_class=None)
    select = class_select(repo, Q_CLASSES)

    def class_det(c):
        # sequential arm: same shared detector output, filtered to one
        # class — the same predicate as select(q, ·) in the multi arm
        return lambda key, frame: filter_class(repo, det_all(key, frame), c)

    keys = [jax.random.fold_in(jax.random.PRNGKey(0), q) for q in range(q_n)]

    # ---- sequential arm: Q independent single-query scan plans ----
    seq_plan = SearchPlan(
        result_limit=limit, max_steps=budget, cohorts=cohorts,
        method="wilson_hilferty",
    )
    seq_steps, seq_results, seq_wall = [], [], 0.0
    for q in range(q_n):
        carry = init_carry(
            init_state(chunks.length), init_matcher(max_results=4096), keys[q]
        )
        t0 = time.perf_counter()
        res = seq_plan.run(carry, chunks, detector=class_det(Q_CLASSES[q]))
        seq_wall += time.perf_counter() - t0
        seq_steps.append(res.steps[0])
        seq_results.append(res.results[0])

    # ---- multi arm: one driver, one shared detector pass per round ----
    carries = init_carry_multi(
        init_state(chunks.length), init_matcher(max_results=4096),
        jnp.stack(keys),
    )
    t0 = time.perf_counter()
    mres = SearchPlan(
        queries=q_n, result_limit=limit, max_steps=budget, cohorts=cohorts,
        method="wilson_hilferty",
        execution=Execution(queries_axis=True, cache=-1),
    ).run(carries, chunks, detector=det_all, select=select)
    multi_wall = time.perf_counter() - t0
    multi_results = list(mres.results)
    stats = {
        "detector_invocations": mres.stats.detector_invocations,
        "cache_hits": mres.stats.cache_hits,
        "rounds": mres.stats.rounds,
        "frames_sampled": mres.stats.frames_sampled,
    }

    seq_inv = sum(seq_steps)          # one detector call per sampled frame
    multi_inv = stats["detector_invocations"]
    seq_per_result = seq_inv / max(sum(seq_results), 1)
    multi_per_result = multi_inv / max(sum(multi_results), 1)
    ratio = seq_per_result / max(multi_per_result, 1e-9)

    print("arm,queries,results,frames_sampled,detector_invocations,"
          "det_per_result,steps_per_sec")
    print(f"sequential,{q_n},{sum(seq_results)},{seq_inv},{seq_inv},"
          f"{seq_per_result:.2f},{seq_inv / max(seq_wall, 1e-9):.0f}")
    print(f"multi,{q_n},{sum(multi_results)},{stats['frames_sampled']},"
          f"{multi_inv},{multi_per_result:.2f},"
          f"{stats['frames_sampled'] / max(multi_wall, 1e-9):.0f}")
    print(f"amortization,{q_n},cache_hits={stats['cache_hits']},"
          f"rounds={stats['rounds']},ratio={ratio:.2f}x,"
          f"{'OK' if ratio >= 2.0 else 'FAIL'}")
    # per-query trajectories are bit-identical across arms (oracle detector)
    assert multi_results == seq_results, (multi_results, seq_results)
    assert ratio >= 2.0, f"amortization {ratio:.2f}x below the 2x gate"


if __name__ == "__main__":
    import sys

    main(quick="--quick" in sys.argv)
