"""Roofline summary table from the dry-run artifacts (EXPERIMENTS §Roofline).

Reads artifacts/dryrun/<mesh>/*.json and prints the per-cell three-term
roofline.  This is the benchmark twin of the §Roofline deliverable — run
``python -m repro.launch.dryrun`` first (or rely on the checked-in
artifacts).
"""
from __future__ import annotations

import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load(mesh_tag: str):
    d = os.path.join(ART, mesh_tag)
    if not os.path.isdir(d):
        return []
    out = []
    for f in sorted(os.listdir(d)):
        with open(os.path.join(d, f)) as fh:
            out.append(json.load(fh))
    return out


def main():
    for mesh_tag in ("single_pod_16x16", "multi_pod_2x16x16",
                     "single_pod_16x16_optimized"):
        recs = load(mesh_tag)
        if not recs:
            continue
        print(f"\n== {mesh_tag} ==")
        print("cell,t_compute_s,t_memory_s,t_collective_s,bottleneck,"
              "useful_ratio,mfu@roofline,hbm_tpu_GiB,fits")
        for r in recs:
            if r.get("skipped"):
                print(f"{r['name']},SKIPPED({r['skipped']})")
                continue
            print(
                f"{r['name']},{r['t_compute_s']:.4g},{r['t_memory_s']:.4g},"
                f"{r['t_collective_s']:.4g},{r['bottleneck']},"
                f"{r['useful_flops_ratio']:.3f},{r['mfu_at_roofline']:.3f},"
                f"{r.get('analytic_hbm_bytes', 0)/2**30:.2f},{r.get('fits_hbm')}"
            )


if __name__ == "__main__":
    main()
