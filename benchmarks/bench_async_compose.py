"""Async × Q-axis composition (DESIGN.md §11): detector-invocation amortization.

The elastic slot scheduler serves Q concurrent queries through a pool of
async workers with ONE shared dedup + detection-cache pass per slot batch.
This bench runs the acceptance comparison: Q = 8 overlapping dashcam
queries (two predicates, four users each — the same workload as
``bench_multiquery``) through the composed ``async_multi`` lowering with
4 workers, against the same 8 queries run one after another through the
single-query async driver (``Execution(async_workers=4)``) — identical
per-query keys, identical result limits, identical frame budget.

The sequential arm pays one detector invocation per sampled frame (no
cross-query sharing is possible: each run owns the process).  The
composed arm shares the per-batch dedup and the repository-sized
``DetectionCache`` across all 8 queries, so invocations per result drop
by roughly the predicate multiplicity.  Acceptance gate: ≥ 2x fewer
detector invocations per result at Q=8 / 4 workers.  (Per-query
trajectories in the sequential-async arm are merge-order dependent, so
unlike ``bench_multiquery`` the arms are compared on aggregate cost, not
bit parity — the composed arm's bit parity vs solo scans is pinned by
tests/test_async_compose.py.)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

Q_CLASSES = (0, 0, 0, 0, 1, 1, 1, 1)   # two predicates × four users
WORKERS = 4


def main(quick: bool = False) -> None:
    from repro.configs.exsample_paper import dashcam
    from repro.core import (
        Execution,
        SearchPlan,
        init_carry,
        init_carry_multi,
        init_matcher,
        init_state,
    )
    from repro.sim import generate
    from repro.sim.oracle import class_select, filter_class, oracle_detect

    scale = 0.02 if quick else 0.05
    limit = 15 if quick else 40
    budget = 2_048 if quick else 8_192
    cohorts = 8
    setup = dashcam(seed=0, scale=scale)
    repo, chunks = generate(setup.repo)
    q_n = len(Q_CLASSES)

    det_all = lambda key, frame: oracle_detect(repo, frame, query_class=None)
    select = class_select(repo, Q_CLASSES)

    def class_det(c):
        # sequential arm: the shared detector output filtered to one
        # class — the same predicate as select(q, ·) in the composed arm
        return lambda key, frame: filter_class(repo, det_all(key, frame), c)

    keys = [jax.random.fold_in(jax.random.PRNGKey(0), q) for q in range(q_n)]

    # ---- sequential arm: Q single-query async runs, one after another ----
    seq_plan = SearchPlan(
        result_limit=limit, max_steps=budget, cohorts=cohorts,
        execution=Execution(async_workers=WORKERS),
    )
    seq_inv, seq_results, seq_wall = 0, [], 0.0
    for q in range(q_n):
        carry = init_carry(
            init_state(chunks.length), init_matcher(max_results=4096), keys[q]
        )
        t0 = time.perf_counter()
        res = seq_plan.run(carry, chunks, detector=class_det(Q_CLASSES[q]))
        seq_wall += time.perf_counter() - t0
        seq_inv += res.stats.detector_invocations
        seq_results.append(res.results[0])

    # ---- composed arm: one elastic slot pool, shared dedup + cache ----
    carries = init_carry_multi(
        init_state(chunks.length), init_matcher(max_results=4096),
        jnp.stack(keys),
    )
    t0 = time.perf_counter()
    mres = SearchPlan(
        queries=q_n, result_limit=limit, max_steps=budget, cohorts=cohorts,
        execution=Execution(
            queries_axis=True, async_workers=WORKERS, cache=-1
        ),
    ).run(carries, chunks, detector=det_all, select=select)
    multi_wall = time.perf_counter() - t0
    multi_results = list(mres.results)
    multi_inv = mres.stats.detector_invocations

    seq_per_result = seq_inv / max(sum(seq_results), 1)
    multi_per_result = multi_inv / max(sum(multi_results), 1)
    ratio = seq_per_result / max(multi_per_result, 1e-9)

    print("arm,queries,workers,results,frames_sampled,detector_invocations,"
          "det_per_result,wall_s")
    print(f"sequential_async,{q_n},{WORKERS},{sum(seq_results)},{seq_inv},"
          f"{seq_inv},{seq_per_result:.2f},{seq_wall:.2f}")
    print(f"async_multi,{q_n},{WORKERS},{sum(multi_results)},"
          f"{mres.stats.frames_sampled},{multi_inv},"
          f"{multi_per_result:.2f},{multi_wall:.2f}")
    print(f"amortization,{q_n},cache_hits={mres.stats.cache_hits},"
          f"rounds={mres.stats.rounds},spilled={mres.stats.results_spilled},"
          f"ratio={ratio:.2f}x,{'OK' if ratio >= 2.0 else 'FAIL'}")
    # the no-overflow construction guarantee held: nothing was lost
    assert not mres.stats.merge_overflow
    assert ratio >= 2.0, f"amortization {ratio:.2f}x below the 2x gate"


if __name__ == "__main__":
    import sys

    main(quick="--quick" in sys.argv)
