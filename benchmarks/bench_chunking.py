"""Paper §3.5: chunking-strategy sensitivity.

"Making intervals too long means less opportunity for scores to differ…
making intervals very short means a lot of sampling is spent estimating
which chunks are better."  We sweep the chunk length over the dashcam-style
repository and report frames-to-recall for ExSample (random+ is
chunk-independent and serves as the fixed denominator)."""
from __future__ import annotations

import jax

from repro.configs.exsample_paper import dashcam
from repro.core import (
    Execution,
    SearchPlan,
    init_carry,
    init_matcher,
    init_state,
)
from repro.core.baselines import FrameSchedule, run_schedule
from repro.core.chunks import build_chunks
from repro.sim import generate
from repro.sim.oracle import oracle_detect


def main(scale: float = 0.15):
    setup = dashcam(scale=scale)
    repo, base_chunks = generate(setup.repo)
    total = base_chunks.total_frames
    lengths = [int(l) for l in __import__("numpy").asarray(
        jax.numpy.bincount(
            base_chunks.video_id, weights=base_chunks.length.astype(jax.numpy.float32)
        )
    )]
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    limit = 40

    rp, _ = run_schedule(
        init_carry(init_state(base_chunks.length), init_matcher(max_results=2048),
                   jax.random.PRNGKey(0)),
        base_chunks, FrameSchedule.randomplus(total, 8000),
        detector=det, result_limit=limit,
    )
    print("chunk_frames,num_chunks,frames_exsample,savings_vs_random+")
    for chunk_frames in (600, 2_000, 8_100, 27_000, max(total // 2, 1)):
        chunks = build_chunks(lengths, chunk_frames=chunk_frames, seed=0)
        carry = init_carry(
            init_state(chunks.length), init_matcher(max_results=2048),
            jax.random.PRNGKey(0),
        )
        ex = SearchPlan(
            result_limit=limit, max_steps=8000, cohorts=8,
            execution=Execution(strategy="host"),
        ).run(carry, chunks, detector=det).carry
        print(f"{chunk_frames},{chunks.num_chunks},{int(ex.step)},"
              f"{int(rp.step)/max(int(ex.step),1):.2f}")
    print(f"random+_reference,{int(rp.step)} frames")


if __name__ == "__main__":
    main()
