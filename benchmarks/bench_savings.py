"""Paper Figs. 3-4: frames-processed savings vs random+ at fixed recall.

Runs ExSample / random+ / random / greedy / surrogate over the dashcam- and
BDD-style simulated repositories, for several query classes × recall
targets, reporting frames processed and the savings ratio vs random+ (the
paper's normalization).  Expected: geomean savings ≈ 2×, up to ~4× on
localized classes (paper §4.5); greedy below Thompson; surrogate wins on
frames at low recall but loses on wall-clock (bench_overhead covers time).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.exsample_paper import bdd, dashcam
from repro.core import (
    Execution,
    SearchPlan,
    init_carry,
    init_matcher,
    init_state,
)
from repro.core.baselines import (
    FrameSchedule,
    run_greedy,
    run_schedule,
    surrogate_schedule,
)
from repro.sim import generate
from repro.sim.oracle import frame_embedding, oracle_detect
from repro.sim.repository import instances_visible


def _count_instances(repo, query_class: int) -> int:
    return int(jnp.sum(repo.inst_class == query_class))


def _fresh(chunks, seed):
    return init_carry(
        init_state(chunks.length),
        init_matcher(max_results=4096),
        jax.random.PRNGKey(seed),
    )


def _surrogate_scores(repo, total_frames: int, query_class: int, stride: int = 37):
    """Cheap stand-in for the trained surrogate: score = noisy ground truth
    (the BlazeIt best case — its model can't do better than this)."""
    frames = jnp.arange(0, total_frames, stride)
    vis = jax.vmap(
        lambda f: jnp.sum(
            instances_visible(repo, f) & (repo.inst_class == query_class)
        )
    )(frames).astype(jnp.float32)
    rng = np.random.default_rng(0)
    dense = np.repeat(np.asarray(vis), stride)[:total_frames]
    return dense + rng.normal(0, 0.3, total_frames)


def run(scale: float = 0.15, classes=(0, 1, 2), recalls=(0.1, 0.5),
        max_steps: int = 5000, seed: int = 0, quick: bool = False):
    # recall 0.9 matches the paper's third setting but multiplies runtime
    # ~4x on CPU; pass recalls=(0.1, 0.5, 0.9) for the full sweep.
    rows = []
    setups = [("dashcam", dashcam(seed=seed, scale=scale))]
    if not quick:
        setups.append(("bdd", bdd(seed=seed, scale=scale)))
    for ds_name, setup in setups:
        repo, chunks = generate(setup.repo)
        for qc in classes:
            n_total = _count_instances(repo, qc)
            if n_total < 10:
                continue
            det = lambda key, frame: oracle_detect(repo, frame, query_class=qc)
            for recall in recalls:
                limit = max(int(n_total * recall), 1)
                cohorts = 8 if limit >= 24 else 1   # §3.7.1: don't let a
                # batched cohort overshoot tiny limit queries
                # device-resident driver: identical (step, results) to the
                # host loop (tests/test_scan_driver.py) at a fraction of the
                # wall-clock — bench_overhead.py quantifies the gap
                ex = SearchPlan(
                    result_limit=limit, max_steps=max_steps, cohorts=cohorts,
                    execution=Execution(strategy="scan"),
                ).run(_fresh(chunks, seed), chunks, detector=det).carry
                rp, _ = run_schedule(
                    _fresh(chunks, seed), chunks,
                    FrameSchedule.randomplus(chunks.total_frames, max_steps),
                    detector=det, result_limit=limit,
                )
                rnd, _ = run_schedule(
                    _fresh(chunks, seed), chunks,
                    FrameSchedule.random(chunks.total_frames, max_steps),
                    detector=det, result_limit=limit,
                )
                gr, _ = run_greedy(
                    _fresh(chunks, seed), chunks, detector=det,
                    result_limit=limit, max_steps=max_steps,
                )
                scores = _surrogate_scores(repo, chunks.total_frames, qc)
                sur, _ = run_schedule(
                    _fresh(chunks, seed), chunks,
                    surrogate_schedule(scores, dedup_window=90)[:max_steps],
                    detector=det, result_limit=limit,
                )
                rows.append(
                    dict(
                        dataset=ds_name, query=qc, recall=recall, limit=limit,
                        exsample=int(ex.step), randomplus=int(rp.step),
                        random=int(rnd.step), greedy=int(gr.step),
                        surrogate=int(sur.step),
                    )
                )
    return rows


def main(quick: bool = False):
    rows = run(quick=quick)
    savings = []
    print("dataset,query,recall,frames_exsample,frames_random+,frames_random,"
          "frames_greedy,frames_surrogate,savings_vs_random+")
    for r in rows:
        s = r["randomplus"] / max(r["exsample"], 1)
        savings.append(s)
        print(
            f"{r['dataset']},{r['query']},{r['recall']},{r['exsample']},"
            f"{r['randomplus']},{r['random']},{r['greedy']},{r['surrogate']},"
            f"{s:.2f}"
        )
    geo = math.exp(sum(math.log(max(s, 1e-9)) for s in savings) / len(savings))
    print(f"geomean_savings,{geo:.3f},paper_reports~2x_(1.1-4x)")
    return geo


if __name__ == "__main__":
    main()
