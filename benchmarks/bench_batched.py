"""Paper §3.7.1: batched-cohort sensitivity (B up to 50, 'no significant
drop') + the asynchronous/straggler model of DESIGN.md §5."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import (
    Execution,
    SearchPlan,
    init_carry,
    init_matcher,
    init_state,
)
from repro.core.distributed import straggler_robust_rounds
from repro.sim import RepoSpec, generate
from repro.sim.oracle import oracle_detect


def main():
    spec = RepoSpec(
        video_lengths=[30_000] * 4, num_instances=300, chunk_frames=3_000,
        locality=4.0, seed=2,
    )
    repo, chunks = generate(spec)
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    limit = 25
    print("cohorts,frames_to_limit,results")
    for b in (1, 4, 16, 50):
        carry = init_carry(
            init_state(chunks.length), init_matcher(max_results=1024),
            jax.random.PRNGKey(0),
        )
        out = SearchPlan(
            result_limit=limit, max_steps=3000, cohorts=b,
            execution=Execution(strategy="host"),
        ).run(carry, chunks, detector=det).carry
        print(f"{b},{int(out.step)},{int(out.results)}")

    # straggler mitigation: barrier vs commutative-async round time
    print("\nworkers,p99_latency_x,barrier_round_s,async_round_s,speedup")
    rng = np.random.default_rng(0)
    for slow in (1.0, 3.0, 10.0):
        lat = rng.lognormal(0, 0.2, 256)
        lat[: max(int(256 * 0.01), 1)] *= slow
        barrier, async_ = np.asarray(
            straggler_robust_rounds(lat, sync_every=4, round_time=0.05)
        )
        print(f"256,{slow}x,{barrier:.3f},{async_:.3f},{barrier/async_:.2f}x")


if __name__ == "__main__":
    main()
