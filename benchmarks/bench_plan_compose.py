"""Composed Q×shards lowering: detector invocations per result (DESIGN.md §10).

The acceptance comparison for the ``SearchPlan`` composition the legacy API
could not express: Q = 8 overlapping dashcam queries (two predicates × four
users) on an 8-way data mesh, THREE arms at identical per-query keys and
budgets:

  * **sequential-sharded** — the legacy-API ceiling: one 8-way
    ``strategy='sharded'`` plan per query, run one after another; every
    sampled frame pays a detector invocation.
  * **composed** — ONE ``queries_axis × shards`` plan: all 8 queries inside
    the §8 mesh loop, sharing per-shard deduplicated + cached detector
    passes.  With the oracle detector each query's trajectory is
    bit-identical to its own sequential-sharded run (the §10 parity
    contract), so the invocation ratio is exactly the amortization factor.
  * **single-device multi** — the §9 Q-batched driver, for the result-count
    cross-check (different PRNG path, so statistical agreement only).

Gates: composed per-query results == sequential-sharded per-query results
(bit parity); ≥ 2x fewer detector invocations per result than
sequential-sharded; per-query result counts within 15% (or one sync
window) of the single-device multi driver.

Needs 8 devices, so the parent re-execs a child with forced host devices
(same pattern as bench_sharded).
"""
from __future__ import annotations

import os
import subprocess
import sys

Q_CLASSES = (0, 0, 0, 0, 1, 1, 1, 1)   # two predicates × four users
SHARDS = 8


def _child(quick: bool) -> None:
    import time

    import jax
    import jax.numpy as jnp

    from repro.configs.exsample_paper import dashcam
    from repro.core import (
        Execution,
        SearchPlan,
        init_carry,
        init_carry_multi,
        init_matcher,
        init_state,
    )
    from repro.sim import generate
    from repro.sim.oracle import class_select, filter_class, oracle_detect

    scale = 0.02 if quick else 0.05
    limit = 12 if quick else 25
    budget = 1_024 if quick else 2_048
    cohorts, sync_every = SHARDS, 1
    setup = dashcam(seed=0, scale=scale)
    repo, chunks = generate(setup.repo)
    q_n = len(Q_CLASSES)

    det_all = lambda key, frame: oracle_detect(repo, frame, query_class=None)
    select = class_select(repo, Q_CLASSES)

    def class_det(c):
        return lambda key, frame: filter_class(repo, det_all(key, frame), c)

    keys = [jax.random.fold_in(jax.random.PRNGKey(0), q) for q in range(q_n)]
    fresh = lambda k: init_carry(
        init_state(chunks.length), init_matcher(max_results=4096), k
    )
    fresh_multi = lambda: init_carry_multi(
        init_state(chunks.length), init_matcher(max_results=4096),
        jnp.stack(keys),
    )

    # ---- arm 1: sequential-sharded (one 8-way plan per query) ----
    seq_plan = lambda: SearchPlan(
        result_limit=limit, max_steps=budget, cohorts=cohorts,
        execution=Execution(shards=SHARDS, sync_every=sync_every),
    )
    seq_steps, seq_results, seq_wall = [], [], 0.0
    for q in range(q_n):
        t0 = time.perf_counter()
        res = seq_plan().run(
            fresh(keys[q]), chunks, detector=class_det(Q_CLASSES[q])
        )
        seq_wall += time.perf_counter() - t0
        seq_steps.append(res.steps[0])
        seq_results.append(res.results[0])

    # ---- arm 2: composed Q×shards (ONE plan) ----
    t0 = time.perf_counter()
    comp = SearchPlan(
        queries=q_n, result_limit=limit, max_steps=budget, cohorts=cohorts,
        execution=Execution(
            queries_axis=True, shards=SHARDS, sync_every=sync_every,
            cache=-1,
        ),
    ).run(fresh_multi(), chunks, detector=det_all, select=select)
    comp_wall = time.perf_counter() - t0
    assert comp.kind == "multi_sharded"

    # ---- arm 3: single-device multi (result-count cross-check) ----
    multi = SearchPlan(
        queries=q_n, result_limit=limit, max_steps=budget, cohorts=cohorts,
        method="wilson_hilferty",
        execution=Execution(queries_axis=True, cache=-1),
    ).run(fresh_multi(), chunks, detector=det_all, select=select)

    seq_inv = sum(seq_steps)          # one invocation per sampled frame
    comp_inv = comp.stats.detector_invocations
    seq_per_result = seq_inv / max(sum(seq_results), 1)
    comp_per_result = comp_inv / max(sum(comp.results), 1)
    ratio = seq_per_result / max(comp_per_result, 1e-9)

    print("arm,queries,results,frames_sampled,detector_invocations,"
          "det_per_result,wall_s")
    print(f"sequential_sharded,{q_n},{sum(seq_results)},{seq_inv},"
          f"{seq_inv},{seq_per_result:.2f},{seq_wall:.1f}")
    print(f"composed,{q_n},{sum(comp.results)},"
          f"{comp.stats.frames_sampled},{comp_inv},{comp_per_result:.2f},"
          f"{comp_wall:.1f}")
    print(f"multi_1dev,{q_n},{sum(multi.results)},"
          f"{multi.stats.frames_sampled},"
          f"{multi.stats.detector_invocations},"
          f"{multi.stats.detector_invocations / max(sum(multi.results), 1):.2f},-")
    print(f"amortization,{q_n},cache_hits={comp.stats.cache_hits},"
          f"hit_rate={comp.stats.cache_hit_rate:.2f},"
          f"merge_high_water={comp.stats.merge_high_water},"
          f"ratio={ratio:.2f}x,{'OK' if ratio >= 2.0 else 'FAIL'}")

    # composed ≡ sequential-sharded per query (oracle detector, §10 parity)
    assert list(comp.results) == seq_results, (list(comp.results), seq_results)
    assert list(comp.steps) == seq_steps, (list(comp.steps), seq_steps)
    # the headline gate: ≥2x fewer detector invocations per result
    assert ratio >= 2.0, f"amortization {ratio:.2f}x below the 2x gate"
    # per-query result counts match the single-device multi driver within
    # one sync window / 15% (different PRNG stream => statistical gate)
    window = cohorts * sync_every
    for q in range(q_n):
        c, m = comp.results[q], multi.results[q]
        assert abs(c - m) <= max(window, 0.15 * max(c, m)), (q, c, m)
    print("plan_compose_parity,OK")


def main(quick: bool = False) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={SHARDS}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    args = [sys.executable, os.path.abspath(__file__), "--child"]
    if quick:
        args.append("--quick")
    r = subprocess.run(args, env=env, capture_output=True, text=True,
                       timeout=3_600)
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        sys.stdout.write(r.stderr[-3000:])
        raise RuntimeError("bench_plan_compose child failed")


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child("--quick" in sys.argv)
    else:
        main(quick="--quick" in sys.argv)
