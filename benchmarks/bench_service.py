"""Multi-tenant search service (DESIGN.md §12): admission + slot reuse.

The serving question is different from the batch question the other
benches answer: tenants ARRIVE, the operator grants a finite priced
budget, and the pool must absorb churn without growing.  This bench
drives 8 tenants (two predicates × four users) through ONE live
:class:`~repro.serve.service.SearchService` in two waves — the second
wave admits mid-flight into slots the first wave retires — plus one
over-budget plan the admission controller must reject, and reports:

* detector amortization vs the same 8 tenants run one-after-another
  through solo device-resident scans (no sharing possible),
* batch-lane occupancy (RequestBatcher convention) and slot-pool size
  vs peak concurrency,
* the budget ledger (projected debits vs settled actuals) and
  per-tenant time-to-first-result.

Gates: zero result loss per tenant (``results == ring live + spilled``),
the pool never grows past wave-1 concurrency, and the rejected plan
never runs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

Q_CLASSES = (0, 0, 0, 0, 1, 1, 1, 1)   # two predicates × four users
WORKERS = 4
WAVE = 4                                # tenants admitted per wave


def main(quick: bool = False) -> None:
    from repro.configs.exsample_paper import dashcam
    from repro.core import (
        Execution,
        SearchPlan,
        init_carry,
        init_carry_multi,
        init_matcher,
        init_state,
    )
    from repro.core.plan import ServiceConfig
    from repro.serve.service import FINISHED, REJECTED, SearchService
    from repro.sim import generate
    from repro.sim.costmodel import CostRates
    from repro.sim.oracle import class_select, filter_class, oracle_detect

    scale = 0.02 if quick else 0.05
    limit = 10 if quick else 25
    budget_frames = 2_048 if quick else 8_192
    cohorts = 8
    setup = dashcam(seed=0, scale=scale)
    repo, chunks = generate(setup.repo)
    num_classes = int(jnp.max(repo.inst_class)) + 1
    q_n = len(Q_CLASSES)
    rates = CostRates()
    frame_s = 1.0 / rates.detect_fps + 1.0 / rates.random_read_fps

    det_all = lambda key, frame: oracle_detect(repo, frame, query_class=None)
    keys = [jax.random.fold_in(jax.random.PRNGKey(0), q) for q in range(q_n)]
    plan = SearchPlan(
        result_limit=limit, max_steps=budget_frames, cohorts=cohorts,
        execution=Execution(
            queries_axis=True,
            service=ServiceConfig(slo_latency_s=60.0),
        ),
    )

    # ---- sequential arm: Q solo scans, one after another (no sharing) ----
    seq_inv, seq_results, seq_wall = 0, 0, 0.0
    for q in range(q_n):
        carry = init_carry_multi(
            init_state(chunks.length), init_matcher(max_results=4096),
            jnp.stack([keys[q]]),
        )
        t0 = time.perf_counter()
        res = SearchPlan(
            queries=1, result_limit=limit, max_steps=budget_frames,
            cohorts=cohorts, execution=Execution(queries_axis=True),
        ).run(carry, chunks, detector=det_all,
              select=class_select(repo, [Q_CLASSES[q]]))
        seq_wall += time.perf_counter() - t0
        seq_inv += res.stats.detector_invocations
        seq_results += sum(res.results)

    # ---- service arm: one live driver, two admission waves ----
    proto = init_carry_multi(
        init_state(chunks.length), init_matcher(max_results=4096),
        jnp.stack([jax.random.PRNGKey(0)]),
    )
    service = SearchService(
        proto, chunks, det_all,
        select=class_select(repo, list(range(num_classes))),
        budget_s=q_n * budget_frames * frame_s + 1.0,
        rates=rates, cohorts=cohorts, num_workers=WORKERS,
        max_steps=budget_frames, cache_frames=chunks.total_frames,
        slots_per_batch=WAVE,
    )
    t0 = time.perf_counter()
    service.start()
    for q in range(WAVE):
        service.submit(f"t{q}", plan, key=keys[q], select_id=Q_CLASSES[q])
    # one plan that can never fit the ledger: must reject, never run
    reject = service.submit(
        "overdraft",
        SearchPlan(result_limit=limit, max_steps=50 * budget_frames * q_n,
                   cohorts=cohorts,
                   execution=Execution(queries_axis=True)),
        key=jax.random.PRNGKey(99), select_id=0,
    )
    # second wave joins mid-flight into retired slots
    while not any(
        t.state == FINISHED for t in service.tenants.values()
    ):
        time.sleep(0.01)
    for q in range(WAVE, q_n):
        service.submit(f"t{q}", plan, key=keys[q], select_id=Q_CLASSES[q])
    service.drain(deadline_s=600.0)
    service.stop()
    svc_wall = time.perf_counter() - t0

    st = service.stats()
    svc_inv = st["driver"]["detector_invocations"]
    svc_results = sum(
        int(t.row_obj.carry.results)
        for t in service.tenants.values() if t.state == FINISHED
    )
    ttfr = [
        t.slo_report()["ttfr_s"]
        for t in service.tenants.values()
        if t.state == FINISHED and t.slo_report()["ttfr_s"] is not None
    ]
    seq_per = seq_inv / max(seq_results, 1)
    svc_per = svc_inv / max(svc_results, 1)

    print("arm,tenants,workers,results,detector_invocations,det_per_result,"
          "wall_s")
    print(f"sequential_solo,{q_n},{WORKERS},{seq_results},{seq_inv},"
          f"{seq_per:.2f},{seq_wall:.2f}")
    print(f"service,{q_n},{WORKERS},{svc_results},{svc_inv},"
          f"{svc_per:.2f},{svc_wall:.2f}")
    ttfr_max = f"{max(ttfr):.2f}" if ttfr else "n/a"
    print(f"service,occupancy={st['batch']['occupancy']:.2f},"
          f"pool_rows={len(service.driver.rows)},"
          f"spent_s={st['budget']['spent_s']:.0f},"
          f"committed_s={st['budget']['committed_s']:.0f},"
          f"ttfr_max_s={ttfr_max}")

    # gates
    assert reject.state == REJECTED
    assert abs(st["budget"]["committed_s"]) < 1e-6
    assert len(service.driver.rows) <= WAVE, "pool grew past concurrency"
    for t in service.tenants.values():
        if t.state != FINISHED:
            continue
        row = t.row_obj
        live = int((np.asarray(row.carry.matcher.times_seen) > 0).sum())
        assert int(row.carry.results) == live + len(row.log), (
            f"{t.tenant_id}: results lost")
    print(f"gates,reject={reject.state},zero_loss=OK,"
          f"slot_reuse={'OK' if len(service.driver.rows) <= WAVE else 'FAIL'}")


if __name__ == "__main__":
    import sys

    main(quick="--quick" in sys.argv)
