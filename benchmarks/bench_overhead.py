"""Paper Fig. 6 + the Figs. 3-4 time axis: phase-overhead breakdown.

Prices ExSample vs the surrogate (BlazeIt-style) plan under the paper's
measured throughputs (detector 10 fps, scan 100 fps, random-read 50 fps)
and under roofline-derived rates for the assigned backbones.  Shows the
paper's headline: the surrogate's fixed labelling+scoring cost dwarfs its
sampling savings for ad-hoc queries.

Also measures OUR framework overhead (DESIGN.md §7): steps/sec of the
host per-step reference driver vs the device-resident scanned driver at
repository scale — the per-frame decision loop must be ~free next to
detector cost for the paper's savings to survive systems overhead.
"""
from __future__ import annotations

import time

import jax

from repro.sim.costmodel import (
    CostRates,
    full_scan_cost,
    sampling_cost,
    surrogate_cost,
)


def bench_driver_dispatch(m_chunks: int = 10_000, chunk_frames: int = 64):
    """Host loop vs scanned driver at M chunks, oracle detector.

    Reports the full driver × Thompson-method matrix so the two
    overheads the scanned driver removes stay separable:

      * per-step dispatch + host sync — host_loop rows vs scanned rows
        for the SAME method;
      * the exact-Gamma rejection sampler (``jax.random.gamma`` costs
        ~100 ms/step at M=10k on CPU) — "exact" rows vs the
        Wilson–Hilferty / fused-pallas rows it is replaced by on the
        device-resident path (DESIGN.md §3, §7).

    The headline ``scanned_vs_host`` ratio compares the seed
    configuration (host loop, exact Gamma — what ``run_search``
    defaulted to) against the production configuration (scanned driver,
    pallas choice path).  Returns that ratio.
    """
    from repro.core import (
        init_carry,
        init_matcher,
        init_state,
        run_search,
        run_search_scan,
    )
    from repro.sim import RepoSpec, generate
    from repro.sim.oracle import oracle_detect

    videos = 10
    spec = RepoSpec(
        video_lengths=[m_chunks * chunk_frames // videos] * videos,
        num_instances=64,
        chunk_frames=chunk_frames,
        seed=0,
    )
    repo, chunks = generate(spec)
    assert chunks.num_chunks == m_chunks, chunks.num_chunks
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    fresh = lambda: init_carry(
        init_state(chunks.length), init_matcher(max_results=512),
        jax.random.PRNGKey(0),
    )
    never = 10**9  # unreachable result limit: measure steady-state rate

    def timed(driver, method, steps):
        # max_steps is a static argument of the scanned driver, so the
        # warm-up must use the SAME steps or it compiles a throwaway
        # executable; the timed call then reuses the warm one.
        kw = dict(detector=det, result_limit=never, method=method)
        driver(fresh(), chunks, max_steps=steps, **kw)  # compile + warm
        t0 = time.perf_counter()
        out, _ = driver(fresh(), chunks, max_steps=steps, **kw)
        jax.block_until_ready(out.results)
        return int(out.step) / (time.perf_counter() - t0)

    print(f"\ndriver dispatch overhead (M={m_chunks:,} chunks, oracle detector)")
    print("driver,method,steps_per_sec")
    rates = {}
    grid = [
        ("host_loop", run_search, "exact", 50),
        ("host_loop", run_search, "wilson_hilferty", 300),
        ("scanned", run_search_scan, "exact", 50),
        ("scanned", run_search_scan, "wilson_hilferty", 3_000),
        ("scanned", run_search_scan, "pallas", 3_000),
    ]
    for name, driver, method, steps in grid:
        rates[(name, method)] = timed(driver, method, steps)
        print(f"{name},{method},{rates[(name, method)]:.0f}")

    like_for_like = (
        rates[("scanned", "wilson_hilferty")] / rates[("host_loop", "wilson_hilferty")]
    )
    headline = rates[("scanned", "pallas")] / rates[("host_loop", "exact")]
    print(f"scanned_vs_host_same_method,{like_for_like:.1f}x")
    print(f"scanned_vs_host,{headline:.1f}x  # seed default vs production path")
    return headline


def main():
    total_frames = 1_080_000            # 10 h @ 30 fps (paper's dashcam)
    print("plan,frames_processed,label_s,train_s,score_s,sample_s,total_s,vs_exsample")
    rates = CostRates()                  # paper-reported throughputs
    scenarios = [
        ("exsample@0.1recall", 2_500, sampling_cost(2_500, rates)),
        ("random+@0.1recall", 6_000, sampling_cost(6_000, rates)),
        ("surrogate@0.1recall", 1_200,
         surrogate_cost(1_200, total_frames, rates=rates)),
        ("exsample@0.9recall", 90_000, sampling_cost(90_000, rates)),
        ("random+@0.9recall", 190_000, sampling_cost(190_000, rates)),
        ("surrogate@0.9recall", 80_000,
         surrogate_cost(80_000, total_frames, rates=rates)),
        ("full_scan", total_frames, full_scan_cost(total_frames, rates)),
    ]
    base = {0.1: scenarios[0][2].total_s, 0.9: scenarios[3][2].total_s}
    for name, frames, c in scenarios:
        ref = base[0.1] if "0.1" in name else base.get(0.9, base[0.1])
        print(
            f"{name},{frames},{c.label_s:.0f},{c.train_s:.0f},{c.score_s:.0f},"
            f"{c.sample_s:.0f},{c.total_s:.0f},{c.total_s / ref:.2f}x"
        )
    # phase throughput table (Fig. 6)
    print("\nphase,throughput_fps,bound")
    print(f"labelling,{1/(1/rates.detect_fps + 1/rates.scan_fps):.1f},detector")
    print(f"training,{rates.train_examples_per_s:.0f},memory-resident")
    print(f"scoring,{min(rates.scan_fps, rates.surrogate_fps):.1f},io+decode")
    print(f"sampling,{1/(1/rates.detect_fps + 1/rates.random_read_fps):.1f},detector")

    # roofline-derived detector rates for three assigned backbones
    print("\nbackbone,detect_fps@40%MFU,sample_phase_s_for_10k_frames")
    from repro.configs import ARCHS
    from repro.launch.specs import active_params

    for arch in ("qwen2.5-32b", "dbrx-132b", "granite-moe-1b-a400m"):
        cfg = ARCHS[arch]
        flops_per_frame = 2.0 * active_params(cfg) * 1024   # 1024-token frame ctx
        r = CostRates.from_backbone(flops_per_frame)
        c = sampling_cost(10_000, r)
        print(f"{arch},{r.detect_fps:.1f},{c.total_s:.0f}")

    bench_driver_dispatch()


if __name__ == "__main__":
    main()
