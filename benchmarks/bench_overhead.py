"""Paper Fig. 6 + the Figs. 3-4 time axis: phase-overhead breakdown.

Prices ExSample vs the surrogate (BlazeIt-style) plan under the paper's
measured throughputs (detector 10 fps, scan 100 fps, random-read 50 fps)
and under roofline-derived rates for the assigned backbones.  Shows the
paper's headline: the surrogate's fixed labelling+scoring cost dwarfs its
sampling savings for ad-hoc queries.
"""
from __future__ import annotations

from repro.sim.costmodel import (
    CostRates,
    full_scan_cost,
    sampling_cost,
    surrogate_cost,
)


def main():
    total_frames = 1_080_000            # 10 h @ 30 fps (paper's dashcam)
    print("plan,frames_processed,label_s,train_s,score_s,sample_s,total_s,vs_exsample")
    rates = CostRates()                  # paper-reported throughputs
    scenarios = [
        ("exsample@0.1recall", 2_500, sampling_cost(2_500, rates)),
        ("random+@0.1recall", 6_000, sampling_cost(6_000, rates)),
        ("surrogate@0.1recall", 1_200,
         surrogate_cost(1_200, total_frames, rates=rates)),
        ("exsample@0.9recall", 90_000, sampling_cost(90_000, rates)),
        ("random+@0.9recall", 190_000, sampling_cost(190_000, rates)),
        ("surrogate@0.9recall", 80_000,
         surrogate_cost(80_000, total_frames, rates=rates)),
        ("full_scan", total_frames, full_scan_cost(total_frames, rates)),
    ]
    base = {0.1: scenarios[0][2].total_s, 0.9: scenarios[3][2].total_s}
    for name, frames, c in scenarios:
        ref = base[0.1] if "0.1" in name else base.get(0.9, base[0.1])
        print(
            f"{name},{frames},{c.label_s:.0f},{c.train_s:.0f},{c.score_s:.0f},"
            f"{c.sample_s:.0f},{c.total_s:.0f},{c.total_s / ref:.2f}x"
        )
    # phase throughput table (Fig. 6)
    print("\nphase,throughput_fps,bound")
    print(f"labelling,{1/(1/rates.detect_fps + 1/rates.scan_fps):.1f},detector")
    print(f"training,{rates.train_examples_per_s:.0f},memory-resident")
    print(f"scoring,{min(rates.scan_fps, rates.surrogate_fps):.1f},io+decode")
    print(f"sampling,{1/(1/rates.detect_fps + 1/rates.random_read_fps):.1f},detector")

    # roofline-derived detector rates for three assigned backbones
    print("\nbackbone,detect_fps@40%MFU,sample_phase_s_for_10k_frames")
    from repro.configs import ARCHS
    from repro.launch.specs import active_params

    for arch in ("qwen2.5-32b", "dbrx-132b", "granite-moe-1b-a400m"):
        cfg = ARCHS[arch]
        flops_per_frame = 2.0 * active_params(cfg) * 1024   # 1024-token frame ctx
        r = CostRates.from_backbone(flops_per_frame)
        c = sampling_cost(10_000, r)
        print(f"{arch},{r.detect_fps:.1f},{c.total_s:.0f}")


if __name__ == "__main__":
    main()
