"""Sharded driver throughput + parity (paper §3.7.1 distributed, DESIGN.md §8).

Measures steps/sec of ``run_search_sharded`` at 1/2/4/8 simulated host
devices against the single-device ``run_search_scan`` baseline, and checks
the acceptance parity: at 8 shards the sharded driver must find the same
result count (±5%) as the scanned driver for the same query and frame
budget on the dashcam config.

Each device count needs its own ``--xla_force_host_platform_device_count``
flag, which must be set before the first jax import — so the parent
re-execs this file once per arm and relays each arm's CSV rows when that
arm finishes (child output is captured, not streamed live).  On a
CPU host the simulated shards CONTEND for the same cores, so steps/sec
here isolates framework/collective overhead, not speedup; the speedup
story needs real devices where detector compute dominates and shards run
concurrently (the async model of bench_batched prices that).
"""
from __future__ import annotations

import os
import subprocess
import sys

DEVICE_COUNTS = (1, 2, 4, 8)


def _child(shards: int, steps: int, parity: bool) -> None:
    import time

    import jax

    from repro.core import (
        Execution,
        SearchPlan,
        init_carry,
        init_matcher,
        init_state,
    )
    from repro.launch.mesh import make_data_mesh
    from repro.sim import RepoSpec, generate
    from repro.sim.oracle import oracle_detect

    cohorts, sync_every = 8, 1
    videos, chunk_frames, m_chunks = 10, 64, 1_000
    spec = RepoSpec(
        video_lengths=[m_chunks * chunk_frames // videos] * videos,
        num_instances=64,
        chunk_frames=chunk_frames,
        seed=0,
    )
    repo, chunks = generate(spec)
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    fresh = lambda: init_carry(
        init_state(chunks.length), init_matcher(max_results=512),
        jax.random.PRNGKey(0),
    )
    never = 10**9  # unreachable result limit: measure steady-state rate
    mesh = make_data_mesh(shards)
    scan_plan = SearchPlan(
        result_limit=never, max_steps=steps, cohorts=cohorts,
        method="wilson_hilferty",
    )
    sharded_plan = SearchPlan(
        result_limit=never, max_steps=steps, cohorts=cohorts,
        execution=Execution(shards=shards, sync_every=sync_every)
        if shards > 1 else Execution(strategy="sharded",
                                     sync_every=sync_every),
    )

    def timed(run):
        run()  # compile + warm (max_steps is static, reuse the executable)
        t0 = time.perf_counter()
        res = run()
        return res.steps[0] / (time.perf_counter() - t0)

    if shards == 1:
        rate = timed(lambda: scan_plan.run(fresh(), chunks, detector=det))
        print(f"scanned,1,{cohorts},-,{rate:.0f}", flush=True)
    rate = timed(lambda: sharded_plan.run(
        fresh(), chunks, detector=det, mesh=mesh))
    print(f"sharded,{shards},{cohorts},{sync_every},{rate:.0f}", flush=True)

    if parity and shards == max(DEVICE_COUNTS):
        from repro.configs.exsample_paper import dashcam

        setup = dashcam(seed=0, scale=0.05)
        repo, chunks = generate(setup.repo)
        det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
        fresh = lambda: init_carry(
            init_state(chunks.length), init_matcher(max_results=8192),
            jax.random.PRNGKey(0),
        )
        budget = 2_048
        scan = SearchPlan(
            result_limit=never, max_steps=budget, cohorts=cohorts,
            method="wilson_hilferty",
        ).run(fresh(), chunks, detector=det)
        sh = SearchPlan(
            result_limit=never, max_steps=budget, cohorts=cohorts,
            execution=Execution(shards=shards, sync_every=sync_every),
        ).run(fresh(), chunks, detector=det, mesh=mesh)
        ratio = sh.results[0] / max(scan.results[0], 1)
        ok = "OK" if abs(ratio - 1.0) <= 0.05 else "FAIL"
        print(
            f"parity_dashcam,{shards},scan={scan.results[0]},"
            f"sharded={sh.results[0]},ratio={ratio:.3f},{ok}",
            flush=True,
        )
        assert ok == "OK", f"8-way parity off by {ratio:.3f}x"


def main(quick: bool = False) -> None:
    steps = 256 if quick else 1_024
    print("driver,shards,global_cohorts,sync_every,steps_per_sec")
    for n in DEVICE_COUNTS:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env.setdefault("JAX_PLATFORMS", "cpu")
        args = [sys.executable, os.path.abspath(__file__),
                "--child", str(n), "--steps", str(steps)]
        if not quick:
            args.append("--parity")
        r = subprocess.run(args, env=env, capture_output=True, text=True,
                           timeout=1_800)
        sys.stdout.write(r.stdout)
        if r.returncode != 0:
            sys.stdout.write(r.stderr[-2000:])
            raise RuntimeError(f"bench_sharded child (shards={n}) failed")


if __name__ == "__main__":
    if "--child" in sys.argv:
        i = sys.argv.index("--child")
        _child(
            int(sys.argv[i + 1]),
            int(sys.argv[sys.argv.index("--steps") + 1]),
            "--parity" in sys.argv,
        )
    else:
        main(quick="--quick" in sys.argv)
