"""Benchmark harness entry: one section per paper table/figure.

  bench_bias       -- paper 3.3.2 / Fig. 2 (estimator + Poisson validation)
  bench_savings    -- paper Figs. 3-4 (frames-processed savings vs random+)
  bench_batched    -- paper 3.7.1 (cohort batching) + straggler model
  bench_sharded    -- sharded driver steps/sec at 1/2/4/8 shards + parity
  bench_multiquery -- Q=8 shared detector pass vs sequential (DESIGN.md §9)
  bench_overhead   -- paper Fig. 6 (phase breakdown; surrogate fixed costs)
  bench_kernels    -- kernel reference microbenchmarks (CSV)
  bench_roofline   -- Roofline table from dry-run artifacts
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    quick = "--quick" in sys.argv
    from benchmarks import (
        bench_batched,
        bench_bias,
        bench_chunking,
        bench_kernels,
        bench_multiquery,
        bench_overhead,
        bench_roofline,
        bench_savings,
        bench_sharded,
    )

    sections = [
        ("bias_validation(fig2)", lambda: bench_bias.main()),
        ("savings(fig3-4)", lambda: bench_savings.main(quick=quick)),
        ("chunking(sec3.5)", bench_chunking.main),
        ("batched(sec3.7.1)", bench_batched.main),
        ("sharded(sec3.7.1)", lambda: bench_sharded.main(quick=quick)),
        ("multiquery(sec9)", lambda: bench_multiquery.main(quick=quick)),
        ("overhead(fig6)", bench_overhead.main),
        ("kernels", bench_kernels.main),
        ("roofline", bench_roofline.main),
    ]
    for name, fn in sections:
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        fn()
        print(f"[{name} done in {time.time() - t0:.1f}s]", flush=True)


if __name__ == "__main__":
    main()
