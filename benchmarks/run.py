"""Benchmark harness entry: one section per paper table/figure.

  bench_bias         -- paper 3.3.2 / Fig. 2 (estimator + Poisson validation)
  bench_savings      -- paper Figs. 3-4 (frames-processed savings vs random+)
  bench_batched      -- paper 3.7.1 (cohort batching) + straggler model
  bench_sharded      -- sharded driver steps/sec at 1/2/4/8 shards + parity
  bench_multiquery   -- Q=8 shared detector pass vs sequential (DESIGN.md §9)
  bench_async_compose -- Q=8 × 4 async workers elastic slot pool vs
                        sequential single-query async (DESIGN.md §11)
  bench_plan_compose -- Q=8 × 8-shard composed lowering vs sequential-sharded
                        and single-device multi (DESIGN.md §10)
  bench_service      -- multi-tenant service: 2 admission waves × 4 tenants
                        on one live driver, budget ledger + slot reuse
                        (DESIGN.md §12)
  bench_index_reuse  -- persistent repository index: identical query cold
                        vs warm + second tenant over a warm service, ≥5×
                        fewer detector invocations (DESIGN.md §13)
  bench_overhead     -- paper Fig. 6 (phase breakdown; surrogate fixed costs)
  bench_kernels      -- kernel reference microbenchmarks (CSV)
  bench_roofline     -- Roofline table from dry-run artifacts

Each section *declares* the ``Execution`` capabilities it exercises
(DESIGN.md §10); sections that need an in-process mesh the host cannot
provide are SKIPPED with a logged reason — never silently — while
subprocess-based sections (``forces_devices``) re-exec children with
forced host devices and run anywhere.
"""
from __future__ import annotations

import dataclasses
import sys
import time
from typing import Callable, Optional

from repro.core.plan import Execution


@dataclasses.dataclass(frozen=True)
class BenchSpec:
    """A registered benchmark section and its execution requirements."""

    name: str
    run: Callable[[bool], None]        # run(quick)
    execution: Optional[Execution] = None  # capabilities it exercises
    forces_devices: bool = False       # spawns children with forced devices


def should_skip(spec: BenchSpec, available_devices: int) -> str | None:
    """Reason this section cannot run on this host, or None to run it.

    A section declaring a mesh (``execution.shards > 1``) needs that many
    in-process devices unless it forces its own (subprocess re-exec with
    ``--xla_force_host_platform_device_count``).
    """
    if spec.execution is None or spec.forces_devices:
        return None
    if spec.execution.shards > available_devices:
        return (
            f"needs a {spec.execution.shards}-way "
            f"'{spec.execution.axis}' mesh but the host exposes "
            f"{available_devices} device(s); set "
            "--xla_force_host_platform_device_count or run on more devices"
        )
    if spec.execution.async_workers > 0 and not _threads_available():
        return (
            f"needs {spec.execution.async_workers} async worker thread(s) "
            "but this host cannot start threads"
        )
    return None


def _threads_available() -> bool:
    """Probe that worker threads can actually start on this host (some
    sandboxed/restricted runtimes refuse thread creation)."""
    import threading

    try:
        t = threading.Thread(target=lambda: None, daemon=True)
        t.start()
        t.join(timeout=5.0)
        return not t.is_alive()
    except RuntimeError:
        return False


def _sections() -> list[BenchSpec]:
    from benchmarks import (
        bench_async_compose,
        bench_batched,
        bench_bias,
        bench_chunking,
        bench_index_reuse,
        bench_kernels,
        bench_multiquery,
        bench_overhead,
        bench_plan_compose,
        bench_roofline,
        bench_savings,
        bench_service,
        bench_sharded,
    )

    return [
        BenchSpec("bias_validation(fig2)", lambda quick: bench_bias.main()),
        BenchSpec("savings(fig3-4)",
                  lambda quick: bench_savings.main(quick=quick)),
        BenchSpec("chunking(sec3.5)", lambda quick: bench_chunking.main()),
        BenchSpec("batched(sec3.7.1)", lambda quick: bench_batched.main()),
        BenchSpec("sharded(sec3.7.1)",
                  lambda quick: bench_sharded.main(quick=quick),
                  execution=Execution(shards=8), forces_devices=True),
        BenchSpec("multiquery(sec9)",
                  lambda quick: bench_multiquery.main(quick=quick),
                  execution=Execution(queries_axis=True, cache=-1)),
        BenchSpec("async_compose(sec11)",
                  lambda quick: bench_async_compose.main(quick=quick),
                  execution=Execution(queries_axis=True, async_workers=4,
                                      cache=-1)),
        BenchSpec("plan_compose(sec10)",
                  lambda quick: bench_plan_compose.main(quick=quick),
                  execution=Execution(queries_axis=True, shards=8, cache=-1),
                  forces_devices=True),
        BenchSpec("service(sec12)",
                  lambda quick: bench_service.main(quick=quick),
                  execution=Execution(queries_axis=True, async_workers=4,
                                      cache=-1)),
        BenchSpec("index_reuse(sec13)",
                  lambda quick: bench_index_reuse.main(quick=quick),
                  execution=Execution(queries_axis=True, async_workers=2,
                                      cache=-1)),
        BenchSpec("overhead(fig6)", lambda quick: bench_overhead.main()),
        BenchSpec("kernels", lambda quick: bench_kernels.main()),
        BenchSpec("roofline", lambda quick: bench_roofline.main()),
    ]


SECTIONS = _sections()


def main() -> None:
    import jax

    quick = "--quick" in sys.argv
    available = len(jax.devices())
    for spec in SECTIONS:
        reason = should_skip(spec, available)
        if reason is not None:
            print(f"\n===== {spec.name} ===== SKIPPED: {reason}", flush=True)
            continue
        print(f"\n===== {spec.name} =====", flush=True)
        t0 = time.time()
        spec.run(quick)
        print(f"[{spec.name} done in {time.time() - t0:.1f}s]", flush=True)


if __name__ == "__main__":
    main()
