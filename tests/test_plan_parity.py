"""SearchPlan lowering ≡ legacy drivers, and the composed Q×shards lowering
(DESIGN.md §10).

Two contracts:

* **Home-config parity** — a plan lowered to each legacy driver's home
  configuration (scan Q=1, host, multi Q=4, sharded) reproduces the legacy
  entry point bit-identically: (step, results), trace, sampler statistics,
  final PRNG key.  The legacy ``run_search_*`` functions are deprecated
  shims over the SAME lowering, so this also pins the shims.
* **Composed lowering parity** — ``run_search_multi_sharded`` (plans with
  queries_axis + shards) is bit-identical PER QUERY to that query's own
  solo ``run_search_sharded`` run on the same mesh with the same key, at
  any Q, with a deterministic detector: cross-query dedup and the
  per-shard detection cache change WHICH detector invocations happen,
  never the values a query consumes.  The in-process tests run the whole
  composed shard_map machinery on a 1-way mesh every tier-1 run; the slow
  subprocess test forces 8 host devices.
"""
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Execution,
    SearchPlan,
    init_carry,
    init_carry_multi,
    init_matcher,
    init_state,
    run_search,
    run_search_multi,
    run_search_multi_sharded,
    run_search_scan,
    run_search_sharded,
)
from repro.launch.mesh import make_data_mesh
from repro.sim import RepoSpec, generate
from repro.sim.oracle import oracle_detect


@pytest.fixture(scope="module")
def world():
    spec = RepoSpec(
        video_lengths=[5_000] * 3, num_instances=100, chunk_frames=500,
        locality=4.0, seed=7,
    )
    repo, chunks = generate(spec)
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    return repo, chunks, det


def _fresh(chunks, key):
    return init_carry(
        init_state(chunks.length), init_matcher(max_results=512), key
    )


def _fresh_multi(chunks, keys):
    return init_carry_multi(
        init_state(chunks.length), init_matcher(max_results=512), keys
    )


def _qkey(q):
    return jax.random.fold_in(jax.random.PRNGKey(0), q)


def _same_carry(a, b, qa=None, qb=None):
    pick = lambda x, q: x if q is None else jax.tree.map(lambda l: l[q], x)
    a, b = pick(a, qa), pick(b, qb)
    assert (int(a.step), int(a.results)) == (int(b.step), int(b.results))
    np.testing.assert_array_equal(np.asarray(a.sampler.n),
                                  np.asarray(b.sampler.n))
    np.testing.assert_array_equal(np.asarray(a.sampler.n1),
                                  np.asarray(b.sampler.n1))
    np.testing.assert_array_equal(np.asarray(a.key), np.asarray(b.key))


# ---------------------------------------------------------------------------
# Home-config parity: plan lowering ≡ legacy entry point, bit for bit
# ---------------------------------------------------------------------------


def test_plan_scan_parity_and_shim_deprecation(world):
    _, chunks, det = world
    with pytest.warns(DeprecationWarning, match="run_search_scan"):
        legacy, legacy_trace = run_search_scan(
            _fresh(chunks, jax.random.PRNGKey(0)), chunks, detector=det,
            result_limit=15, max_steps=900, cohorts=4, trace_every=50,
        )
    res = SearchPlan(
        result_limit=15, max_steps=900, cohorts=4, trace_every=50,
    ).run(_fresh(chunks, jax.random.PRNGKey(0)), chunks, detector=det)
    assert res.kind == "scan"
    _same_carry(legacy, res.carry)
    assert legacy_trace == res.trace
    assert res.stats.detector_invocations == res.steps[0]
    assert res.stats.matcher_capacity == 512


def test_plan_host_parity(world):
    _, chunks, det = world
    with pytest.warns(DeprecationWarning, match="run_search"):
        legacy, legacy_trace = run_search(
            _fresh(chunks, jax.random.PRNGKey(0)), chunks, detector=det,
            result_limit=8, max_steps=200, trace_every=25,
        )
    res = SearchPlan(
        result_limit=8, max_steps=200, trace_every=25,
        execution=Execution(strategy="host"),
    ).run(_fresh(chunks, jax.random.PRNGKey(0)), chunks, detector=det)
    assert res.kind == "host"
    _same_carry(legacy, res.carry)
    assert legacy_trace == res.trace


def test_plan_multi_parity(world):
    _, chunks, det = world
    q_n, limits = 4, (12, 12, 6, 12)
    keys = jnp.stack([_qkey(q) for q in range(q_n)])
    with pytest.warns(DeprecationWarning, match="run_search_multi"):
        legacy, ltraces, lstats = run_search_multi(
            _fresh_multi(chunks, keys), chunks, detector=det,
            result_limits=jnp.asarray(limits, jnp.int32), max_steps=600,
            cohorts=4, trace_every=25, cache_frames=chunks.total_frames,
        )
    res = SearchPlan(
        queries=q_n, result_limit=limits, max_steps=600, cohorts=4,
        trace_every=25, execution=Execution(queries_axis=True, cache=-1),
    ).run(_fresh_multi(chunks, keys), chunks, detector=det)
    assert res.kind == "multi"
    for q in range(q_n):
        _same_carry(legacy, res.carry, qa=q, qb=q)
        assert ltraces[q] == res.traces[q]
    assert lstats["detector_invocations"] == res.stats.detector_invocations
    assert lstats["cache_hits"] == res.stats.cache_hits
    assert res.stats.frames_sampled == sum(res.steps)
    assert 0.0 <= res.stats.cache_hit_rate <= 1.0


def test_plan_sharded_parity_1way(world):
    _, chunks, det = world
    mesh = make_data_mesh(1)
    with pytest.warns(DeprecationWarning, match="run_search_sharded"):
        legacy, legacy_trace = run_search_sharded(
            _fresh(chunks, jax.random.PRNGKey(0)), chunks, mesh=mesh,
            detector=det, result_limit=10, max_steps=400, cohorts=2,
            sync_every=2,
        )
    res = SearchPlan(
        result_limit=10, max_steps=400, cohorts=2,
        execution=Execution(strategy="sharded", sync_every=2),
    ).run(
        _fresh(chunks, jax.random.PRNGKey(0)), chunks, detector=det,
        mesh=mesh,
    )
    assert res.kind == "sharded"
    _same_carry(legacy, res.carry)
    assert legacy_trace == res.trace
    # merge ring pressure surfaced uniformly (was async-driver-only);
    # every executed sync window appended one trace entry here (no cap hit)
    assert res.stats.merges == len(res.trace)
    assert res.stats.merge_high_water >= 1  # results were found and merged
    assert not res.stats.merge_overflow


@pytest.mark.skipif(
    len(jax.devices()) < 2, reason="needs >=2 host devices (CI 8-dev legs)"
)
def test_plan_sharded_parity_2way_in_process(world):
    _, chunks, det = world
    mesh = make_data_mesh(2)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy, legacy_trace = run_search_sharded(
            _fresh(chunks, jax.random.PRNGKey(0)), chunks, mesh=mesh,
            detector=det, result_limit=12, max_steps=400, cohorts=4,
            sync_every=1,
        )
    res = SearchPlan(
        result_limit=12, max_steps=400, cohorts=4,
        execution=Execution(shards=2),
    ).run(
        _fresh(chunks, jax.random.PRNGKey(0)), chunks, detector=det,
        mesh=mesh,
    )
    assert res.kind == "sharded"
    _same_carry(legacy, res.carry)
    assert legacy_trace == res.trace


# ---------------------------------------------------------------------------
# Composed lowering: per-query bit-parity with the solo sharded driver
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cache", [0, -1])
def test_composed_each_query_matches_solo_sharded_1way(world, cache):
    _, chunks, det = world
    mesh = make_data_mesh(1)
    q_n, cohorts, sync_every = 3, 2, 2
    limits = [10, 5, 10]   # query 1 finishes early and must freeze
    keys = jnp.stack([_qkey(q) for q in range(q_n)])
    res = SearchPlan(
        queries=q_n, result_limit=tuple(limits), max_steps=400,
        cohorts=cohorts,
        execution=Execution(
            strategy="sharded", sync_every=sync_every,
            cache=cache if cache else None,
        ),
    ).run(_fresh_multi(chunks, keys), chunks, detector=det, mesh=mesh)
    assert res.kind == "multi_sharded"
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for q in range(q_n):
            solo, solo_trace = run_search_sharded(
                _fresh(chunks, keys[q]), chunks, mesh=mesh, detector=det,
                result_limit=limits[q], max_steps=400, cohorts=cohorts,
                sync_every=sync_every,
            )
            _same_carry(solo, res.carry, qb=q)
            assert solo_trace == res.traces[q], f"query {q} trace diverged"
    # sharing can only save detector work, never add any
    assert res.stats.detector_invocations <= res.stats.frames_sampled
    assert res.stats.frames_sampled == sum(res.steps)


def test_composed_identical_queries_dedup_exactly(world):
    """Q identical queries sample identical frames every round; the
    per-shard dedup collapses them to ONE invocation each even with the
    cache off: invocations · Q == frames sampled."""
    _, chunks, det = world
    q_n = 4
    keys = jnp.stack([jax.random.PRNGKey(3)] * q_n)
    out, _, stats = run_search_multi_sharded(
        _fresh_multi(chunks, keys), chunks, mesh=make_data_mesh(1),
        detector=det, result_limits=10, max_steps=200, cohorts=2,
    )
    steps = np.asarray(out.step)
    assert (steps == steps[0]).all()
    assert stats["detector_invocations"] * q_n == stats["frames_sampled"]


def test_composed_rejects_bad_geometry(world):
    _, chunks, det = world
    keys = jnp.stack([_qkey(q) for q in range(2)])
    carries = _fresh_multi(chunks, keys)
    with pytest.raises(ValueError, match="cohorts"):
        run_search_multi_sharded(
            carries, chunks, mesh=make_data_mesh(1), detector=det,
            result_limits=5, max_steps=16, cohorts=0,
        )
    with pytest.raises(ValueError, match="sync_every"):
        run_search_multi_sharded(
            carries, chunks, mesh=make_data_mesh(1), detector=det,
            result_limits=5, max_steps=16, cohorts=1, sync_every=0,
        )


def test_plan_async_lowering(world):
    """async_workers>0 lowers to the threaded AsyncSearchDriver and its
    scheduler counters surface through the SAME SearchStats container."""
    _, chunks, det = world
    res = SearchPlan(
        result_limit=12, max_steps=2_000, cohorts=4,
        execution=Execution(async_workers=2),
    ).run(_fresh(chunks, jax.random.PRNGKey(0)), chunks, detector=det)
    assert res.kind == "async"
    assert res.results[0] >= 12
    assert res.stats.merges >= 1
    assert res.stats.merge_high_water >= 1
    assert res.stats.frames_sampled == res.steps[0]
    assert res.trace == [(res.steps[0], res.results[0])]


COMPOSED_8DEV_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import warnings
    warnings.simplefilter("ignore", DeprecationWarning)
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import (Execution, SearchPlan, init_carry,
                            init_carry_multi, init_matcher, init_state,
                            run_search_sharded)
    from repro.launch.mesh import make_data_mesh
    from repro.sim import RepoSpec, generate
    from repro.sim.oracle import oracle_detect

    spec = RepoSpec(video_lengths=[8_000] * 4, num_instances=150,
                    chunk_frames=800, locality=4.0, seed=5)
    repo, chunks = generate(spec)
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    fresh = lambda k: init_carry(init_state(chunks.length),
                                 init_matcher(max_results=2048), k)
    fresh_multi = lambda ks: init_carry_multi(
        init_state(chunks.length), init_matcher(max_results=2048), ks)
    q_n, cohorts, sync_every, budget = 4, 8, 2, 768
    limits = (25, 25, 10, 25)
    keys = jnp.stack([jax.random.fold_in(jax.random.PRNGKey(0), q)
                      for q in range(q_n)])
    mesh = make_data_mesh(8)
    res = SearchPlan(
        queries=q_n, result_limit=limits, max_steps=budget,
        cohorts=cohorts,
        execution=Execution(shards=8, sync_every=sync_every, cache=-1),
    ).run(fresh_multi(keys), chunks, detector=det, mesh=mesh)
    assert res.kind == "multi_sharded"
    for q in range(q_n):
        solo, solo_trace = run_search_sharded(
            fresh(keys[q]), chunks, mesh=mesh, detector=det,
            result_limit=limits[q], max_steps=budget, cohorts=cohorts,
            sync_every=sync_every)
        assert (int(solo.step), int(solo.results)) == (
            res.steps[q], res.results[q]), (q, int(solo.step), res.steps[q])
        assert solo_trace == res.traces[q], q
        np.testing.assert_array_equal(
            np.asarray(solo.sampler.n), np.asarray(res.carry.sampler.n[q]))
        np.testing.assert_array_equal(
            np.asarray(solo.sampler.n1), np.asarray(res.carry.sampler.n1[q]))
        np.testing.assert_array_equal(
            np.asarray(solo.key), np.asarray(res.carry.key[q]))
        print(f"composed q={q}: bit-identical to solo sharded "
              f"({res.steps[q]} steps, {res.results[q]} results)")
    assert res.stats.detector_invocations < res.stats.frames_sampled
    print("invocations", res.stats.detector_invocations,
          "of", res.stats.frames_sampled, "frames sampled")
    print("ALL_OK")
    """
)


@pytest.mark.slow
def test_composed_parity_multidevice():
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", COMPOSED_8DEV_SCRIPT],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert "ALL_OK" in r.stdout, r.stdout[-3000:] + "\n" + r.stderr[-3000:]
