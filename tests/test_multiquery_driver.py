"""Multi-query batched driver ≡ per-query scanned driver (DESIGN.md §9).

The acceptance bar: at Q=1 ``run_search_multi`` is bit-identical in
(step, results, trace, sampler statistics, key) to ``run_search_scan``;
at Q>1 with disjoint per-query keys every query's trajectory equals its
own sequential run at the same frame budget — cross-query dedup and the
detection cache change WHICH detector invocations happen, never the
values a query consumes.  Property tests pin the dedup/scatter-back
invariants: no sampled frame is ever dropped, no detection is ever
counted into two queries' sampler deltas.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    init_carry,
    init_carry_multi,
    init_matcher,
    init_state,
    run_search_multi,
    run_search_scan,
    stack_carries,
)
from repro.core.thompson import choose_chunks, choose_chunks_batched
from repro.serve.batcher import (
    cache_insert,
    cache_lookup,
    dedup_first_index,
    init_detection_cache,
)
from repro.sim import RepoSpec, generate
from repro.sim.oracle import oracle_detect


@pytest.fixture(scope="module")
def world():
    spec = RepoSpec(
        video_lengths=[6_000] * 3, num_instances=120, chunk_frames=600,
        locality=4.0, seed=7,
    )
    repo, chunks = generate(spec)
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    return repo, chunks, det


def _fresh(chunks, key):
    return init_carry(
        init_state(chunks.length), init_matcher(max_results=512), key
    )


def _fresh_multi(chunks, keys):
    return init_carry_multi(
        init_state(chunks.length), init_matcher(max_results=512), keys
    )


def _qkey(q):
    return jax.random.fold_in(jax.random.PRNGKey(0), q)


# ---------------------------------------------------------------------------
# Q=1 parity: bit-identical to run_search_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cohorts", [1, 8])
def test_multi_q1_bit_identical_to_scan(world, cohorts):
    _, chunks, det = world
    scan, scan_trace = run_search_scan(
        _fresh(chunks, jax.random.PRNGKey(0)), chunks, detector=det,
        result_limit=15, max_steps=1200, cohorts=cohorts, trace_every=25,
    )
    multi, traces, stats = run_search_multi(
        _fresh_multi(chunks, jax.random.PRNGKey(0)[None]), chunks,
        detector=det, result_limits=15, max_steps=1200, cohorts=cohorts,
        trace_every=25,
    )
    assert (int(scan.step), int(scan.results)) == (
        int(multi.step[0]), int(multi.results[0])
    )
    assert scan_trace == traces[0]
    np.testing.assert_array_equal(
        np.asarray(scan.sampler.n), np.asarray(multi.sampler.n[0])
    )
    np.testing.assert_array_equal(
        np.asarray(scan.sampler.n1), np.asarray(multi.sampler.n1[0])
    )
    np.testing.assert_array_equal(
        np.asarray(scan.key), np.asarray(multi.key[0])
    )
    # one query, no duplicates: every sampled frame is one detector call
    assert stats["detector_invocations"] == int(multi.step[0])


@pytest.mark.parametrize("method", ["wilson_hilferty", "pallas"])
def test_multi_q1_other_methods(world, method):
    _, chunks, det = world
    scan, _ = run_search_scan(
        _fresh(chunks, jax.random.PRNGKey(0)), chunks, detector=det,
        result_limit=10, max_steps=600, method=method,
    )
    multi, _, _ = run_search_multi(
        _fresh_multi(chunks, jax.random.PRNGKey(0)[None]), chunks,
        detector=det, result_limits=10, max_steps=600, method=method,
    )
    assert (int(scan.step), int(scan.results)) == (
        int(multi.step[0]), int(multi.results[0])
    )


# ---------------------------------------------------------------------------
# Q=4 disjoint keys: each query matches its own sequential run
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cache", [0, -1])
def test_multi_q4_each_query_matches_sequential(world, cache):
    _, chunks, det = world
    q_n, cohorts = 4, 4
    limits = [12, 12, 6, 12]   # query 2 finishes early and must mask out
    keys = jnp.stack([_qkey(q) for q in range(q_n)])
    cache_frames = chunks.total_frames if cache else 0
    multi, traces, stats = run_search_multi(
        _fresh_multi(chunks, keys), chunks, detector=det,
        result_limits=jnp.asarray(limits, jnp.int32), max_steps=900,
        cohorts=cohorts, trace_every=25, cache_frames=cache_frames,
    )
    for q in range(q_n):
        scan, scan_trace = run_search_scan(
            _fresh(chunks, _qkey(q)), chunks, detector=det,
            result_limit=limits[q], max_steps=900, cohorts=cohorts,
            trace_every=25,
        )
        assert (int(scan.step), int(scan.results)) == (
            int(multi.step[q]), int(multi.results[q])
        ), f"query {q} diverged"
        assert scan_trace == traces[q], f"query {q} trace diverged"
        np.testing.assert_array_equal(
            np.asarray(scan.sampler.n), np.asarray(multi.sampler.n[q])
        )
        np.testing.assert_array_equal(
            np.asarray(scan.key), np.asarray(multi.key[q])
        )
    # sharing can only save detector work, never add any
    assert stats["detector_invocations"] <= stats["frames_sampled"]


def test_stack_carries_matches_init_multi(world):
    _, chunks, _ = world
    keys = [_qkey(q) for q in range(3)]
    stacked = stack_carries([_fresh(chunks, k) for k in keys])
    built = _fresh_multi(chunks, jnp.stack(keys))
    for a, b in zip(jax.tree.leaves(stacked), jax.tree.leaves(built)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_init_matcher_multi_layout():
    from repro.core import init_matcher_multi

    single = init_matcher(max_results=8, feat_dim=4, iou_thresh=0.3)
    multi = init_matcher_multi(3, max_results=8, feat_dim=4, iou_thresh=0.3)
    assert multi.iou_thresh == single.iou_thresh    # statics shared
    for a, b in zip(jax.tree.leaves(multi), jax.tree.leaves(single)):
        assert a.shape == (3,) + b.shape
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b))


def test_identical_queries_dedup_exactly(world):
    """Q identical queries (same key) sample identical frames every round,
    so the batched pass detects each frame exactly once: invocations =
    frames_sampled / Q, even with the cache off."""
    _, chunks, det = world
    q_n, cohorts = 4, 4
    keys = jnp.stack([jax.random.PRNGKey(3)] * q_n)
    multi, _, stats = run_search_multi(
        _fresh_multi(chunks, keys), chunks, detector=det,
        result_limits=12, max_steps=600, cohorts=cohorts,
    )
    steps = np.asarray(multi.step)
    assert (steps == steps[0]).all()
    assert stats["frames_sampled"] == int(steps.sum())
    assert stats["detector_invocations"] * q_n == stats["frames_sampled"]


# ---------------------------------------------------------------------------
# Batched Thompson choice: per-query bit-parity with the scalar path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["exact", "wilson_hilferty", "pallas"])
def test_choose_chunks_batched_parity(method):
    q_n, m, cohorts = 5, 37, 6
    rng = jax.random.PRNGKey(11)
    n1 = jnp.abs(jax.random.normal(rng, (q_n, m))) * 3
    n = jnp.abs(jax.random.normal(jax.random.fold_in(rng, 1), (q_n, m))) * 9
    frames = jnp.full((q_n, m), 100, jnp.int32)
    # a couple of exhausted chunks per query
    n = n.at[:, 0].set(100.0)
    import dataclasses

    state = init_state(frames[0])
    batched_state = dataclasses.replace(state, n1=n1, n=n, frames=frames)
    keys = jnp.stack([_qkey(q) for q in range(q_n)])
    got = choose_chunks_batched(
        keys, batched_state, cohorts=cohorts, method=method
    )
    assert got.shape == (q_n, cohorts)
    for q in range(q_n):
        single = dataclasses.replace(
            state, n1=n1[q], n=n[q], frames=frames[q]
        )
        want = choose_chunks(keys[q], single, cohorts=cohorts, method=method)
        np.testing.assert_array_equal(np.asarray(got[q]), np.asarray(want))


# ---------------------------------------------------------------------------
# Dedup + cache properties (run under the hypothesis stub when offline)
# ---------------------------------------------------------------------------


@settings(max_examples=60)
@given(
    frames=st.lists(st.integers(0, 9), min_size=1, max_size=32),
    valid_bits=st.integers(0, 2**32 - 1),
)
def test_dedup_never_drops_never_duplicates(frames, valid_bits):
    f = jnp.asarray(frames, jnp.int32)
    valid = np.asarray(
        [(valid_bits >> i) & 1 for i in range(len(frames))], bool
    )
    first = np.asarray(dedup_first_index(f, jnp.asarray(valid)))
    is_rep = (first == np.arange(len(frames))) & valid
    for i, ok in enumerate(valid):
        if not ok:
            continue
        r = first[i]
        # never drops: every valid slot gathers a valid representative
        # holding EXACTLY the frame the query sampled
        assert valid[r] and frames[r] == frames[i]
        assert is_rep[r]
        assert r <= i
    # never double-counts: exactly one representative (one detector call)
    # per distinct valid frame
    assert is_rep.sum() == len({frames[i] for i in np.nonzero(valid)[0]})


@settings(max_examples=10)
@given(seed=st.integers(0, 2**16))
def test_round_sampler_deltas_isolated_per_query(seed, _world_cache={}):
    """No detection is ever double-counted across queries: after a short
    multi-query run, each query's sampler has absorbed exactly its own
    frames (Σ n-delta == its step counter) and its trajectory equals its
    solo run — a detection leaking into another query's deltas would break
    both."""
    if "w" not in _world_cache:
        spec = RepoSpec(
            video_lengths=[2_000] * 2, num_instances=60, chunk_frames=500,
            locality=3.0, seed=5,
        )
        _world_cache["w"] = generate(spec)
    repo, chunks = _world_cache["w"]
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    q_n, cohorts = 3, 2
    keys = jnp.stack([
        jax.random.fold_in(jax.random.PRNGKey(seed), q) for q in range(q_n)
    ])
    multi, _, stats = run_search_multi(
        _fresh_multi(chunks, keys), chunks, detector=det,
        result_limits=8, max_steps=24, cohorts=cohorts,
        cache_frames=chunks.total_frames,
    )
    n_sum = np.asarray(multi.sampler.n).sum(axis=-1)
    steps = np.asarray(multi.step)
    np.testing.assert_array_equal(n_sum, steps.astype(n_sum.dtype))
    for q in range(q_n):
        solo, _ = run_search_scan(
            _fresh(chunks, keys[q]), chunks, detector=det,
            result_limit=8, max_steps=24, cohorts=cohorts,
        )
        assert (int(solo.step), int(solo.results)) == (
            int(multi.step[q]), int(multi.results[q])
        )


# ---------------------------------------------------------------------------
# Detection cache unit semantics
# ---------------------------------------------------------------------------


def _det_struct():
    return {
        "boxes": jax.ShapeDtypeStruct((2, 4), jnp.float32),
        "valid": jax.ShapeDtypeStruct((2,), jnp.bool_),
    }


def test_cache_roundtrip_and_eviction():
    cache = init_detection_cache(_det_struct(), capacity=4)
    frames = jnp.asarray([0, 1, 5, 2], jnp.int32)
    dets = {
        "boxes": jnp.arange(4 * 2 * 4, dtype=jnp.float32).reshape(4, 2, 4),
        "valid": jnp.ones((4, 2), bool),
    }
    cache = cache_insert(cache, frames, dets, jnp.ones((4,), bool))
    hit, vals = cache_lookup(cache, frames)
    # frame 5 collides with frame 1 (slot 1); the FIRST masked write wins,
    # so 1 survives and 5 missed
    np.testing.assert_array_equal(np.asarray(hit), [True, True, False, True])
    np.testing.assert_array_equal(
        np.asarray(vals["boxes"][0]), np.asarray(dets["boxes"][0])
    )
    # eviction: inserting frame 5 now overwrites slot 1
    cache = cache_insert(
        cache,
        jnp.asarray([5], jnp.int32),
        jax.tree.map(lambda x: x[2:3], dets),
        jnp.ones((1,), bool),
    )
    hit2, _ = cache_lookup(cache, frames)
    np.testing.assert_array_equal(np.asarray(hit2), [True, False, True, True])


def test_cache_padded_sentinel_frames_never_hit():
    """Regression: a padded/sentinel frame id of -1 maps to slot
    ``capacity-1`` (Python modulo) and compared equal to the empty-slot
    tag -1 — so padding slots of a ``RequestBatcher`` batch reported
    phantom cache hits against an EMPTY cache and gathered garbage
    detections.  Sentinels must miss on lookup and be inert on insert."""
    cache = init_detection_cache(_det_struct(), capacity=4)
    padded = jnp.asarray([0, -1, -1, 2], jnp.int32)   # Batch.frame_ids style
    hit, _ = cache_lookup(cache, padded)
    np.testing.assert_array_equal(np.asarray(hit), [False] * 4)

    # harden cache_insert the same way: seed slot capacity-1 with a real
    # frame, then insert a padded batch whose mask (wrongly) covers the
    # sentinels — the real entry must survive and the sentinel never lands
    dets = {
        "boxes": jnp.ones((4, 2, 4), jnp.float32),
        "valid": jnp.ones((4, 2), bool),
    }
    cache = cache_insert(
        cache, jnp.asarray([7], jnp.int32),
        jax.tree.map(lambda x: x[:1], dets), jnp.ones((1,), bool),
    )
    cache = cache_insert(cache, padded, dets, jnp.ones((4,), bool))
    assert int(cache.tag[3]) == 7                     # not clobbered to -1
    hit2, _ = cache_lookup(cache, jnp.asarray([7, -1], jnp.int32))
    np.testing.assert_array_equal(np.asarray(hit2), [True, False])


def test_cache_masked_insert_is_noop():
    cache = init_detection_cache(_det_struct(), capacity=4)
    dets = {
        "boxes": jnp.ones((1, 2, 4), jnp.float32),
        "valid": jnp.ones((1, 2), bool),
    }
    cache = cache_insert(
        cache, jnp.asarray([3], jnp.int32), dets, jnp.zeros((1,), bool)
    )
    hit, _ = cache_lookup(cache, jnp.asarray([3], jnp.int32))
    assert not bool(hit[0])
