import os
import sys

# Tests must see exactly ONE device (the dry-run sets its own flag in a
# separate process).  Sharding tests spawn subprocesses with their own
# XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")

# The property tests want hypothesis (declared in pyproject's test extra);
# air-gapped environments fall back to the deterministic stub so the suite
# still collects and exercises the properties.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import importlib.util
    import pathlib

    _spec = importlib.util.spec_from_file_location(
        "hypothesis", pathlib.Path(__file__).parent / "_hypothesis_stub.py"
    )
    _stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _stub.strategies
