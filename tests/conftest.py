import os

# Tests must see exactly ONE device (the dry-run sets its own flag in a
# separate process).  Sharding tests spawn subprocesses with their own
# XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")
