"""Multi-tenant search service: admission + SLO scheduling (DESIGN.md §12).

The acceptance bar composes the driver's (tests/test_async_compose.py):
admission control must price plans with the §4.6 cost model and debit a
race-free ledger; slots must be REUSED across tenant generations rather
than growing the pool; and multi-tenancy must not perturb any tenant's
search — each admitted tenant's trajectory is bit-identical to its solo
``run_search_scan`` run at its debited frame budget.  The E2E test drives
four tenants through the ``repro.launch.serve_search`` front onto one
live driver with admission rejections/queueing and verifies zero result
loss (``results == ring live entries + len(ResultLog)`` per tenant).
"""
import argparse
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    init_carry,
    init_carry_multi,
    init_matcher,
    init_state,
    run_search_scan,
)
from repro.core.plan import Execution, PlanError, SearchPlan, ServiceConfig
from repro.sim import RepoSpec, generate
from repro.sim.costmodel import CostRates, plan_projected_cost
from repro.sim.oracle import class_select, oracle_detect
from repro.serve.service import (
    FINISHED,
    QUEUED,
    REJECTED,
    RUNNING,
    SearchService,
)

warnings.filterwarnings("ignore", message="run_search_scan")

RATES = CostRates()
# default rates: 1/detect_fps + 1/random_read_fps = 0.12 s per sampled frame
FRAME_S = 1.0 / RATES.detect_fps + 1.0 / RATES.random_read_fps


@pytest.fixture(scope="module")
def world():
    spec = RepoSpec(
        video_lengths=[6_000] * 3, num_instances=120, chunk_frames=600,
        locality=4.0, seed=7,
    )
    repo, chunks = generate(spec)
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    return repo, chunks, det


def _qkey(q):
    return jax.random.fold_in(jax.random.PRNGKey(0), q)


def _proto(chunks, max_results=64):
    return init_carry_multi(
        init_state(chunks.length), init_matcher(max_results=max_results),
        jnp.stack([jax.random.PRNGKey(0)]),
    )


def _service(chunks, det, **kw):
    kw.setdefault("cohorts", 2)
    kw.setdefault("num_workers", 1)
    kw.setdefault("slots_per_batch", 2)
    return SearchService(_proto(chunks), chunks, det, rates=RATES, **kw)


def _plan(max_steps=1500, limit=8, service=None, cohorts=2):
    return SearchPlan(
        result_limit=limit, max_steps=max_steps, cohorts=cohorts,
        execution=Execution(queries_axis=True, service=service),
    )


def _drain_sync(svc, deadline_s=120.0):
    svc.start(pump=False)
    svc.drain(deadline_s=deadline_s)
    svc.stop()


# ---------------------------------------------------------------------------
# Admission control: accept / reject / queue matrix under CostRates budgets
# ---------------------------------------------------------------------------


def test_admission_accept_reject_matrix(world):
    """Projected cost vs remaining budget decides accept/queue/reject —
    priced BEFORE anything runs, so no tick is needed to observe it."""
    _, chunks, det = world
    svc = _service(chunks, det, budget_s=1000 * FRAME_S)

    a = svc.submit("a", _plan(max_steps=600), key=_qkey(0))
    assert a.state == RUNNING
    assert a.projected_s == pytest.approx(600 * FRAME_S)
    assert svc.budget.committed_s == pytest.approx(600 * FRAME_S)

    # fits the total but not the remainder: rejected without queue_on_reject
    b = svc.submit("b", _plan(max_steps=600), key=_qkey(1))
    assert b.state == REJECTED and "remaining" in b.reason

    # same projection, queue_on_reject: parked, budget NOT debited
    c = svc.submit(
        "c", _plan(max_steps=600, service=ServiceConfig(queue_on_reject=True)),
        key=_qkey(2),
    )
    assert c.state == QUEUED
    assert svc.budget.committed_s == pytest.approx(600 * FRAME_S)

    # can never fit: rejected outright even with queue_on_reject (queueing
    # it would deadlock the drain)
    d = svc.submit(
        "d",
        _plan(max_steps=100_000, service=ServiceConfig(queue_on_reject=True)),
        key=_qkey(3),
    )
    assert d.state == REJECTED and "total" in d.reason

    # multi-query plans are not admissible service units
    with pytest.raises(PlanError, match="single-query"):
        svc.submit("e", SearchPlan(queries=2, execution=Execution(
            queries_axis=True)), key=_qkey(4))
    with pytest.raises(PlanError, match="already submitted"):
        svc.submit("a", _plan(), key=_qkey(0))


def test_projection_matches_costmodel(world):
    plan = _plan(max_steps=777)
    assert plan_projected_cost(plan, RATES).total_s == pytest.approx(
        777 * FRAME_S)


def test_warm_plan_admitted_where_cold_projection_rejects(world):
    """Regression (warm-plan over-pricing): a plan whose detections are
    ~90% persisted in the shared index was still priced as if every frame
    paid a fresh detector call, so admission rejected it under budgets it
    trivially fits.  The coverage-discounted projection must admit it,
    stay ≥ the scan-only floor, and settle normally with the credit
    surfaced in per-tenant economics."""
    from repro.core.plan import IndexSpec
    from repro.index.store import RepositoryIndex

    _, chunks, det = world
    index = RepositoryIndex(detector_version="v1")
    covered = int(0.9 * chunks.total_frames)
    f = jnp.arange(covered, dtype=jnp.int32)
    index.publish(f, f.astype(jnp.float32))
    coverage = covered / chunks.total_frames

    ms = 1500
    cold = plan_projected_cost(_plan(max_steps=ms), RATES).total_s
    assert cold == pytest.approx(ms * FRAME_S)

    warm_plan = SearchPlan(
        result_limit=8, max_steps=ms, cohorts=2,
        execution=Execution(
            queries_axis=True, index=IndexSpec(detector_version="v1"),
        ),
    )
    warm = plan_projected_cost(
        warm_plan, RATES, index=index, total_frames=chunks.total_frames
    ).total_s
    scan_floor = ms / RATES.random_read_fps
    assert warm == pytest.approx(
        ms * ((1 - coverage) / RATES.detect_fps + 1 / RATES.random_read_fps))
    assert scan_floor <= warm < cold

    # a budget between warm and cold: rejects the cold projection,
    # admits the coverage-discounted one
    budget = 0.5 * (warm + cold)
    svc = _service(chunks, det, budget_s=budget, index=index)
    t = svc.submit("warm", warm_plan, key=_qkey(0))
    assert t.state == RUNNING
    assert t.projected_s == pytest.approx(warm)
    assert svc.budget.committed_s == pytest.approx(warm)
    _drain_sync(svc)
    assert t.state == FINISHED
    assert svc.budget.committed_s == pytest.approx(0.0)
    steps = int(t.row_obj.carry.step)
    assert t.actual_s == pytest.approx(steps * FRAME_S)
    econ = t.to_dict()["projected_vs_settled"]
    assert econ["projected_s"] == pytest.approx(warm)
    assert econ["settled_s"] == pytest.approx(t.actual_s)
    assert econ["credited_s"] == pytest.approx(warm - t.actual_s)


def test_warm_projection_requires_index_binding(world):
    """No IndexSpec on the plan, or no live index/total_frames at the
    call, keeps the cold upper bound — the discount never applies by
    accident."""
    from repro.index.store import RepositoryIndex

    _, chunks, _ = world
    index = RepositoryIndex(detector_version="v1")
    f = jnp.arange(100, dtype=jnp.int32)
    index.publish(f, f.astype(jnp.float32))
    plan = _plan(max_steps=500)                # no IndexSpec
    cold = 500 * FRAME_S
    assert plan_projected_cost(
        plan, RATES, index=index, total_frames=chunks.total_frames
    ).total_s == pytest.approx(cold)
    from repro.core.plan import IndexSpec
    bound = SearchPlan(
        result_limit=8, max_steps=500,
        execution=Execution(
            queries_axis=True, index=IndexSpec(detector_version="v1"),
        ),
    )
    assert plan_projected_cost(bound, RATES).total_s == pytest.approx(cold)
    assert plan_projected_cost(
        bound, RATES, index=index, total_frames=0
    ).total_s == pytest.approx(cold)
    # wrong detector version reads an empty tier: no discount
    assert plan_projected_cost(
        dataclasses.replace(
            bound,
            execution=Execution(
                queries_axis=True, index=IndexSpec(detector_version="v9"),
            ),
        ),
        RATES, index=index, total_frames=chunks.total_frames,
    ).total_s == pytest.approx(cold)


def test_budget_settles_actual_and_credits_unspent(world):
    """The admission debit is an upper bound; retirement settles the
    realized sampling cost and credits the rest back to headroom."""
    _, chunks, det = world
    svc = _service(chunks, det, budget_s=10_000 * FRAME_S)
    t = svc.submit("a", _plan(max_steps=5_000, limit=4), key=_qkey(0))
    _drain_sync(svc)
    assert t.state == FINISHED
    assert svc.budget.committed_s == pytest.approx(0.0)
    steps = int(t.row_obj.carry.step)
    assert t.actual_s == pytest.approx(steps * FRAME_S)
    assert svc.budget.spent_s == pytest.approx(t.actual_s)
    assert t.actual_s < t.projected_s          # limit hit early ⇒ credit
    assert svc.budget.remaining_s == pytest.approx(
        10_000 * FRAME_S - t.actual_s)


# ---------------------------------------------------------------------------
# Slot reuse + queued admission
# ---------------------------------------------------------------------------


def test_slot_reuse_after_retire(world):
    """Sequential tenants reuse the same Q-axis slot: the pool's device
    footprint tracks concurrency, not tenant count."""
    _, chunks, det = world
    svc = _service(chunks, det)
    a = svc.submit("a", _plan(limit=3), key=_qkey(0))
    _drain_sync(svc)
    b = svc.submit("b", _plan(limit=3), key=_qkey(1))
    _drain_sync(svc)
    assert a.state == b.state == FINISHED
    assert a.row == b.row                     # same slot, two generations
    assert len(svc.driver.rows) == 1          # proto slot only, never grew
    # harvested rows stay distinct objects with their own results
    assert a.row_obj is not b.row_obj
    assert int(a.row_obj.carry.results) >= 3
    assert int(b.row_obj.carry.results) >= 3


def test_queued_tenants_admit_by_priority_when_capacity_frees(world):
    """Capacity freed by a retirement admits parked plans highest-priority
    first (FIFO within a level), and the head blocks the tail."""
    _, chunks, det = world
    svc = _service(chunks, det, budget_s=1000 * FRAME_S)
    t1 = svc.submit("t1", _plan(max_steps=900, limit=3), key=_qkey(0))
    lo = svc.submit(
        "lo", _plan(max_steps=900, limit=3,
                    service=ServiceConfig(queue_on_reject=True, priority=0)),
        key=_qkey(1))
    hi = svc.submit(
        "hi", _plan(max_steps=900, limit=3,
                    service=ServiceConfig(queue_on_reject=True, priority=5)),
        key=_qkey(2))
    assert t1.state == RUNNING and lo.state == QUEUED and hi.state == QUEUED
    _drain_sync(svc)
    assert {t.state for t in (t1, lo, hi)} == {FINISHED}
    # hi (later submit, higher priority) was admitted before lo
    assert hi.row_obj.admitted_s < lo.row_obj.admitted_s


def test_queued_plan_that_can_never_fit_is_rejected_not_stuck(world):
    """Regression: ``spent_s`` is never credited back, so a parked plan
    whose projection exceeds ``total − spent`` can never be admitted.  It
    used to sit QUEUED forever once earlier tenants settled their spend —
    ``busy()`` stayed True and ``drain()`` span to TimeoutError.  The pump
    must re-reject it the moment the shrunken ceiling rules it out."""
    _, chunks, det = world
    svc = _service(chunks, det, budget_s=1000 * FRAME_S)
    # `a` fits and will exhaust its whole 600-frame budget (limit is
    # unreachable), settling spent_s ≈ 600 frames
    a = svc.submit("a", _plan(max_steps=600, limit=64), key=_qkey(0))
    b = svc.submit(
        "b", _plan(max_steps=600, limit=3,
                   service=ServiceConfig(queue_on_reject=True)),
        key=_qkey(1))
    assert a.state == RUNNING and b.state == QUEUED
    _drain_sync(svc, deadline_s=60.0)          # pre-fix: TimeoutError here
    assert a.state == FINISHED
    assert int(a.row_obj.carry.step) == 600    # spend settled at 600 frames
    # after settling, total − spent = 400 frames < b's 600-frame projection
    assert b.state == REJECTED and "never fit" in b.reason
    assert svc.budget.committed_s == pytest.approx(0.0)


def test_rejected_tenant_can_resubmit_under_same_id(world):
    """A rejection is terminal for the PLAN, not the tenant id: the same
    tenant may come back with a smaller plan (and a finished id may be
    reused), while QUEUED/RUNNING ids stay exclusive."""
    _, chunks, det = world
    svc = _service(chunks, det, budget_s=1000 * FRAME_S)
    r = svc.submit("a", _plan(max_steps=100_000), key=_qkey(0))
    assert r.state == REJECTED
    t = svc.submit("a", _plan(max_steps=500, limit=3), key=_qkey(0))
    assert t.state == RUNNING
    with pytest.raises(PlanError, match="already submitted"):
        svc.submit("a", _plan(max_steps=500, limit=3), key=_qkey(0))
    _drain_sync(svc)
    assert t.state == FINISHED
    again = svc.submit("a", _plan(max_steps=500, limit=3), key=_qkey(1))
    assert again.state == RUNNING
    _drain_sync(svc)
    assert again.state == FINISHED
    # the service keeps ONE record per id: the latest generation
    assert svc.tenants["a"] is again
    # terminal records can be evicted so a persistent service stays bounded
    assert svc.evict_terminal() == 1
    assert not svc.tenants and not svc.busy()


def test_running_tenant_slo_visible_before_retire(world):
    """Regression: SLO attainment must be visible for in-flight tenants —
    the driver stamps ``first_result_s`` at the merge, but the report used
    to read a ``row_obj`` only bound at reap time, so a RUNNING tenant
    whose first result had already merged reported ``ttfr_s=None``."""
    _, chunks, det = world
    svc = _service(chunks, det)
    t = svc.submit(
        "a", _plan(max_steps=1500, limit=64,
                   service=ServiceConfig(slo_latency_s=300.0)),
        key=_qkey(0))
    svc.start(pump=False)
    for _ in range(200):
        svc.tick(timeout=5.0)
        if t.state != RUNNING or t.row_obj.first_result_s:
            break
    assert t.state == RUNNING              # limit 64 is not hit this fast
    rep = t.slo_report()
    assert rep["ttfr_s"] is not None and rep["ttfr_s"] > 0
    assert rep["slo_met"] is True
    assert t.to_dict()["results"] >= 1     # live progress, same binding
    svc.drain()
    svc.stop()
    assert t.state == FINISHED


def test_concurrent_submits_race_the_background_pump(world):
    """Regression: the pump's ``_reap``/``busy`` used to iterate the live
    ``self.tenants`` dict while ``submit`` (another thread) inserted under
    the lock — a mid-iteration insert raised ``RuntimeError: dictionary
    changed size during iteration``, silently killing the pump so nothing
    ever retired and drain timed out.  Both now iterate locked snapshots;
    submitting against a hot pump must drain cleanly."""
    _, chunks, det = world
    svc = _service(chunks, det)
    svc.start(pump=True)
    try:
        tenants = [
            svc.submit(f"t{i}", _plan(max_steps=60, limit=2), key=_qkey(i))
            for i in range(12)
        ]
        svc.drain(deadline_s=60.0)
    finally:
        svc.stop()
    assert all(t.state == FINISHED for t in tenants)
    assert svc.budget.committed_s == pytest.approx(0.0)


# ---------------------------------------------------------------------------
# Parity: multi-tenancy never perturbs a tenant's search
# ---------------------------------------------------------------------------


def _solo(chunks, det, key, *, result_limit, max_steps, cohorts=2):
    carry = init_carry(
        init_state(chunks.length), init_matcher(max_results=64), key,
    )
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return run_search_scan(
            carry, chunks, detector=det, result_limit=result_limit,
            max_steps=max_steps, cohorts=cohorts,
        )


def test_two_tenant_solo_parity_at_debited_budget(world):
    """Each tenant's trajectory through the shared service — including one
    admitted mid-flight — is bit-identical to its solo ``run_search_scan``
    run at the frame budget the service debited it."""
    _, chunks, det = world
    svc = _service(chunks, det)
    a = svc.submit("a", _plan(max_steps=1500, limit=8), key=_qkey(0))
    svc.start(pump=False)
    for _ in range(3):                        # progress the pool, then join
        svc.tick(timeout=5.0)
    b = svc.submit("b", _plan(max_steps=1500, limit=8), key=_qkey(1))
    svc.drain()
    svc.stop()
    assert a.state == b.state == FINISHED
    # the late joiner was debited the frames it missed: a whole number of
    # pool rounds × cohorts off its requested 1500, the early one none
    assert a.row_obj.budget == 1500
    assert b.row_obj.budget < 1500
    assert (1500 - b.row_obj.budget) % svc.driver.cohorts == 0
    for tenant, key in ((a, _qkey(0)), (b, _qkey(1))):
        row = tenant.row_obj
        solo_out, _ = _solo(
            chunks, det, key, result_limit=8, max_steps=row.budget,
        )
        assert int(row.carry.step) == int(solo_out.step)
        assert int(row.carry.results) == int(solo_out.results)
        assert bool(jnp.all(row.carry.key == solo_out.key))
        np.testing.assert_array_equal(
            row.carry.sampler.n, solo_out.sampler.n)
        np.testing.assert_array_equal(
            row.carry.sampler.n1, solo_out.sampler.n1)
        np.testing.assert_array_equal(
            row.carry.matcher.times_seen, solo_out.matcher.times_seen)


def test_select_id_binds_tenant_predicate(world):
    """``select_id`` routes a tenant's lane to its own predicate through
    the service's ONE universe ``class_select`` — equivalent to a solo
    Q=1 run with the predicate bound directly, with no recompilation."""
    repo, chunks, _ = world
    num_classes = int(jnp.max(repo.inst_class)) + 1
    det_all = lambda key, frame: oracle_detect(repo, frame, query_class=None)
    svc = _service(
        chunks, det_all, select=class_select(repo, list(range(num_classes))),
    )
    tenants = {}
    for cls in (0, 1):
        tenants[cls] = svc.submit(
            f"cls{cls}", _plan(max_steps=1200, limit=5),
            key=_qkey(cls), select_id=cls,
        )
    _drain_sync(svc)
    for cls, tenant in tenants.items():
        assert tenant.state == FINISHED
        row = tenant.row_obj
        ref = SearchPlan(
            queries=1, result_limit=5, max_steps=row.budget, cohorts=2,
            execution=Execution(queries_axis=True),
        ).run(
            init_carry_multi(
                init_state(chunks.length), init_matcher(max_results=64),
                jnp.stack([_qkey(cls)]),
            ),
            chunks, detector=det_all, select=class_select(repo, [cls]),
        )
        assert int(row.carry.step) == ref.steps[0]
        assert int(row.carry.results) == ref.results[0]
        np.testing.assert_array_equal(
            row.carry.sampler.n, ref.carry.sampler.n[0])
        np.testing.assert_array_equal(
            row.carry.matcher.times_seen, ref.carry.matcher.times_seen[0])


# ---------------------------------------------------------------------------
# SLO accounting
# ---------------------------------------------------------------------------


def test_slo_accounting(world):
    """Time-to-first-result is measured from admission; a generous SLO is
    met, an impossible one is missed, and no SLO reports None — the
    service reports attainment, it never kills a query."""
    _, chunks, det = world
    svc = _service(chunks, det)
    met = svc.submit(
        "met", _plan(limit=3, service=ServiceConfig(slo_latency_s=300.0)),
        key=_qkey(0))
    missed = svc.submit(
        "missed", _plan(limit=3, service=ServiceConfig(slo_latency_s=1e-9)),
        key=_qkey(1))
    none = svc.submit("none", _plan(limit=3), key=_qkey(2))
    _drain_sync(svc)
    for t in (met, missed, none):
        assert t.state == FINISHED
        rep = t.slo_report()
        assert rep["ttfr_s"] is not None and rep["ttfr_s"] > 0
        # wall-clock ordering: admission precedes first result, first
        # result precedes retirement
        row = t.row_obj
        assert row.admitted_s < row.first_result_s <= row.finished_s
    assert met.slo_report()["slo_met"] is True
    assert missed.slo_report()["slo_met"] is False
    assert none.slo_report()["slo_met"] is None


def test_per_tenant_stats_and_occupancy(world):
    """Per-tenant SearchStats attribute detector economics by dedup
    representative, and the service's batch occupancy follows the
    RequestBatcher ``occupancy = 1 − padding`` convention."""
    _, chunks, det = world
    svc = _service(chunks, det)
    a = svc.submit("a", _plan(limit=4), key=_qkey(0))
    b = svc.submit("b", _plan(limit=4), key=_qkey(1))
    _drain_sync(svc)
    st = svc.stats()
    d = svc.driver.stats
    assert abs(svc.occupancy + svc.padding_fraction() - 1.0) < 1e-12
    assert st["batch"]["lanes_issued"] == d["lanes_issued"] > 0
    # attributed economics sum to the pool totals: every fresh detector
    # call and cache hit belongs to exactly one tenant (its dedup rep)
    fresh = sum(t.stats.detector_invocations for t in (a, b))
    hits = sum(t.stats.cache_hits for t in (a, b))
    assert fresh == d["detector_invocations"]
    assert hits == d["cache_hits"]
    for t in (a, b):
        s = t.stats
        assert s.frames_sampled == int(t.row_obj.carry.step)
        assert s.rounds == t.row_obj.rounds > 0
        assert s.results_spilled == len(t.row_obj.log)


# ---------------------------------------------------------------------------
# E2E: four tenants over the front onto one live driver
# ---------------------------------------------------------------------------


def test_front_e2e_four_tenants_one_live_driver():
    """The stdin-RPC front: ≥4 tenants share one live driver, admission
    rejects one plan and queues another, the drain is clean and NO result
    is lost: per tenant, ``results == ring live entries + len(ResultLog)``."""
    from repro.launch.serve_search import build_service, handle_request

    args = argparse.Namespace(
        dataset="dashcam", scale=0.02, seed=0,
        budget_s=4 * 1200 * FRAME_S + 1.0,
        cohorts=4, workers=2, max_steps=100_000, max_results=256,
        slots_per_batch=4, cache=True,
    )
    service = build_service(args)
    service.start()   # background pump: requests arrive against live work
    try:
        def submit(tid, cls, seed, *, max_steps=1200, limit=4,
                   service_cfg=None):
            plan = {
                "result_limit": limit, "max_steps": max_steps, "cohorts": 4,
                "execution": {"queries_axis": True},
            }
            if service_cfg:
                plan["execution"]["service"] = service_cfg
            return handle_request(service, {
                "op": "submit", "tenant": tid, "class": cls,
                "seed": seed, "plan": plan,
            })

        live = [submit(f"t{i}", cls=i % service.num_classes, seed=i)
                for i in range(4)]
        assert all(r["ok"] and r["state"] == RUNNING for r in live)
        # 5th plan exceeds the REMAINING budget → queued for capacity
        queued = submit("t4", cls=0, seed=4,
                        service_cfg={"queue_on_reject": True})
        assert queued["ok"] and queued["state"] == QUEUED
        # 6th exceeds the TOTAL budget → rejected by admission
        rejected = submit("t5", cls=1, seed=5, max_steps=500_000)
        assert rejected["ok"] and rejected["state"] == REJECTED
        assert "budget" in rejected["reason"]
        # malformed plan surfaces a typed field error, not a crash
        bad = handle_request(service, {
            "op": "submit", "tenant": "bad", "class": 0,
            "plan": {"max_step": 5}})
        assert not bad["ok"] and bad["field"] == "max_step"

        resp = handle_request(service, {"op": "drain", "deadline_s": 300})
        assert resp["ok"]
    finally:
        service.stop()

    tenants = resp["tenants"]
    finished = [t for t in tenants.values() if t["state"] == FINISHED]
    assert len(finished) == 5                 # 4 live + the queued one
    assert tenants["t5"]["state"] == REJECTED
    assert "bad" not in tenants
    # zero result loss, per tenant: distinct results == live ring entries
    # + host-spilled entries
    for tid in ("t0", "t1", "t2", "t3", "t4"):
        row = service.tenants[tid].row_obj
        ring_live = int((np.asarray(row.carry.matcher.times_seen) > 0).sum())
        assert int(row.carry.results) == ring_live + len(row.log)
        assert int(row.carry.results) >= 1
        # every tenant retired for a legitimate reason: its result limit
        # or its (debited) frame budget — never dropped mid-flight
        assert (int(row.carry.results) >= 4
                or int(row.carry.step) >= row.budget)
    # budget ledger closed: nothing committed, spends settled
    assert resp["budget"]["committed_s"] == pytest.approx(0.0)
    assert resp["budget"]["spent_s"] > 0
    # every slot freed for reuse; the pool never grew past concurrency
    assert len(service.driver.rows) <= 4
    assert all(r.vacant for r in service.driver.rows)
    # unknown op is a clean protocol error
    assert not handle_request(service, {"op": "nope"})["ok"]
