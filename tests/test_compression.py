"""Gradient compression: quantization error + error-feedback convergence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import _dequantize, _quantize


def test_quantize_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1024,)) * 5
    q, s = _quantize(x, 128)
    back = _dequantize(q, s, x.shape, 128)
    blocks = np.asarray(x).reshape(-1, 128)
    per_block_bound = np.abs(blocks).max(1) / 127.0
    err = np.abs(np.asarray(back).reshape(-1, 128) - blocks)
    assert (err <= per_block_bound[:, None] * 0.5001 + 1e-7).all()


def test_error_feedback_unbiased_over_time():
    """With EF, the running sum of transmitted values tracks the running sum
    of true gradients (compression error does not accumulate)."""
    key = jax.random.PRNGKey(1)
    g_true = jax.random.normal(key, (64, 256)) * 0.1
    residual = jnp.zeros((256,))
    sent_sum = jnp.zeros((256,))
    true_sum = jnp.zeros((256,))
    for i in range(64):
        g = g_true[i]
        x = g + residual
        q, s = _quantize(x, 64)
        sent = _dequantize(q, s, x.shape, 64)
        residual = x - sent
        sent_sum = sent_sum + sent
        true_sum = true_sum + g
    # EF guarantee: |Σ sent − Σ true| = |residual| ≤ one quantization step
    gap = np.abs(np.asarray(sent_sum - true_sum))
    assert gap.max() <= float(jnp.abs(residual).max()) + 1e-6
    # and the residual itself is bounded by the last block scales
    assert float(jnp.abs(residual).max()) < 0.05


def test_toy_sgd_with_ef_converges_like_exact():
    """Quadratic objective: compressed-with-EF SGD reaches the same optimum."""
    target = jnp.linspace(-1, 1, 128)

    def run(compress: bool):
        w = jnp.zeros(128)
        residual = jnp.zeros(128)
        for _ in range(300):
            g = w - target
            if compress:
                x = g + residual
                q, s = _quantize(x, 32)
                g_hat = _dequantize(q, s, x.shape, 32)
                residual = x - g_hat
            else:
                g_hat = g
            w = w - 0.1 * g_hat
        return w

    exact = run(False)
    comp = run(True)
    assert float(jnp.abs(comp - target).max()) < 5e-3
    assert float(jnp.abs(comp - exact).max()) < 5e-3
