"""Device-resident scanned driver ≡ host reference driver (DESIGN.md §7).

The equivalence is the acceptance bar of the scanned driver: identical
(step, results) trajectory AND identical trace checkpoints for the same
PRNG key, across cohort sizes and Thompson methods.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    init_carry,
    init_matcher,
    init_state,
    run_search,
    run_search_scan,
)
from repro.sim import RepoSpec, generate
from repro.sim.oracle import oracle_detect


@pytest.fixture(scope="module")
def world():
    spec = RepoSpec(
        video_lengths=[6_000] * 3, num_instances=120, chunk_frames=600,
        locality=4.0, seed=7,
    )
    repo, chunks = generate(spec)
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    return repo, chunks, det


def _fresh(chunks, seed=0):
    return init_carry(
        init_state(chunks.length), init_matcher(max_results=512),
        jax.random.PRNGKey(seed),
    )


@pytest.mark.parametrize("cohorts", [1, 8])
def test_scan_matches_host_bit_identical(world, cohorts):
    _, chunks, det = world
    host, host_trace = run_search(
        _fresh(chunks), chunks, detector=det, result_limit=15,
        max_steps=1200, cohorts=cohorts, trace_every=25,
    )
    scan, scan_trace = run_search_scan(
        _fresh(chunks), chunks, detector=det, result_limit=15,
        max_steps=1200, cohorts=cohorts, trace_every=25,
    )
    assert (int(host.step), int(host.results)) == (int(scan.step), int(scan.results))
    assert host_trace == scan_trace
    np.testing.assert_array_equal(np.asarray(host.sampler.n), np.asarray(scan.sampler.n))
    np.testing.assert_array_equal(np.asarray(host.sampler.n1), np.asarray(scan.sampler.n1))
    np.testing.assert_array_equal(np.asarray(host.key), np.asarray(scan.key))


@pytest.mark.parametrize("method", ["wilson_hilferty", "pallas"])
def test_scan_matches_host_other_methods(world, method):
    _, chunks, det = world
    host, _ = run_search(
        _fresh(chunks), chunks, detector=det, result_limit=10,
        max_steps=600, method=method,
    )
    scan, _ = run_search_scan(
        _fresh(chunks), chunks, detector=det, result_limit=10,
        max_steps=600, method=method,
    )
    assert (int(host.step), int(host.results)) == (int(scan.step), int(scan.results))


@pytest.mark.parametrize("driver", [run_search, run_search_scan])
def test_trace_fires_on_boundary_crossings_with_cohorts(world, driver):
    """Regression: with cohorts=8 and trace_every=7 the step counter never
    lands on a multiple of 7 below lcm(8,7)·k, so the old ``step %
    trace_every == 0`` recorded nothing; boundary-crossing semantics must
    checkpoint every crossed multiple."""
    _, chunks, det = world
    result_limit = 10**9  # never satisfied — run to max_steps
    final, trace = driver(
        _fresh(chunks), chunks, detector=det, result_limit=result_limit,
        max_steps=40, cohorts=8, trace_every=7,
    )
    assert int(final.step) == 40
    # crossings at steps 8, 16, 24, 32, 40 (floors 1..5) + final entry
    steps = [s for s, _ in trace]
    assert steps == [8, 16, 24, 32, 40, 40], trace
    # results column is consistent with the final carry
    assert trace[-1] == (int(final.step), int(final.results))


@pytest.mark.parametrize("driver", [run_search, run_search_scan])
def test_trace_unit_cohort_matches_every_multiple(world, driver):
    _, chunks, det = world
    _, trace = driver(
        _fresh(chunks), chunks, detector=det, result_limit=10**9,
        max_steps=30, cohorts=1, trace_every=10,
    )
    assert [s for s, _ in trace] == [10, 20, 30, 30]


@pytest.mark.parametrize("driver", [run_search, run_search_scan])
def test_all_chunks_exhausted_stops_early(driver):
    """A repository with fewer frames than max_steps must stop once every
    chunk is exhausted instead of resampling frames forever."""
    spec = RepoSpec(
        video_lengths=[64], num_instances=2, chunk_frames=16,
        num_classes=1, seed=3,
    )
    repo, chunks = generate(spec)
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    final, _ = driver(
        _fresh(chunks), chunks, detector=det, result_limit=10**9,
        max_steps=10_000,
    )
    assert int(final.step) == 64, int(final.step)
    assert bool(jnp.all(final.sampler.exhausted()))


def test_scan_trace_disabled_returns_final_only(world):
    _, chunks, det = world
    final, trace = run_search_scan(
        _fresh(chunks), chunks, detector=det, result_limit=5, max_steps=200,
    )
    assert trace == [(int(final.step), int(final.results))]
