"""Fault tolerance: heartbeats, stragglers, restarts, batcher, elastic."""
import jax.numpy as jnp
import numpy as np

from repro.core.chunks import build_chunks
from repro.core.state import init_state
from repro.distributed.elastic import plan_resize, resize_chunk_stats
from repro.distributed.fault_tolerance import (
    HeartbeatMonitor,
    RestartPolicy,
    WorkerState,
)
from repro.serve.batcher import RequestBatcher


def test_heartbeat_transitions():
    mon = HeartbeatMonitor(suspect_after_s=10, dead_after_s=30)
    mon.register(0, now=0.0)
    mon.register(1, now=0.0)
    mon.heartbeat(0, now=30.0)
    actions = mon.sweep(now=35.0)
    assert 1 in actions["dead"]
    assert mon.workers[0].state is WorkerState.HEALTHY
    assert mon.healthy_workers == [0]


def test_dead_worker_cohort_reissued():
    mon = HeartbeatMonitor(dead_after_s=30)
    mon.register(0, now=0.0)
    mon.assign(0, cohort=42)
    actions = mon.sweep(now=100.0)
    assert actions["reissue_cohorts"] == [42]


def test_straggler_detection_uses_inflight_elapsed_time():
    """Synthetic clock: a cohort in flight far past factor × median is
    re-issued — based on ITS elapsed time, not the worker's history."""
    mon = HeartbeatMonitor(straggler_factor=3.0)
    for w in range(4):
        mon.register(w, now=0.0)
        mon.heartbeat(w, now=1.0)
        mon.record_completion(w, latency=1.0, now=1.0)
    mon.assign(3, cohort=7, now=1.0)
    # elapsed 1.0 ≤ 3 × median(1.0): still within budget
    assert mon.sweep(now=2.0)["reissue_cohorts"] == []
    # elapsed 4.0 > 3.0: over budget ⇒ re-issue exactly once
    assert mon.sweep(now=5.0)["reissue_cohorts"] == [7]
    assert mon.sweep(now=6.0)["reissue_cohorts"] == []


def test_straggler_ema_history_does_not_condemn_fresh_cohorts():
    """Regression (synthetic clock): the old rule compared the worker's
    HISTORICAL ema_latency to the median, so one slow completed cohort
    caused every subsequent cohort from that worker to be re-issued the
    moment it was assigned.  A freshly-assigned cohort must get its full
    factor × median budget regardless of the worker's past."""
    mon = HeartbeatMonitor(straggler_factor=3.0)
    for w in range(4):
        mon.register(w, now=0.0)
        mon.heartbeat(w, now=1.0)
        mon.record_completion(w, latency=1.0, now=1.0)
    # one slow COMPLETED cohort inflates worker 3's EMA way over the median
    mon.record_completion(3, latency=100.0, now=101.0)
    mon.heartbeat(3, now=101.0)
    assert mon.workers[3].ema_latency > 3.0 * 1.0
    mon.assign(3, cohort=7, now=101.0)
    # swept immediately after assignment: elapsed ≈ 0 ⇒ NOT a straggler
    # (fails on the pre-fix ema-vs-median rule, which re-issued cohort 7)
    assert mon.sweep(now=101.5)["reissue_cohorts"] == []
    assert mon.workers[3].inflight_cohort == 7
    # but left in flight past the budget it IS re-issued
    assert mon.sweep(now=120.0)["reissue_cohorts"] == [7]


def test_heartbeat_registers_unknown_worker():
    """Regression: a restarted driver process observing an old worker's
    heartbeat (or completion) must absorb it, not KeyError."""
    mon = HeartbeatMonitor()
    mon.heartbeat(5, now=10.0)             # never register()ed
    assert mon.workers[5].state is WorkerState.HEALTHY
    assert mon.workers[5].last_heartbeat == 10.0
    mon.record_completion(6, latency=2.0, now=12.0)   # also unknown
    assert mon.workers[6].completed == 1
    assert mon.workers[6].ema_latency == 2.0
    mon.assign(7, cohort=3, now=13.0)      # unknown at assign too
    assert mon.workers[7].inflight_cohort == 3
    assert mon.workers[7].inflight_since == 13.0


def test_timestampless_registration_is_not_marked_dead():
    """Regression: a worker absorbed from a timestamp-LESS completion or
    assignment used to be registered with last_heartbeat=0.0 — on a
    monotonic clock the very next sweep read that as ``now − 0.0`` of
    silence, declared the worker DEAD and re-issued its cohort, the
    opposite of absorb-don't-raise.  Liveness must stay unknown (and the
    worker untouched) until a real heartbeat arrives."""
    mon = HeartbeatMonitor(dead_after_s=120.0)
    mon.record_completion(9, latency=2.0)      # legacy caller: no clock
    mon.assign(9, cohort=11)                   # still no clock
    out = mon.sweep(now=10_000.0)
    assert 9 not in out["dead"] and 9 not in out["suspect"]
    assert 11 not in out["reissue_cohorts"]
    assert mon.workers[9].state is WorkerState.HEALTHY
    assert mon.workers[9].last_heartbeat is None
    # the first real heartbeat starts normal liveness tracking
    mon.heartbeat(9, now=10_000.0)
    out = mon.sweep(now=10_200.0)
    assert 9 in out["dead"]
    assert out["reissue_cohorts"] == [11]      # death re-issues in-flight


def test_restart_policy():
    p = RestartPolicy(max_restarts=2)
    assert p.should_restart(0) and p.should_restart(1)
    assert not p.should_restart(2)


def test_batcher_padding_and_order():
    b = RequestBatcher(batch_size=4)
    b.submit([10, 11, 12], [0, 0, 1], cohort=0)
    assert b.ready()
    batch = b.next_batch()
    assert batch.frame_ids.tolist() == [10, 11, 12, -1]
    assert batch.valid.tolist() == [True, True, True, False]
    assert b.occupancy == 0.75


def test_batcher_never_blocks_on_stragglers():
    b = RequestBatcher(batch_size=4, max_wait_rounds=0)
    b.submit([1], [0], cohort=0)
    assert b.ready()                      # emits partial batch immediately
    batch = b.next_batch()
    assert batch.valid.sum() == 1


def test_batcher_padding_fraction_matches_hand_count():
    """Regression for the ``stats["padded_slots"]`` accounting gap: the
    padding fraction must equal the pads actually emitted, hand-counted
    over a ragged queue (full, partial, and singleton batches)."""
    b = RequestBatcher(batch_size=4, max_wait_rounds=0)
    assert b.padding_fraction() == 0.0    # nothing emitted yet
    hand_pads, hand_slots = 0, 0
    for burst in ([5] * 4, [6] * 3, [7]):  # pads: 0, 1, 3
        b.submit(burst, [0] * len(burst), cohort=0)
        batch = b.next_batch()
        hand_pads += int((~batch.valid).sum())
        hand_slots += len(batch.valid)
    assert b.stats["padded_slots"] == hand_pads == 4
    assert b.padding_fraction() == hand_pads / hand_slots
    assert abs(b.padding_fraction() + b.occupancy - 1.0) < 1e-12


def test_batcher_ratio_stats_defined_before_any_batch():
    """Regression: ``padding_fraction``/``occupancy`` must not divide by
    zero before the first batch is emitted — including after ``next_batch``
    calls that found the queue empty (which advance the round counter but
    emit nothing)."""
    b = RequestBatcher(batch_size=4, max_wait_rounds=0)
    assert b.padding_fraction() == 0.0
    assert b.occupancy == 1.0
    assert b.next_batch() is None         # empty queue: no batch, no stats
    assert b.stats["batches"] == 0
    assert b.padding_fraction() == 0.0
    assert b.occupancy == 1.0


def test_elastic_plan_feasibility():
    import os
    # single-device "mesh" of shape (1,1) always divides
    import jax
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((1, 1), ("data", "model"))
    from repro.configs import ARCHS, scale_down
    from repro.models.transformer import backbone_schema

    schema = backbone_schema(scale_down(ARCHS["qwen2.5-32b"]))
    plan = plan_resize(schema, mesh, global_batch=8)
    assert plan.feasible


def test_resize_chunk_stats_pads_exhausted():
    n1, n, frames = resize_chunk_stats(
        jnp.ones(10), jnp.ones(10), jnp.full(10, 5, jnp.int32), new_shards=4
    )
    assert n1.shape[0] == 12
    assert float(frames[-1]) == 0         # padded chunks exhausted
    assert float(n[-1]) == 1


def test_resume_replay_is_bit_exact(tmp_path):
    """Kill-and-restore: state + pipeline cursor reproduce the same batch."""
    from repro.data.pipeline import DeterministicTokenPipeline, TrainBatchSpec
    from repro.train.checkpoint import CheckpointManager

    spec = TrainBatchSpec(global_batch=4, seq_len=8, vocab=97)
    pipe = DeterministicTokenPipeline(spec, seed=3)
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(4.0), "cursor": jnp.int32(5)}
    mgr.save(5, state)
    got = mgr.restore_latest(state)
    assert got is not None
    step, restored, _ = got
    b1 = pipe.batch_at(int(restored["cursor"]))
    b2 = pipe.batch_at(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
