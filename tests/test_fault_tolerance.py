"""Fault tolerance: heartbeats, stragglers, restarts, batcher, elastic."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.chunks import build_chunks
from repro.core.state import init_state
from repro.distributed.elastic import plan_resize, resize_chunk_stats
from repro.distributed.fault_tolerance import (
    HeartbeatMonitor,
    RestartPolicy,
    WorkerState,
)
from repro.serve.batcher import RequestBatcher


def test_heartbeat_transitions():
    mon = HeartbeatMonitor(suspect_after_s=10, dead_after_s=30)
    mon.register(0, now=0.0)
    mon.register(1, now=0.0)
    mon.heartbeat(0, now=30.0)
    actions = mon.sweep(now=35.0)
    assert 1 in actions["dead"]
    assert mon.workers[0].state is WorkerState.HEALTHY
    assert mon.healthy_workers == [0]


def test_dead_worker_cohort_reissued():
    mon = HeartbeatMonitor(dead_after_s=30)
    mon.register(0, now=0.0)
    mon.assign(0, cohort=42)
    actions = mon.sweep(now=100.0)
    assert actions["reissue_cohorts"] == [42]


def test_straggler_detection_uses_inflight_elapsed_time():
    """Synthetic clock: a cohort in flight far past factor × median is
    re-issued — based on ITS elapsed time, not the worker's history."""
    mon = HeartbeatMonitor(straggler_factor=3.0)
    for w in range(4):
        mon.register(w, now=0.0)
        mon.heartbeat(w, now=1.0)
        mon.record_completion(w, latency=1.0, now=1.0)
    mon.assign(3, cohort=7, now=1.0)
    # elapsed 1.0 ≤ 3 × median(1.0): still within budget
    assert mon.sweep(now=2.0)["reissue_cohorts"] == []
    # elapsed 4.0 > 3.0: over budget ⇒ re-issue exactly once
    assert mon.sweep(now=5.0)["reissue_cohorts"] == [7]
    assert mon.sweep(now=6.0)["reissue_cohorts"] == []


def test_straggler_ema_history_does_not_condemn_fresh_cohorts():
    """Regression (synthetic clock): the old rule compared the worker's
    HISTORICAL ema_latency to the median, so one slow completed cohort
    caused every subsequent cohort from that worker to be re-issued the
    moment it was assigned.  A freshly-assigned cohort must get its full
    factor × median budget regardless of the worker's past."""
    mon = HeartbeatMonitor(straggler_factor=3.0)
    for w in range(4):
        mon.register(w, now=0.0)
        mon.heartbeat(w, now=1.0)
        mon.record_completion(w, latency=1.0, now=1.0)
    # one slow COMPLETED cohort inflates worker 3's EMA way over the median
    mon.record_completion(3, latency=100.0, now=101.0)
    mon.heartbeat(3, now=101.0)
    assert mon.workers[3].ema_latency > 3.0 * 1.0
    mon.assign(3, cohort=7, now=101.0)
    # swept immediately after assignment: elapsed ≈ 0 ⇒ NOT a straggler
    # (fails on the pre-fix ema-vs-median rule, which re-issued cohort 7)
    assert mon.sweep(now=101.5)["reissue_cohorts"] == []
    assert mon.workers[3].inflight_cohort == 7
    # but left in flight past the budget it IS re-issued
    assert mon.sweep(now=120.0)["reissue_cohorts"] == [7]


def test_heartbeat_registers_unknown_worker():
    """Regression: a restarted driver process observing an old worker's
    heartbeat (or completion) must absorb it, not KeyError."""
    mon = HeartbeatMonitor()
    mon.heartbeat(5, now=10.0)             # never register()ed
    assert mon.workers[5].state is WorkerState.HEALTHY
    assert mon.workers[5].last_heartbeat == 10.0
    mon.record_completion(6, latency=2.0, now=12.0)   # also unknown
    assert mon.workers[6].completed == 1
    assert mon.workers[6].ema_latency == 2.0
    mon.assign(7, cohort=3, now=13.0)      # unknown at assign too
    assert mon.workers[7].inflight_cohort == 3
    assert mon.workers[7].inflight_since == 13.0


def test_timestampless_registration_is_not_marked_dead():
    """Regression: a worker absorbed from a timestamp-LESS completion or
    assignment used to be registered with last_heartbeat=0.0 — on a
    monotonic clock the very next sweep read that as ``now − 0.0`` of
    silence, declared the worker DEAD and re-issued its cohort, the
    opposite of absorb-don't-raise.  Liveness must stay unknown (and the
    worker untouched) until a real heartbeat arrives."""
    mon = HeartbeatMonitor(dead_after_s=120.0)
    mon.record_completion(9, latency=2.0)      # legacy caller: no clock
    mon.assign(9, cohort=11)                   # still no clock
    out = mon.sweep(now=10_000.0)
    assert 9 not in out["dead"] and 9 not in out["suspect"]
    assert 11 not in out["reissue_cohorts"]
    assert mon.workers[9].state is WorkerState.HEALTHY
    assert mon.workers[9].last_heartbeat is None
    # the first real heartbeat starts normal liveness tracking
    mon.heartbeat(9, now=10_000.0)
    out = mon.sweep(now=10_200.0)
    assert 9 in out["dead"]
    assert out["reissue_cohorts"] == [11]      # death re-issues in-flight


def test_restart_policy():
    p = RestartPolicy(max_restarts=2)
    assert p.should_restart(0) and p.should_restart(1)
    assert not p.should_restart(2)


def test_batcher_padding_and_order():
    b = RequestBatcher(batch_size=4)
    b.submit([10, 11, 12], [0, 0, 1], cohort=0)
    assert b.ready()
    batch = b.next_batch()
    assert batch.frame_ids.tolist() == [10, 11, 12, -1]
    assert batch.valid.tolist() == [True, True, True, False]
    assert b.occupancy == 0.75


def test_batcher_never_blocks_on_stragglers():
    b = RequestBatcher(batch_size=4, max_wait_rounds=0)
    b.submit([1], [0], cohort=0)
    assert b.ready()                      # emits partial batch immediately
    batch = b.next_batch()
    assert batch.valid.sum() == 1


def test_batcher_padding_fraction_matches_hand_count():
    """Regression for the ``stats["padded_slots"]`` accounting gap: the
    padding fraction must equal the pads actually emitted, hand-counted
    over a ragged queue (full, partial, and singleton batches)."""
    b = RequestBatcher(batch_size=4, max_wait_rounds=0)
    assert b.padding_fraction() == 0.0    # nothing emitted yet
    hand_pads, hand_slots = 0, 0
    for burst in ([5] * 4, [6] * 3, [7]):  # pads: 0, 1, 3
        b.submit(burst, [0] * len(burst), cohort=0)
        batch = b.next_batch()
        hand_pads += int((~batch.valid).sum())
        hand_slots += len(batch.valid)
    assert b.stats["padded_slots"] == hand_pads == 4
    assert b.padding_fraction() == hand_pads / hand_slots
    assert abs(b.padding_fraction() + b.occupancy - 1.0) < 1e-12


def test_batcher_ratio_stats_defined_before_any_batch():
    """Regression: ``padding_fraction``/``occupancy`` must not divide by
    zero before the first batch is emitted — including after ``next_batch``
    calls that found the queue empty (which advance the round counter but
    emit nothing)."""
    b = RequestBatcher(batch_size=4, max_wait_rounds=0)
    assert b.padding_fraction() == 0.0
    assert b.occupancy == 1.0
    assert b.next_batch() is None         # empty queue: no batch, no stats
    assert b.stats["batches"] == 0
    assert b.padding_fraction() == 0.0
    assert b.occupancy == 1.0


def test_elastic_plan_feasibility():
    import os
    # single-device "mesh" of shape (1,1) always divides
    import jax
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((1, 1), ("data", "model"))
    from repro.configs import ARCHS, scale_down
    from repro.models.transformer import backbone_schema

    schema = backbone_schema(scale_down(ARCHS["qwen2.5-32b"]))
    plan = plan_resize(schema, mesh, global_batch=8)
    assert plan.feasible


def test_resize_chunk_stats_pads_exhausted():
    n1, n, frames = resize_chunk_stats(
        jnp.ones(10), jnp.ones(10), jnp.full(10, 5, jnp.int32), new_shards=4
    )
    assert n1.shape[0] == 12
    assert float(frames[-1]) == 0         # padded chunks exhausted
    assert float(n[-1]) == 1


@settings(max_examples=60, deadline=None)
@given(
    m=st.integers(1, 37),
    seed=st.integers(0, 1000),
    shards=st.lists(st.integers(1, 8), min_size=1, max_size=5),
)
def test_resize_chunk_stats_strips_then_repads(m, seed, shards):
    """Property (pre-fix failure): resizing already-padded stats must not
    stack padding — after ANY shrink/grow sequence the length is always
    ``real_m`` rounded up to the CURRENT shard count, the real prefix is
    untouched, and every padded chunk keeps the ``pad_chunks`` exhausted
    fill ``n1=0, n=1, frames=0``."""
    rng = np.random.default_rng(seed)
    real_n1 = rng.integers(0, 4, size=m).astype(np.float32)
    real_frames = rng.integers(1, 30, size=m).astype(np.int32)
    real_n = np.minimum(
        rng.integers(0, 6, size=m), real_frames
    ).astype(np.float32) + real_n1
    n1, n, frames = jnp.asarray(real_n1), jnp.asarray(real_n), jnp.asarray(real_frames)
    for s in shards:
        n1, n, frames = resize_chunk_stats(n1, n, frames, new_shards=s)
        want = m + (-m) % s
        assert n1.shape == n.shape == frames.shape == (want,)
        np.testing.assert_array_equal(np.asarray(n1[:m]), real_n1)
        np.testing.assert_array_equal(np.asarray(n[:m]), real_n)
        np.testing.assert_array_equal(np.asarray(frames[:m]), real_frames)
        assert np.all(np.asarray(n1[m:]) == 0)
        assert np.all(np.asarray(n[m:]) == 1)      # n >= frames ⇒ exhausted
        assert np.all(np.asarray(frames[m:]) == 0)


def test_resize_chunk_stats_keeps_interior_dummy_lookalikes():
    """Only the TRAILING dummy run is padding; a real interior chunk that
    happens to match the fill pattern must survive resizing."""
    n1 = jnp.asarray([1.0, 0.0, 2.0, 0.0, 0.0])
    n = jnp.asarray([3.0, 1.0, 4.0, 1.0, 1.0])
    frames = jnp.asarray([9, 0, 9, 0, 0], dtype=jnp.int32)  # idx 1 is interior
    rn1, rn, rframes = resize_chunk_stats(n1, n, frames, new_shards=2)
    assert rn1.shape[0] == 4                       # 3 real + 1 pad
    np.testing.assert_array_equal(np.asarray(rframes), [9, 0, 9, 0])
    np.testing.assert_array_equal(np.asarray(rn1), [1.0, 0.0, 2.0, 0.0])


def test_resume_replay_is_bit_exact(tmp_path):
    """Kill-and-restore: state + pipeline cursor reproduce the same batch."""
    from repro.data.pipeline import DeterministicTokenPipeline, TrainBatchSpec
    from repro.train.checkpoint import CheckpointManager

    spec = TrainBatchSpec(global_batch=4, seq_len=8, vocab=97)
    pipe = DeterministicTokenPipeline(spec, seed=3)
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(4.0), "cursor": jnp.int32(5)}
    mgr.save(5, state)
    got = mgr.restore_latest(state)
    assert got is not None
    step, restored, _ = got
    b1 = pipe.batch_at(int(restored["cursor"]))
    b2 = pipe.batch_at(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))


# ---------------------------------------------------------------------------
# Elastic mesh-shrink recovery (ElasticShardedRunner, DESIGN.md §14)
# ---------------------------------------------------------------------------


def _elastic_world(seed=11):
    import jax

    from repro.sim import RepoSpec, generate
    from repro.sim.oracle import oracle_detect

    spec = RepoSpec(
        video_lengths=[4_000] * 2, num_instances=60, chunk_frames=500,
        locality=4.0, seed=seed,
    )
    repo, chunks = generate(spec)
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    return chunks, det


def _elastic_carries(chunks, q_n=2):
    import jax
    import jax.numpy as jnp

    from repro.core import init_carry_multi, init_matcher, init_state

    keys = jnp.stack([
        jax.random.fold_in(jax.random.PRNGKey(0), q) for q in range(q_n)
    ])
    return init_carry_multi(
        init_state(chunks.length), init_matcher(max_results=512), keys
    )


def test_elastic_runner_windowed_matches_single_call():
    """Resumability contract: slicing the composed driver into bounded
    ``window_limit`` calls (carry + cache fed back each slice) is
    bit-identical to one unbounded call — same carries, traces, and
    summed sharing stats."""
    from repro.core.executor import run_search_multi_sharded
    from repro.core.runtime import ElasticShardedRunner
    from repro.launch.mesh import make_data_mesh

    chunks, det = _elastic_world()
    one, one_traces, one_stats = run_search_multi_sharded(
        _elastic_carries(chunks), chunks, mesh=make_data_mesh(1),
        detector=det, result_limits=8, max_steps=120, cohorts=2,
        cache_frames=64,
    )
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    runner = ElasticShardedRunner(
        _elastic_carries(chunks), chunks, detector=det, result_limits=8,
        max_steps=120, num_shards=1, cohorts=2, cache_frames=64,
        clock=clock, sync_windows=2,
    )
    out, traces, stats = runner.run()
    assert not stats["reshard_events"]
    np.testing.assert_array_equal(np.asarray(out.step), np.asarray(one.step))
    np.testing.assert_array_equal(
        np.asarray(out.results), np.asarray(one.results))
    np.testing.assert_array_equal(
        np.asarray(out.sampler.n), np.asarray(one.sampler.n))
    np.testing.assert_array_equal(
        np.asarray(out.sampler.n1), np.asarray(one.sampler.n1))
    np.testing.assert_array_equal(np.asarray(out.key), np.asarray(one.key))
    assert traces == one_traces
    for k in ("detector_invocations", "cache_hits", "index_hits", "rounds"):
        assert stats[k] == one_stats[k], k
    np.testing.assert_array_equal(
        np.asarray(stats["final_cache"].tag),
        np.asarray(one_stats["final_cache"].tag),
    )


def test_elastic_runner_handshake_register_silence_verdict():
    """The recovery handshake on a synthetic clock: workers register at
    construction, a killed worker goes silent, the boundary sweep returns
    the dead verdict — and with no survivors the runner refuses to
    continue rather than losing the search."""
    import pytest

    from repro.core.runtime import ElasticShardedRunner
    from repro.distributed.fault_tolerance import WorkerState

    chunks, det = _elastic_world()
    t = [0.0]

    def clock():
        t[0] += 100.0
        return t[0]

    mon = HeartbeatMonitor(suspect_after_s=50.0, dead_after_s=150.0)
    runner = ElasticShardedRunner(
        _elastic_carries(chunks), chunks, detector=det, result_limits=10**9,
        max_steps=500, num_shards=1, cohorts=2, monitor=mon, clock=clock,
        sync_windows=1,
    )
    assert set(mon.workers) == {0}            # registered at construction
    assert runner.step()                      # boundary 1: heartbeat, alive
    assert mon.workers[0].state is WorkerState.HEALTHY
    runner.kill_worker(0)                     # silence begins mid-window
    assert runner.step()                      # silence 100 < 150: deferred
    assert mon.workers[0].state is not WorkerState.DEAD
    with pytest.raises(RuntimeError, match="no surviving workers"):
        runner.step()                         # silence 200 ≥ 150: verdict
    assert mon.workers[0].state is WorkerState.DEAD


def test_elastic_runner_death_during_final_window_completes():
    """A worker dying during the final window never triggers a reshard:
    the window's merged results complete the search on the spot."""
    from repro.core.runtime import ElasticShardedRunner

    chunks, det = _elastic_world()
    t = [0.0]

    def clock():
        t[0] += 1000.0                        # any silence ⇒ instant verdict
        return t[0]

    runner = ElasticShardedRunner(
        _elastic_carries(chunks), chunks, detector=det, result_limits=10**9,
        max_steps=40, num_shards=1, cohorts=2, clock=clock, sync_windows=100,
    )
    runner.kill_worker(0)                     # dies while the window runs
    out, _, stats = runner.run()              # ...which still completes
    assert not stats["reshard_events"]
    assert (np.asarray(out.step) == 40).all()
    occupied = (np.asarray(out.matcher.times_seen) > 0).sum(axis=-1)
    np.testing.assert_array_equal(occupied, np.asarray(out.results))


ELASTIC_SHRINK_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from repro.core import init_carry_multi, init_matcher, init_state
from repro.core.runtime import ElasticShardedRunner
from repro.distributed.fault_tolerance import HeartbeatMonitor
from repro.sim import RepoSpec, generate
from repro.sim.oracle import oracle_detect

spec = RepoSpec(video_lengths=[6_000] * 3, num_instances=120,
                chunk_frames=600, locality=4.0, seed=13)
repo, chunks = generate(spec)
det = lambda key, frame: oracle_detect(repo, frame, query_class=0)

def fresh():
    keys = jnp.stack([jax.random.fold_in(jax.random.PRNGKey(0), q)
                      for q in range(2)])
    return init_carry_multi(init_state(chunks.length),
                            init_matcher(max_results=2048), keys)

def run_once():
    t = [0.0]
    def clock():
        t[0] += 100.0
        return t[0]
    runner = ElasticShardedRunner(
        fresh(), chunks, detector=det, result_limits=10**9, max_steps=480,
        num_shards=8, cohorts=24, cache_frames=chunks.total_frames + 8,
        monitor=HeartbeatMonitor(suspect_after_s=50.0, dead_after_s=150.0),
        clock=clock, sync_windows=1,
    )
    results_per_slice, slices = [], 0
    while True:
        alive = runner.step()
        slices += 1
        results_per_slice.append(np.asarray(runner.carry.results).copy())
        if slices == 2:
            runner.kill_worker(7)   # dies while window 3 is in flight
        if not alive:
            break
    return runner, results_per_slice

runner, per_slice = run_once()
out, traces, stats = runner.carry, runner.traces, runner.stats

# drain-and-reshard: exactly one shrink, 8 -> 6 (largest k <= 7 surviving
# workers with cohorts=24 % k == 0), landing at the boundary where the
# silence crosses dead_after_s — window 3 ran to completion first
assert len(stats["reshard_events"]) == 1, stats["reshard_events"]
ev = stats["reshard_events"][0]
assert ev["from_shards"] == 8 and ev["to_shards"] == 6, ev
assert ev["dead"] == [7], ev
assert ev["window"] == 4, ev           # kill after window 2, verdict 2 boundaries later
assert runner.num_shards == 6

# the search FINISHED on the shrunken mesh
assert (np.asarray(out.step) == 480).all(), np.asarray(out.step)
assert stats["rounds"] == 20

# zero merged results lost: counters never regress across any boundary
# (including the reshard), and the final ring occupancy matches them
stacked = np.stack(per_slice)
assert (np.diff(stacked, axis=0) >= 0).all()
occ = (np.asarray(out.matcher.times_seen) > 0).sum(axis=-1)
np.testing.assert_array_equal(occ, np.asarray(out.results))

def multiset(carry):
    seen = np.asarray(carry.matcher.times_seen) > 0
    vid = np.asarray(carry.matcher.video)
    frm = np.asarray(carry.matcher.frame)
    return [sorted(zip(vid[q][seen[q]].tolist(), frm[q][seen[q]].tolist()))
            for q in range(seen.shape[0])]

# deterministic replay: the same death schedule reproduces the same
# result multiset, traces, and sharing stats bit-for-bit
runner2, per_slice2 = run_once()
out2 = runner2.carry
np.testing.assert_array_equal(np.asarray(out.step), np.asarray(out2.step))
np.testing.assert_array_equal(np.asarray(out.results), np.asarray(out2.results))
np.testing.assert_array_equal(np.asarray(out.sampler.n),
                              np.asarray(out2.sampler.n))
assert runner.traces == runner2.traces
assert multiset(out) == multiset(out2)
for k in ("detector_invocations", "cache_hits", "rounds"):
    assert runner.stats[k] == runner2.stats[k], k
assert runner2.stats["reshard_events"] == stats["reshard_events"]
print("ELASTIC_OK results=%s invocations=%d hits=%d" %
      (np.asarray(out.results).tolist(), stats["detector_invocations"],
       stats["cache_hits"]))
"""


@pytest.mark.slow
def test_elastic_shrink_recovery_multidevice():
    """8-way mesh, worker 7 killed mid-flight: drain at the boundary,
    reshard 8→6, finish the search on the survivors, and replay the same
    death schedule to the same result multiset (slow subprocess leg)."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", ELASTIC_SHRINK_SCRIPT],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert "ELASTIC_OK" in r.stdout, r.stdout[-3000:] + "\n" + r.stderr[-3000:]
