"""Fault tolerance: heartbeats, stragglers, restarts, batcher, elastic."""
import jax.numpy as jnp
import numpy as np

from repro.core.chunks import build_chunks
from repro.core.state import init_state
from repro.distributed.elastic import plan_resize, resize_chunk_stats
from repro.distributed.fault_tolerance import (
    HeartbeatMonitor,
    RestartPolicy,
    WorkerState,
)
from repro.serve.batcher import RequestBatcher


def test_heartbeat_transitions():
    mon = HeartbeatMonitor(suspect_after_s=10, dead_after_s=30)
    mon.register(0, now=0.0)
    mon.register(1, now=0.0)
    mon.heartbeat(0, now=30.0)
    actions = mon.sweep(now=35.0)
    assert 1 in actions["dead"]
    assert mon.workers[0].state is WorkerState.HEALTHY
    assert mon.healthy_workers == [0]


def test_dead_worker_cohort_reissued():
    mon = HeartbeatMonitor(dead_after_s=30)
    mon.register(0, now=0.0)
    mon.assign(0, cohort=42)
    actions = mon.sweep(now=100.0)
    assert actions["reissue_cohorts"] == [42]


def test_straggler_detection():
    mon = HeartbeatMonitor(straggler_factor=3.0)
    for w in range(4):
        mon.register(w, now=0.0)
        mon.heartbeat(w, now=1.0)
        mon.record_completion(w, latency=1.0)
    mon.record_completion(3, latency=100.0)   # ema jumps
    mon.assign(3, cohort=7)
    actions = mon.sweep(now=2.0)
    assert 7 in actions["reissue_cohorts"]


def test_restart_policy():
    p = RestartPolicy(max_restarts=2)
    assert p.should_restart(0) and p.should_restart(1)
    assert not p.should_restart(2)


def test_batcher_padding_and_order():
    b = RequestBatcher(batch_size=4)
    b.submit([10, 11, 12], [0, 0, 1], cohort=0)
    assert b.ready()
    batch = b.next_batch()
    assert batch.frame_ids.tolist() == [10, 11, 12, -1]
    assert batch.valid.tolist() == [True, True, True, False]
    assert b.occupancy == 0.75


def test_batcher_never_blocks_on_stragglers():
    b = RequestBatcher(batch_size=4, max_wait_rounds=0)
    b.submit([1], [0], cohort=0)
    assert b.ready()                      # emits partial batch immediately
    batch = b.next_batch()
    assert batch.valid.sum() == 1


def test_batcher_padding_fraction_matches_hand_count():
    """Regression for the ``stats["padded_slots"]`` accounting gap: the
    padding fraction must equal the pads actually emitted, hand-counted
    over a ragged queue (full, partial, and singleton batches)."""
    b = RequestBatcher(batch_size=4, max_wait_rounds=0)
    assert b.padding_fraction() == 0.0    # nothing emitted yet
    hand_pads, hand_slots = 0, 0
    for burst in ([5] * 4, [6] * 3, [7]):  # pads: 0, 1, 3
        b.submit(burst, [0] * len(burst), cohort=0)
        batch = b.next_batch()
        hand_pads += int((~batch.valid).sum())
        hand_slots += len(batch.valid)
    assert b.stats["padded_slots"] == hand_pads == 4
    assert b.padding_fraction() == hand_pads / hand_slots
    assert abs(b.padding_fraction() + b.occupancy - 1.0) < 1e-12


def test_batcher_ratio_stats_defined_before_any_batch():
    """Regression: ``padding_fraction``/``occupancy`` must not divide by
    zero before the first batch is emitted — including after ``next_batch``
    calls that found the queue empty (which advance the round counter but
    emit nothing)."""
    b = RequestBatcher(batch_size=4, max_wait_rounds=0)
    assert b.padding_fraction() == 0.0
    assert b.occupancy == 1.0
    assert b.next_batch() is None         # empty queue: no batch, no stats
    assert b.stats["batches"] == 0
    assert b.padding_fraction() == 0.0
    assert b.occupancy == 1.0


def test_elastic_plan_feasibility():
    import os
    # single-device "mesh" of shape (1,1) always divides
    import jax
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((1, 1), ("data", "model"))
    from repro.configs import ARCHS, scale_down
    from repro.models.transformer import backbone_schema

    schema = backbone_schema(scale_down(ARCHS["qwen2.5-32b"]))
    plan = plan_resize(schema, mesh, global_batch=8)
    assert plan.feasible


def test_resize_chunk_stats_pads_exhausted():
    n1, n, frames = resize_chunk_stats(
        jnp.ones(10), jnp.ones(10), jnp.full(10, 5, jnp.int32), new_shards=4
    )
    assert n1.shape[0] == 12
    assert float(frames[-1]) == 0         # padded chunks exhausted
    assert float(n[-1]) == 1


def test_resume_replay_is_bit_exact(tmp_path):
    """Kill-and-restore: state + pipeline cursor reproduce the same batch."""
    from repro.data.pipeline import DeterministicTokenPipeline, TrainBatchSpec
    from repro.train.checkpoint import CheckpointManager

    spec = TrainBatchSpec(global_batch=4, seq_len=8, vocab=97)
    pipe = DeterministicTokenPipeline(spec, seed=3)
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(4.0), "cursor": jnp.int32(5)}
    mgr.save(5, state)
    got = mgr.restore_latest(state)
    assert got is not None
    step, restored, _ = got
    b1 = pipe.batch_at(int(restored["cursor"]))
    b2 = pipe.batch_at(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
