"""Paper §3.4 + Appendix B: instances spanning multiple chunks.

Executable versions of Eqs. 11–13: with cross-chunk instances, N¹_j counts
results seen exactly once GLOBALLY whose sighting was in chunk j, and the
estimator error stays term-by-term ≤ p_i × estimate.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (
    init_carry,
    init_matcher,
    init_state,
)
from repro.core.exsample import _process_frame
from repro.sim import RepoSpec, generate
from repro.sim.oracle import oracle_detect


def _appendix_b_error(p1, q, n1):
    """Eq. 13: Σ p_i1² (1-p_i1)^(n1-1) q_i — expected estimator error."""
    return np.sum(p1**2 * (1 - p1) ** (n1 - 1) * q)


@settings(max_examples=30, deadline=None)
@given(
    p1=st.lists(st.floats(1e-4, 0.2), min_size=2, max_size=50),
    n1=st.integers(1, 200),
    qscale=st.floats(0.1, 1.0),
)
def test_appendix_b_error_bounded(p1, n1, qscale):
    """Eq. 13's error is term-by-term ≤ p_i × the N¹/n estimate (the paper's
    closing remark of Appendix B)."""
    p1 = np.asarray(p1)
    q = np.full_like(p1, qscale)      # prob of not being seen elsewhere
    err = _appendix_b_error(p1, q, n1)
    estimate = np.sum(p1 * (1 - p1) ** (n1 - 1) * q)   # E[N¹_1]/n_1
    assert err <= np.max(p1) * estimate + 1e-12


def test_cross_chunk_result_counts_once():
    """A long instance spanning two chunks raises the FIRST chunk's N¹ and,
    on re-detection in the second chunk, decrements it there (not the
    second chunk's)."""
    spec = RepoSpec(
        video_lengths=[4_000], num_instances=1, chunk_frames=2_000,
        duration_mu=20.0, duration_sigma=0.01,   # ~everywhere-visible
        num_classes=1, seed=11,
    )
    repo, chunks = generate(spec)
    assert chunks.num_chunks == 2
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    carry = init_carry(
        init_state(chunks.length),
        init_matcher(max_results=64, time_gate=10**9, feat_thresh=0.9),
        jax.random.PRNGKey(0),
    )
    c = _process_frame(carry, chunks, det, jnp.int32(0), jax.random.PRNGKey(1))
    assert float(c.sampler.n1[0]) == 1.0 and float(c.sampler.n1[1]) == 0.0
    c = _process_frame(c, chunks, det, jnp.int32(1), jax.random.PRNGKey(2))
    # second sighting happened in chunk 1 ⇒ chunk 0 (home) loses its N¹,
    # chunk 1 never gains one (§3.4 rule)
    assert float(c.sampler.n1[0]) == 0.0
    assert float(c.sampler.n1[1]) == 0.0
    assert int(c.results) == 1                    # still ONE distinct result


def test_n1_never_double_counts_on_third_sighting():
    spec = RepoSpec(
        video_lengths=[3_000], num_instances=1, chunk_frames=1_000,
        duration_mu=20.0, duration_sigma=0.01, num_classes=1, seed=12,
    )
    repo, chunks = generate(spec)
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    carry = init_carry(
        init_state(chunks.length),
        init_matcher(max_results=64, time_gate=10**9, feat_thresh=0.9),
        jax.random.PRNGKey(0),
    )
    for i, c_id in enumerate((0, 1, 2)):
        carry = _process_frame(
            carry, chunks, det, jnp.int32(c_id), jax.random.PRNGKey(i)
        )
    assert float(jnp.sum(carry.sampler.n1)) == 0.0   # seen 3× ⇒ N¹ fully retired
    assert int(carry.results) == 1
