"""Mamba-2 SSD: chunked scan vs sequential recurrence, decode parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SSMConfig
from repro.models import mamba2
from repro.models.layers import materialize


def sequential_ref(x, dt, bmat, cmat, a_log):
    """Direct h_t = a_t h_{t-1} + dt_t B_t xᵀ_t recurrence (f32)."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    hs = jnp.zeros((b, h, p, n))
    ys = []
    aa = -jnp.exp(a_log)
    for t in range(s):
        decay = jnp.exp(dt[:, t] * aa[None, :])                 # [B,H]
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt[:, t], bmat[:, t], x[:, t])
        hs = decay[..., None, None] * hs + upd
        ys.append(jnp.einsum("bn,bhpn->bhp", cmat[:, t], hs))
    return jnp.stack(ys, axis=1)                                # [B,S,H,P]


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_scan_matches_sequential(chunk):
    key = jax.random.PRNGKey(0)
    B, S, H, P, N = 2, 32, 3, 8, 16
    x = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (B, S, H)))
    bm = jax.random.normal(jax.random.fold_in(key, 2), (B, S, N)) * 0.3
    cm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, N)) * 0.3
    a_log = jax.random.normal(jax.random.fold_in(key, 4), (H,)) * 0.3
    for unroll in (False, True):
        y, _ = mamba2.ssd_scan(x, dt, bm, cm, a_log, chunk=chunk, unroll=unroll)
        ref = sequential_ref(x, dt, bm, cm, a_log)
        np.testing.assert_allclose(y, ref, rtol=2e-4, atol=2e-4)


def test_decode_matches_prefill():
    """Feeding tokens one-by-one through the decode step reproduces the
    prefill block output (state-space consistency)."""
    cfg = SSMConfig(state_dim=8, head_dim=4, expand=2, conv_width=4, chunk_len=8)
    d_model = 8
    params = materialize(
        mamba2.mamba_schema(d_model, cfg), jax.random.PRNGKey(5), jnp.float32
    )
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(6), (B, S, d_model)) * 0.5
    full = mamba2.apply_mamba(params, x, cfg)
    cache = mamba2.init_cache(B, d_model, cfg, jnp.float32)
    outs = []
    for t in range(S):
        y, cache = mamba2.apply_mamba_decode(params, x[:, t : t + 1], cache, cfg)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(step, full, rtol=5e-4, atol=5e-4)


def test_state_carry_across_scan_calls():
    key = jax.random.PRNGKey(7)
    B, S, H, P, N = 1, 16, 2, 4, 8
    x = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (B, S, H)))
    bm = jax.random.normal(jax.random.fold_in(key, 2), (B, S, N)) * 0.3
    cm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, N)) * 0.3
    a_log = jnp.zeros((H,))
    y_full, h_full = mamba2.ssd_scan(x, dt, bm, cm, a_log, chunk=8)
    y1, h1 = mamba2.ssd_scan(x[:, :8], dt[:, :8], bm[:, :8], cm[:, :8], a_log, chunk=8)
    y2, h2 = mamba2.ssd_scan(
        x[:, 8:], dt[:, 8:], bm[:, 8:], cm[:, 8:], a_log, chunk=8, h0=h1
    )
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(h2, h_full, rtol=1e-4, atol=1e-5)
