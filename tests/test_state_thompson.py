"""Sampler-state algebra + Thompson sampling behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.state import (
    SamplerState,
    apply_cross_chunk_decrement,
    apply_update,
    init_state,
    merge_states,
    point_estimate,
)
from repro.core import thompson


def _state(m=8, frames=1000):
    return init_state(jnp.full((m,), frames, jnp.int32))


@settings(max_examples=30, deadline=None)
@given(
    updates=st.lists(
        st.tuples(st.integers(0, 7), st.integers(0, 5), st.integers(0, 3)),
        min_size=1,
        max_size=30,
    ),
    seed=st.integers(0, 100),
)
def test_updates_commute(updates, seed):
    """§3.7.1: additive updates are order-independent."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(updates))
    s1 = _state()
    for c, d0, d1 in updates:
        s1 = apply_update(s1, c, d0, d1)
    s2 = _state()
    for i in perm:
        c, d0, d1 = updates[i]
        s2 = apply_update(s2, c, d0, d1)
    assert jnp.allclose(s1.n1, s2.n1)
    assert jnp.allclose(s1.n, s2.n)


def test_merge_equals_sequential():
    """Async merge (psum of deltas) == sequential application."""
    a, b = _state(), _state()
    a = apply_update(a, 1, 3, 1)
    b = apply_update(b, 2, 2, 0)
    merged = merge_states(a, b)
    seq = apply_update(apply_update(_state(), 1, 3, 1), 2, 2, 0)
    assert jnp.allclose(merged.n1, seq.n1)
    assert jnp.allclose(merged.n, seq.n)


def test_cross_chunk_decrement():
    s = apply_update(_state(), 0, 2, 0)
    s = apply_cross_chunk_decrement(s, jnp.array([0]), jnp.array([1.0]))
    assert float(s.n1[0]) == 1.0


def test_exhausted_chunks_never_chosen():
    s = _state(m=4, frames=2)
    s = dataclasses.replace(s, n=jnp.array([2.0, 2.0, 2.0, 0.0]))
    for i in range(20):
        c = thompson.choose_chunks(jax.random.PRNGKey(i), s, cohorts=4)
        assert jnp.all(c == 3)


def test_point_estimate_prefers_productive_chunk():
    s = _state(m=3)
    s = apply_update(s, 0, 5, 0)    # 5 fresh results
    s = apply_update(s, 1, 0, 0)    # nothing
    est = point_estimate(s)
    assert int(jnp.argmax(est)) == 0


def test_thompson_concentrates_but_explores():
    """A rich chunk wins most draws; an UNSAMPLED chunk retains nonzero
    selection probability through the Γ(α₀, β₀) prior (Eq. 10) — heavily
    sampled barren chunks are effectively retired."""
    s = _state(m=4)
    for _ in range(20):
        s = apply_update(s, 0, 1, 0)            # chunk 0: rich
    for c in (1, 2):
        for _ in range(20):
            s = apply_update(s, c, 0, 0)        # 1,2: barren, well-sampled
    # chunk 3: never sampled — prior Γ(0.1, 1) has a fat right tail
    picks = np.asarray(
        thompson.choose_chunks(jax.random.PRNGKey(0), s, cohorts=2000)
    )
    counts = np.bincount(picks, minlength=4)
    assert counts[0] / 2000 > 0.6
    assert counts[3] > 0                         # prior keeps exploring
    assert counts[3] > counts[1] + counts[2]     # unexplored ≻ known-barren


def test_wilson_hilferty_ordinal_agreement():
    """WH approximation agrees with exact Gamma on argmax distribution."""
    s = _state(m=6)
    s = apply_update(s, 2, 4, 0)
    s = apply_update(s, 5, 1, 0)
    exact = np.asarray(
        thompson.choose_chunks(jax.random.PRNGKey(1), s, cohorts=2000, method="exact")
    )
    wh = np.asarray(
        thompson.choose_chunks(
            jax.random.PRNGKey(2), s, cohorts=2000, method="wilson_hilferty"
        )
    )
    pe = np.bincount(exact, minlength=6) / len(exact)
    pw = np.bincount(wh, minlength=6) / len(wh)
    assert np.abs(pe - pw).max() < 0.08


def test_wh_transform_moments():
    """WH draws match Gamma mean/variance within tolerance for α ≥ 1."""
    key = jax.random.PRNGKey(0)
    alpha = jnp.float32(4.0)
    z = jax.random.normal(key, (200_000,))
    x = thompson.wilson_hilferty(alpha, z)
    assert abs(float(jnp.mean(x)) - 4.0) < 0.05
    assert abs(float(jnp.var(x)) - 4.0) < 0.2
