"""SearchPlan validation, serde round-trip, and lowering-rule tests
(DESIGN.md §10).

Every invalid plan must fail with a *typed* ``PlanError`` whose message
names the offending option; any VALID plan must survive
``from_dict(to_dict(plan)) == plan`` exactly (property-tested, runs under
the hypothesis stub when offline).
"""
import dataclasses
import warnings

import jax
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    Execution,
    PlanCompatibilityError,
    PlanError,
    PlanValueError,
    SearchPlan,
    SearchStats,
    lower,
)


# ---------------------------------------------------------------------------
# Typed validation errors with actionable messages
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "plan, err, needle",
    [
        # option values invalid on their own
        (SearchPlan(queries=0), PlanValueError, "queries"),
        (SearchPlan(max_steps=0), PlanValueError, "max_steps"),
        (SearchPlan(cohorts=0), PlanValueError, "cohorts"),
        (SearchPlan(trace_every=-1), PlanValueError, "trace_every"),
        (SearchPlan(result_limit=0), PlanValueError, "result_limit"),
        (SearchPlan(queries=2, result_limit=(5, 5, 5)), PlanValueError,
         "result_limit"),
        (SearchPlan(method="gibbs"), PlanValueError, "method"),
        (SearchPlan(execution=Execution(strategy="warp")), PlanValueError,
         "strategy"),
        (SearchPlan(execution=Execution(shards=0)), PlanValueError, "shards"),
        (SearchPlan(execution=Execution(sync_every=0)), PlanValueError,
         "sync_every"),
        (SearchPlan(execution=Execution(async_workers=-1)), PlanValueError,
         "async_workers"),
        (SearchPlan(queries=2, execution=Execution(cache=0)), PlanValueError,
         "cache"),
        (SearchPlan(queries=2, execution=Execution(cache=-7)), PlanValueError,
         "cache"),
        # individually-valid options that no lowering can combine
        (SearchPlan(execution=Execution(async_workers=2, shards=4)),
         PlanCompatibilityError, "async_workers"),
        (SearchPlan(trace_every=16, execution=Execution(async_workers=2)),
         PlanCompatibilityError, "trace"),
        (SearchPlan(execution=Execution(strategy="async")),
         PlanCompatibilityError, "async_workers"),
        (SearchPlan(execution=Execution(cache=128)),
         PlanCompatibilityError, "queries_axis"),
        (SearchPlan(queries=4, execution=Execution(strategy="scan")),
         PlanCompatibilityError, "strategy"),
        (SearchPlan(queries=4, execution=Execution(strategy="host")),
         PlanCompatibilityError, "strategy"),
        (SearchPlan(execution=Execution(strategy="scan", shards=4)),
         PlanCompatibilityError, "strategy"),
        (SearchPlan(execution=Execution(sync_every=4)),
         PlanCompatibilityError, "sync_every"),
        (SearchPlan(cohorts=3, execution=Execution(shards=2)),
         PlanCompatibilityError, "cohorts"),
        (SearchPlan(cohorts=2, method="exact",
                    execution=Execution(shards=2)),
         PlanCompatibilityError, "method"),
        (SearchPlan(cohorts=2, method="pallas",
                    execution=Execution(shards=2)),
         PlanCompatibilityError, "method"),
        (SearchPlan(method="pallas",
                    execution=Execution(async_workers=2)),
         PlanCompatibilityError, "method"),
    ],
)
def test_invalid_plans_raise_typed_errors(plan, err, needle):
    with pytest.raises(err, match=needle):
        plan.resolve()
    # every PlanError is a ValueError (legacy except-clauses keep working)
    # and carries the offending field for tooling
    with pytest.raises(ValueError):
        plan.lower()
    try:
        plan.resolve()
    except PlanError as e:
        assert e.field is not None


def test_unknown_keys_rejected():
    with pytest.raises(PlanValueError, match="max_step"):
        SearchPlan.from_dict({"max_step": 100})
    with pytest.raises(PlanValueError, match="shard"):
        SearchPlan.from_dict({"execution": {"shard": 4}})


# ---------------------------------------------------------------------------
# Lowering rules (DESIGN.md §10 table)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "plan, kind, method",
    [
        (SearchPlan(), "scan", "exact"),
        (SearchPlan(execution=Execution(strategy="host")), "host", "exact"),
        (SearchPlan(method="pallas"), "scan", "pallas"),
        (SearchPlan(cohorts=8, execution=Execution(shards=8)),
         "sharded", "wilson_hilferty"),
        (SearchPlan(execution=Execution(strategy="sharded")),
         "sharded", "wilson_hilferty"),
        (SearchPlan(queries=4), "multi", "exact"),
        (SearchPlan(execution=Execution(queries_axis=True)), "multi",
         "exact"),
        (SearchPlan(execution=Execution(queries_axis=True, cache=-1)),
         "multi", "exact"),
        (SearchPlan(queries=4, cohorts=8, execution=Execution(shards=8)),
         "multi_sharded", "wilson_hilferty"),
        (SearchPlan(execution=Execution(queries_axis=True, cache=64,
                                        strategy="sharded")),
         "multi_sharded", "wilson_hilferty"),
        (SearchPlan(execution=Execution(async_workers=2)), "async", "exact"),
        (SearchPlan(queries=4, execution=Execution(async_workers=2)),
         "async_multi", "exact"),
        (SearchPlan(execution=Execution(queries_axis=True, async_workers=1,
                                        cache=-1)),
         "async_multi", "exact"),
        (SearchPlan(queries=2, trace_every=16,
                    execution=Execution(async_workers=2)),
         "async_multi", "exact"),
    ],
)
def test_lowering_kind(plan, kind, method):
    lp = lower(plan)
    assert (lp.kind, lp.method) == (kind, method)


def test_uniform_stats_fields():
    """Every lowering reports through the SAME SearchStats container —
    the fields the async/multi paths used to scatter across ad-hoc dicts."""
    s = SearchStats()
    for field in (
        "detector_invocations", "cache_hits", "rounds", "frames_sampled",
        "merge_high_water", "merge_overflow", "merges", "reissues",
        "duplicate_drops", "results_spilled", "matcher_inserted",
        "matcher_capacity",
    ):
        assert hasattr(s, field)
    assert s.cache_hit_rate == 0.0
    assert SearchStats(cache_hits=3, detector_invocations=9).cache_hit_rate \
        == pytest.approx(0.25)
    assert SearchStats(frames_sampled=80,
                       detector_invocations=10).amortization == 8.0


# ---------------------------------------------------------------------------
# Serde round-trip property: any valid plan survives to_dict/from_dict
# ---------------------------------------------------------------------------


def _maybe_valid_plan(q, limit, per_query, max_steps, cohorts_per_shard,
                      method, trace_every, strategy, shards, queries_axis,
                      sync_every, async_workers, cache):
    ex = Execution(
        strategy=strategy, shards=shards, queries_axis=queries_axis,
        sync_every=sync_every, async_workers=async_workers, cache=cache,
    )
    rl = tuple(limit + i for i in range(q)) if per_query else limit
    return SearchPlan(
        queries=q, result_limit=rl, max_steps=max_steps,
        cohorts=cohorts_per_shard * shards, method=method,
        trace_every=trace_every, execution=ex,
    )


@settings(max_examples=80)
@given(
    q=st.integers(1, 5),
    limit=st.integers(1, 100),
    per_query=st.booleans(),
    max_steps=st.integers(1, 10_000),
    cohorts_per_shard=st.integers(1, 4),
    method=st.sampled_from(["auto", "exact", "wilson_hilferty", "pallas"]),
    trace_every=st.integers(0, 64),
    strategy=st.sampled_from(["auto", "host", "scan", "sharded", "async"]),
    shards=st.sampled_from([1, 2, 8]),
    queries_axis=st.booleans(),
    sync_every=st.integers(1, 4),
    async_workers=st.integers(0, 3),
    cache=st.sampled_from([None, -1, 1, 4096]),
)
def test_plan_roundtrips_to_dict(q, limit, per_query, max_steps,
                                 cohorts_per_shard, method, trace_every,
                                 strategy, shards, queries_axis, sync_every,
                                 async_workers, cache):
    plan = _maybe_valid_plan(
        q, limit, per_query, max_steps, cohorts_per_shard, method,
        trace_every, strategy, shards, queries_axis, sync_every,
        async_workers, cache,
    )
    try:
        kind, meth = plan.resolve()
    except PlanError:
        return  # invalid combination — only valid plans must round-trip
    d = plan.to_dict()
    # the dict is json-plain: no tuples, a nested execution dict
    assert isinstance(d["execution"], dict)
    assert not isinstance(d["result_limit"], tuple)
    back = SearchPlan.from_dict(d)
    assert back == plan
    assert back.resolve() == (kind, meth)
    # and the round-trip is a fixed point
    assert SearchPlan.from_dict(back.to_dict()) == back


def test_from_dict_accepts_json_lists():
    plan = SearchPlan.from_dict(
        {"queries": 2, "result_limit": [3, 4],
         "execution": {"queries_axis": True}}
    )
    assert plan.result_limit == (3, 4)
    assert plan == SearchPlan(
        queries=2, result_limit=(3, 4),
        execution=Execution(queries_axis=True),
    )


# ---------------------------------------------------------------------------
# Benchmark registration: declared Execution requirements drive skips
# ---------------------------------------------------------------------------


def test_bench_registry_declares_and_skips():
    import pathlib
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).parent.parent))
    try:
        from benchmarks.run import SECTIONS, should_skip
    finally:
        sys.path.pop(0)
    by_name = {s.name: s for s in SECTIONS}
    assert "plan_compose(sec10)" in by_name
    compose = by_name["plan_compose(sec10)"]
    assert compose.execution is not None and compose.execution.shards == 8
    # subprocess-forcing benches never skip; in-process mesh requirements
    # skip with a logged reason when the host is short on devices
    assert should_skip(compose, available_devices=1) is None  # self-forcing
    probe = dataclasses.replace(compose, forces_devices=False)
    reason = should_skip(probe, available_devices=1)
    assert reason is not None and "8" in reason and "1" in reason
    assert should_skip(probe, available_devices=8) is None
    for s in SECTIONS:
        if s.execution is None:
            assert should_skip(s, available_devices=1) is None
    # the async-compose section declares its worker-thread need and only
    # skips when the host cannot start threads (probed, not assumed)
    assert "async_compose(sec11)" in by_name
    async_spec = by_name["async_compose(sec11)"]
    assert async_spec.execution.async_workers == 4
    assert should_skip(async_spec, available_devices=1) is None


def test_run_reconciles_mesh_with_plan_geometry():
    """A caller-supplied mesh must provide exactly the validated shards on
    the declared axis, and a non-'data' axis cannot be auto-built."""
    from repro.core import init_carry, init_matcher, init_state
    from repro.launch.mesh import make_data_mesh
    from repro.sim import RepoSpec, generate

    _, chunks = generate(RepoSpec(
        video_lengths=[500], num_instances=10, chunk_frames=100, seed=0))
    carry = init_carry(
        init_state(chunks.length), init_matcher(max_results=32),
        jax.random.PRNGKey(0),
    )
    det = lambda key, frame: None
    plan2 = SearchPlan(cohorts=2, execution=Execution(shards=2))
    with pytest.raises(PlanError, match="shards"):
        plan2.run(carry, chunks, detector=det, mesh=make_data_mesh(1))
    with pytest.raises(PlanError, match="axis"):
        SearchPlan(execution=Execution(strategy="sharded", axis="model")) \
            .run(carry, chunks, detector=det)


def test_legacy_cli_flags_build_valid_plans():
    """The deprecated launch flags must keep translating into VALID plans
    — including --sync-every without --mesh, which the old CLI silently
    ignored (regression: the planner rejects sync_every>1 off the mesh)."""
    import argparse

    from repro.launch.search import build_plan

    base = dict(
        plan="", mesh=1, sync_every=1, queries=None, cache_frames=-1,
        driver="scan", limit=10, max_steps=100, cohorts=4,
    )
    mk = lambda **kw: argparse.Namespace(**{**base, **kw})
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        assert build_plan(mk(sync_every=4)).resolve() == ("scan", "exact")
        assert build_plan(mk(mesh=2, sync_every=4, cohorts=4)).resolve() \
            == ("sharded", "wilson_hilferty")
        assert build_plan(mk(mesh=2, cohorts=5)).execution.shards == 2
        assert build_plan(mk(queries=[0, 1])).resolve() == ("multi", "exact")
        assert build_plan(
            mk(queries=[0, 1], mesh=2, cohorts=4)
        ).resolve() == ("multi_sharded", "wilson_hilferty")
        assert build_plan(mk(driver="host")).resolve() == ("host", "exact")
    # every legacy driver-shaping combination warns
    with pytest.warns(DeprecationWarning, match="--plan"):
        build_plan(mk(sync_every=4))


def test_plan_run_rejects_mismatched_carry():
    """Carry shape must agree with the plan's query axis."""
    import jax.numpy as jnp

    from repro.core import init_carry, init_carry_multi, init_matcher, \
        init_state
    from repro.sim import RepoSpec, generate

    _, chunks = generate(RepoSpec(
        video_lengths=[500], num_instances=10, chunk_frames=100, seed=0))
    single = init_carry(
        init_state(chunks.length), init_matcher(max_results=32),
        jax.random.PRNGKey(0),
    )
    multi = init_carry_multi(
        init_state(chunks.length), init_matcher(max_results=32),
        jnp.stack([jax.random.PRNGKey(0)] * 2),
    )
    det = lambda key, frame: None
    with pytest.raises(PlanError, match="leading"):
        SearchPlan(queries=2).run(single, chunks, detector=det)
    with pytest.raises(PlanError, match="queries"):
        SearchPlan().run(multi, chunks, detector=det)
    with pytest.raises(PlanError, match="select"):
        SearchPlan().run(single, chunks, detector=det,
                         select=lambda q, d: d.valid)
