"""Async search runtime: barrier-free cohorts, commutative merges."""
import jax
import pytest

from repro.core import init_carry, init_matcher, init_state
from repro.core.runtime import AsyncSearchDriver
from repro.sim import RepoSpec, generate
from repro.sim.oracle import oracle_detect


@pytest.fixture(scope="module")
def world():
    spec = RepoSpec(
        video_lengths=[10_000] * 4, num_instances=150, chunk_frames=1_000,
        locality=4.0, seed=5,
    )
    repo, chunks = generate(spec)
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    return repo, chunks, det


def test_async_driver_finds_results(world):
    repo, chunks, det = world
    carry = init_carry(
        init_state(chunks.length), init_matcher(max_results=1024),
        jax.random.PRNGKey(0),
    )
    driver = AsyncSearchDriver(
        carry, chunks, det, cohort_size=4, num_workers=3,
        result_limit=15, max_frames=3_000,
    )
    out = driver.run()
    assert int(out.results) >= 15
    assert driver.stats["cohorts"] >= 4
    assert driver.stats["merges"] >= 4
    # counters stay consistent under concurrency
    assert int(out.step) == int(jax.numpy.sum(out.sampler.n))


def test_async_driver_merge_is_atomic_under_contention(world):
    """Regression for the snapshot/merge races: with many workers racing,
    frame counters must still exactly equal the merged sampler statistics
    and every merged result delta must be non-negative (the old code read
    ``self.carry.results`` outside the lock, double-counting results, and
    clobbered the matcher after merges)."""
    repo, chunks, det = world
    carry = init_carry(
        init_state(chunks.length), init_matcher(max_results=2048),
        jax.random.PRNGKey(3),
    )
    driver = AsyncSearchDriver(
        carry, chunks, det, cohort_size=8, num_workers=8,
        result_limit=40, max_frames=4_000,
    )
    seen_deltas = []
    orig_merge = driver._merge

    def spy_merge(res):
        seen_deltas.append(res.new_results)
        orig_merge(res)

    driver._merge = spy_merge
    out = driver.run()
    assert int(out.results) >= 40 or int(out.step) >= 4_000
    # counters merged exactly once per frame
    assert int(out.step) == int(jax.numpy.sum(out.sampler.n))
    # snapshot-based delta: never negative (old code read the live carry
    # after processing, which could go negative under contention)
    assert all(d >= 0 for d in seen_deltas), seen_deltas
    # matcher MERGE, not replacement: every merged worker's insertions
    # survive, so occupied result-memory slots equal the counted results.
    # Last-writer-wins replacement fails this whenever two workers'
    # processing windows overlapped (the final matcher then only holds the
    # last worker's view).
    occupied = int(jax.numpy.sum(out.matcher.times_seen > 0))
    assert occupied == int(out.results), (occupied, int(out.results))


def test_async_driver_drops_duplicate_completions(world):
    """Regression for the double-merge bug: ``HeartbeatMonitor`` re-issues
    a straggler's cohort, so two completions of the SAME cohort can land.
    The old ``_merge`` folded every WorkerResult in — sampler deltas,
    ``step``, ``results`` and matcher insertions all double-counted.  A
    cohort must merge at most once; the duplicate is dropped and counted."""
    repo, chunks, det = world
    carry = init_carry(
        init_state(chunks.length), init_matcher(max_results=1024),
        jax.random.PRNGKey(7),
    )
    driver = AsyncSearchDriver(
        carry, chunks, det, cohort_size=4, num_workers=1,
        result_limit=10**9, max_frames=10**9,
    )
    driver._issue_cohort()
    cohort = driver._work.get_nowait()
    first = driver._process_one(0, cohort)
    # force a re-issue (what the monitor does for a straggler) and let a
    # second worker complete the same cohort
    driver._reissue(cohort.cohort_id)
    dup = driver._work.get_nowait()
    second = driver._process_one(1, dup)
    driver._merge(first)
    driver._merge(second)
    assert driver.stats["reissues"] == 1
    assert driver.stats["duplicate_drops"] == 1
    # step equals DISTINCT frames processed, not completions merged
    assert int(driver.carry.step) == len(cohort.chunk_ids)
    assert int(driver.carry.step) == int(jax.numpy.sum(driver.carry.sampler.n))
    occupied = int(jax.numpy.sum(driver.carry.matcher.times_seen > 0))
    assert occupied == int(driver.carry.results)


def test_async_driver_merge_high_water_and_overflow_guard(world):
    """Ring-wrap guard: merges surface their insertion high-water mark, and
    a worker matcher that overflowed its ring (≥ capacity insertions since
    the snapshot) raises instead of silently aliasing the append window."""
    import dataclasses

    import jax.numpy as jnp

    from repro.core.runtime import MatcherRingOverflow, WorkerResult

    repo, chunks, det = world
    carry = init_carry(
        init_state(chunks.length), init_matcher(max_results=8),
        jax.random.PRNGKey(1),
    )
    driver = AsyncSearchDriver(
        carry, chunks, det, cohort_size=2, num_workers=1,
        result_limit=10**9, max_frames=10**9,
    )
    driver._issue_cohort()
    cohort = driver._work.get_nowait()
    res = driver._process_one(0, cohort)
    driver._merge(res)
    assert driver.stats["merge_high_water"] == int(
        res.matcher.total_inserted - res.snap_matcher.total_inserted
    )
    # fabricate an overflowed worker: total_inserted advanced past capacity
    driver._issue_cohort()
    cohort2 = driver._work.get_nowait()
    res2 = driver._process_one(0, cohort2)
    overflowed = dataclasses.replace(
        res2.matcher,
        total_inserted=res2.snap_matcher.total_inserted + jnp.int32(9),
    )
    bad = WorkerResult(
        cohort_id=res2.cohort_id, worker_id=0,
        delta_n1=res2.delta_n1, delta_n=res2.delta_n,
        new_results=res2.new_results, frames=res2.frames,
        matcher=overflowed, snap_matcher=res2.snap_matcher,
    )
    step_before = int(driver.carry.step)
    import pytest as _pytest

    with _pytest.raises(MatcherRingOverflow):
        driver._merge(bad)
    # the poisoned merge must not have been committed
    assert int(driver.carry.step) == step_before


def test_async_driver_single_worker_equivalent_semantics(world):
    """1-worker async == serialized batched search (same state algebra)."""
    repo, chunks, det = world
    carry = init_carry(
        init_state(chunks.length), init_matcher(max_results=1024),
        jax.random.PRNGKey(0),
    )
    driver = AsyncSearchDriver(
        carry, chunks, det, cohort_size=2, num_workers=1,
        result_limit=10, max_frames=2_000,
    )
    out = driver.run()
    assert int(out.results) >= 10
    assert driver.stats["reissues"] == 0
