"""Paper §3.1/§3.3 theorems as executable properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import good_turing as gt


def p_vectors(min_size=2, max_size=200):
    return st.lists(
        st.floats(1e-6, 0.2), min_size=min_size, max_size=max_size
    ).map(lambda xs: jnp.asarray(xs, jnp.float32))


@settings(max_examples=60, deadline=None)
@given(p=p_vectors(), n=st.integers(1, 500))
def test_bias_is_nonnegative_and_bounded(p, n):
    """Theorem (Bias): 0 ≤ rel.err ≤ min(max pᵢ, √N(μ+σ))   (Eqs. 2-4)."""
    b = gt.bias_bounds(p, jnp.float32(n))
    assert float(b.rel_err) >= -1e-6
    assert float(b.rel_err) <= float(b.max_p_bound) + 1e-6
    assert float(b.rel_err) <= float(b.moment_bound) + 1e-6


@settings(max_examples=40, deadline=None)
@given(p=p_vectors(), n=st.integers(1, 300))
def test_variance_bound(p, n):
    """Theorem (Variance): exact Var[N¹/n] ≤ E[N¹]/n²   (Eq. 8)."""
    exact = float(gt.exact_variance(p, jnp.float32(n)))
    bound = float(gt.variance_bound(p, jnp.float32(n)))
    assert exact <= bound + 1e-9


def test_estimator_matches_expectation_monte_carlo():
    """E[N¹(n)/n] ≈ Σπᵢ(n) and ≈ E[R(n+1)] up to the bias bound."""
    rng = np.random.default_rng(0)
    p = jnp.asarray(
        np.exp(rng.normal(-6.0, 1.5, 400)).clip(1e-6, 0.15), jnp.float32
    )
    n = 200
    keys = jax.random.split(jax.random.PRNGKey(1), 300)

    def draw(k):
        seen, _ = gt.simulate_counts(k, p, n)
        return gt.n1_from_counts(seen) / n, gt.remaining_value(p, seen)

    est, rem = jax.vmap(draw)(keys)
    mean_est = float(jnp.mean(est))
    expected = float(gt.expected_estimate(p, jnp.float32(n)))
    assert abs(mean_est - expected) / max(expected, 1e-9) < 0.1
    # Eq. 2 exactly, on the analytic expectations (MC means carry noise):
    assert expected >= float(gt.expected_new(p, jnp.float32(n)))
    # and MC agrees with the analytic E[R(n+1)] within sampling error
    assert abs(float(jnp.mean(rem)) - float(gt.expected_new(p, jnp.float32(n)))) < 0.02


def test_poisson_rate_matches_variance_regime():
    p = jnp.full((50,), 0.01, jnp.float32)
    lam = float(gt.poisson_rate(p, jnp.float32(100)))
    # Poisson ⇒ Var[N¹] ≈ λ;  bound E[N¹] = n·Σπ = n·λ/n... consistency:
    assert lam > 0
    assert lam <= 50 * 0.01 * 100  # trivially sane


def test_estimator_handles_zero_counts():
    assert float(gt.estimator(jnp.float32(0), jnp.float32(10))) == 0.0
    assert float(gt.estimator(jnp.float32(0), jnp.float32(0))) == 0.0
