"""Sharded device-resident search driver (``run_search_sharded``, DESIGN.md §8).

Three layers of coverage:

  * a 1-way mesh runs on the single tier-1 test device, so the whole
    shard_map loop (choice, delta sync, matcher fold, trace) is exercised
    in-process on every run;
  * a 2-way in-process test runs whenever the host exposes ≥2 devices —
    the CI multi-device leg sets ``--xla_force_host_platform_device_count``
    so sharded collectives are exercised on every push;
  * the subprocess suite forces 8 host devices and checks statistical
    parity with the single-device scanned driver at a fixed frame budget,
    for both per-round (`sync_every=1`) and eventually-consistent
    (`sync_every=4`) merge schedules.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    init_carry,
    init_matcher,
    init_state,
    run_search_sharded,
)
from repro.launch.mesh import make_data_mesh
from repro.sim import RepoSpec, generate
from repro.sim.oracle import oracle_detect


def _world(seed=3):
    spec = RepoSpec(
        video_lengths=[5_000] * 2, num_instances=80, chunk_frames=1_000,
        locality=4.0, seed=seed,
    )
    repo, chunks = generate(spec)
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    return chunks, det


def _consistent(out):
    """Invariants every sharded run must satisfy after the final sync."""
    assert int(out.step) == int(jnp.sum(out.sampler.n)), "n/step diverged"
    occupied = int(jnp.sum(out.matcher.times_seen > 0))
    assert occupied == int(out.results), (occupied, int(out.results))


def test_sharded_single_shard_in_process():
    chunks, det = _world()
    carry = init_carry(
        init_state(chunks.length), init_matcher(max_results=512),
        jax.random.PRNGKey(0),
    )
    out, trace = run_search_sharded(
        carry, chunks, mesh=make_data_mesh(1), detector=det,
        result_limit=10, max_steps=500, cohorts=2, sync_every=2,
    )
    assert int(out.results) >= 10
    _consistent(out)
    assert trace[-1] == (int(out.step), int(out.results))
    # padding trimmed back to the true chunk count
    assert out.sampler.num_chunks == chunks.num_chunks


def test_sharded_rejects_indivisible_cohorts():
    chunks, det = _world()
    carry = init_carry(
        init_state(chunks.length), init_matcher(max_results=64),
        jax.random.PRNGKey(0),
    )
    with pytest.raises(ValueError, match="cohorts"):
        run_search_sharded(
            carry, chunks, mesh=make_data_mesh(1), detector=det,
            result_limit=1, max_steps=8, cohorts=0, sync_every=1,
        )
    with pytest.raises(ValueError, match="sync_every"):
        run_search_sharded(
            carry, chunks, mesh=make_data_mesh(1), detector=det,
            result_limit=1, max_steps=8, cohorts=1, sync_every=0,
        )


@pytest.mark.skipif(
    len(jax.devices()) < 2, reason="needs >=2 host devices (CI multi-device leg)"
)
def test_sharded_two_way_in_process():
    chunks, det = _world()
    carry = init_carry(
        init_state(chunks.length), init_matcher(max_results=512),
        jax.random.PRNGKey(0),
    )
    out, _ = run_search_sharded(
        carry, chunks, mesh=make_data_mesh(2), detector=det,
        result_limit=15, max_steps=600, cohorts=4, sync_every=1,
    )
    assert int(out.results) >= 15
    _consistent(out)


PARITY_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro.core import (init_carry, init_matcher, init_state,
                            run_search_scan, run_search_sharded)
    from repro.launch.mesh import make_data_mesh
    from repro.sim import RepoSpec, generate
    from repro.sim.oracle import oracle_detect

    spec = RepoSpec(video_lengths=[10_000] * 4, num_instances=150,
                    chunk_frames=1_000, locality=4.0, seed=5)
    repo, chunks = generate(spec)
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    fresh = lambda: init_carry(init_state(chunks.length),
                               init_matcher(max_results=2048),
                               jax.random.PRNGKey(0))
    budget = 1024
    scan, _ = run_search_scan(fresh(), chunks, detector=det,
                              result_limit=10**9, max_steps=budget,
                              cohorts=8, method="wilson_hilferty")
    assert int(scan.step) == budget
    for shards, sync_every in ((2, 1), (8, 1), (8, 4)):
        out, trace = run_search_sharded(
            fresh(), chunks, mesh=make_data_mesh(shards), detector=det,
            result_limit=10**9, max_steps=budget, cohorts=8,
            sync_every=sync_every)
        assert int(out.step) == budget, (shards, sync_every, int(out.step))
        assert int(out.step) == int(jnp.sum(out.sampler.n))
        occ = int(jnp.sum(out.matcher.times_seen > 0))
        assert occ == int(out.results), (occ, int(out.results))
        ratio = int(out.results) / int(scan.results)
        # same frame budget => statistically matching result count within
        # the documented +-5% gate; the merge schedule only adds posterior
        # staleness (DESIGN.md Sec 8)
        assert abs(ratio - 1.0) <= 0.05, (shards, sync_every, ratio)
        assert trace[-1] == (int(out.step), int(out.results))
        print(f"parity ok shards={shards} sync={sync_every} ratio={ratio:.3f}")
    print("ALL_OK")
    """
)


@pytest.mark.slow
def test_sharded_parity_multidevice():
    env = dict(os.environ)
    # the device-count flag only affects the CPU platform — pin it, or a
    # GPU host ignores the flag and make_data_mesh(8) fails spuriously
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", PARITY_SCRIPT],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert "ALL_OK" in r.stdout, r.stdout[-3000:] + "\n" + r.stderr[-3000:]
