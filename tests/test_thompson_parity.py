"""Clamping-contract parity: kernels/thompson (ref + interpret-mode
kernel) ≡ core.thompson.draw_scores_wilson_hilferty (DESIGN.md §3).

``gamma_params`` owns the statistical clamp (α floored at α₀/2 when N¹
dips below zero through §3.4 cross-chunk decrements); the kernel's
internal ``max(α, 1e-6)`` is numeric safety that must never bind for a
live chunk.  These tests lock both halves of that contract in.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import thompson
from repro.core.state import init_state
from repro.kernels.thompson.kernel import thompson_choose
from repro.kernels.thompson.ref import thompson_ref


def _tricky_state(m=130, frames=50, seed=0):
    """State exercising every clamping branch: negative N¹ (cross-chunk
    decrements), zero stats, rich chunks, and exhausted chunks."""
    rng = np.random.default_rng(seed)
    s = init_state(jnp.full((m,), frames, jnp.int32))
    n1 = rng.integers(-3, 8, m).astype(np.float32)   # negatives ⇒ α clamp
    n = rng.integers(0, frames, m).astype(np.float32)
    n[::17] = frames                                  # some exhausted
    return dataclasses.replace(s, n1=jnp.asarray(n1), n=jnp.asarray(n))


def _sentinel_params(state):
    alpha, beta = thompson.gamma_params(state)
    return jnp.where(state.exhausted(), -1.0, alpha), beta


def test_gamma_params_clamps_negative_n1_at_half_alpha0():
    s = _tricky_state()
    alpha, _ = thompson.gamma_params(s)
    assert float(jnp.min(alpha)) == pytest.approx(s.alpha0 * 0.5)
    assert bool(jnp.all(alpha > 0))  # live α always beats the 1e-6 floor


def test_ref_matches_draw_scores_wilson_hilferty():
    s = _tricky_state()
    key = jax.random.PRNGKey(42)
    cohorts = 9
    scores = thompson.draw_scores_wilson_hilferty(key, s, cohorts=cohorts)
    expected_idx = jnp.argmax(scores, axis=-1).astype(jnp.int32)

    alpha, beta = _sentinel_params(s)
    z = jax.random.normal(key, (cohorts, alpha.shape[0]), dtype=alpha.dtype)
    idx, val = thompson_ref(alpha, beta, z)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(expected_idx))
    # scores (not just argmax) agree exactly on live chunks — the kernel's
    # 1e-6 clamp never bound
    np.testing.assert_array_equal(
        np.asarray(val),
        np.asarray(jnp.max(scores, axis=-1)),
    )


@pytest.mark.parametrize("m,bm", [(130, 64), (64, 64), (300, 128)])
def test_interpret_kernel_matches_ref_on_tricky_states(m, bm):
    s = _tricky_state(m=m, seed=m)
    alpha, beta = _sentinel_params(s)
    z = jax.random.normal(jax.random.PRNGKey(m), (4, m))
    kidx, kval = thompson_choose(alpha, beta, z, block_m=bm, interpret=True)
    ridx, rval = thompson_ref(alpha, beta, z)
    np.testing.assert_array_equal(np.asarray(kidx), np.asarray(ridx))
    np.testing.assert_allclose(np.asarray(kval), np.asarray(rval), rtol=1e-6)


@pytest.mark.parametrize("m,bm", [(130, 64), (300, 128)])
def test_interpret_batched_kernel_matches_per_query_kernel(m, bm):
    """Multi-query grid (DESIGN.md §9): one (Q·C, M-blocks) launch must be
    bit-identical per query row to Q separate ``thompson_choose`` calls."""
    from repro.kernels.thompson.kernel import thompson_choose_batched

    q_n, cohorts = 3, 4
    alphas, betas, zs = [], [], []
    for q in range(q_n):
        s = _tricky_state(m=m, seed=m + q)
        a, b = _sentinel_params(s)
        alphas.append(a)
        betas.append(b)
        zs.append(jax.random.normal(jax.random.PRNGKey(100 + q), (cohorts, m)))
    bidx, bval = thompson_choose_batched(
        jnp.stack(alphas), jnp.stack(betas), jnp.stack(zs),
        block_m=bm, interpret=True,
    )
    for q in range(q_n):
        sidx, sval = thompson_choose(
            alphas[q], betas[q], zs[q], block_m=bm, interpret=True
        )
        np.testing.assert_array_equal(np.asarray(bidx[q]), np.asarray(sidx))
        np.testing.assert_allclose(
            np.asarray(bval[q]), np.asarray(sval), rtol=1e-6
        )


def test_choose_chunks_pallas_equals_wilson_hilferty():
    """method="pallas" must be bit-identical in its chunk choices to
    method="wilson_hilferty" under the same key."""
    s = _tricky_state(m=257, seed=5)
    for k in range(4):
        key = jax.random.PRNGKey(k)
        wh = thompson.choose_chunks(key, s, cohorts=16, method="wilson_hilferty")
        pal = thompson.choose_chunks(key, s, cohorts=16, method="pallas")
        np.testing.assert_array_equal(np.asarray(wh), np.asarray(pal))


def test_pallas_never_picks_exhausted_chunks():
    s = init_state(jnp.full((8,), 4, jnp.int32))
    n = jnp.full((8,), 4.0).at[6].set(0.0)  # only chunk 6 live
    s = dataclasses.replace(s, n=n)
    for k in range(10):
        c = thompson.choose_chunks(
            jax.random.PRNGKey(k), s, cohorts=4, method="pallas"
        )
        assert bool(jnp.all(c == 6)), c
