"""MoE routing/dispatch/combine semantics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models.layers import materialize
from repro.models.moe import apply_moe, capacity, moe_flops, moe_schema


def _params(d, cfg, kind="gelu", seed=0):
    return materialize(moe_schema(d, cfg, kind), jax.random.PRNGKey(seed), jnp.float32)


def test_capacity_formula():
    cfg = MoEConfig(num_experts=4, top_k=2, d_ff=8, capacity_factor=1.0)
    assert capacity(16, cfg) == 8


def test_moe_matches_dense_reference():
    """With capacity ≥ tokens (no drops), MoE output must equal the
    explicit Σ_k p_k · FFN_{e_k}(x) reference."""
    d, cfg = 8, MoEConfig(num_experts=4, top_k=2, d_ff=16, capacity_factor=8.0)
    p = _params(d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, d))
    out, stats = apply_moe(p, x, cfg, mlp_kind="gelu")

    logits = jnp.einsum("gtd,de->gte", x, p["router"])
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, 2)
    top_p = top_p / top_p.sum(-1, keepdims=True)

    def ffn(e, xi):
        h = jax.nn.gelu(xi @ p["w_up"][e], approximate=True)
        return h @ p["w_down"][e]

    ref = jnp.zeros_like(x)
    for g in range(2):
        for t in range(6):
            for kk in range(2):
                e = int(top_e[g, t, kk])
                ref = ref.at[g, t].add(top_p[g, t, kk] * ffn(e, x[g, t]))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
    assert float(stats.dropped_fraction) == 0.0


def test_capacity_drops_tokens():
    d = 8
    cfg = MoEConfig(num_experts=2, top_k=1, d_ff=4, capacity_factor=0.25)
    p = _params(d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 16, d))
    out, stats = apply_moe(p, x, cfg, mlp_kind="gelu")
    assert float(stats.dropped_fraction) > 0.0
    assert jnp.all(jnp.isfinite(out))


def test_aux_loss_range():
    d = 8
    cfg = MoEConfig(num_experts=4, top_k=1, d_ff=4)
    p = _params(d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, d))
    _, stats = apply_moe(p, x, cfg, mlp_kind="gelu")
    # Switch aux loss is ≥ 1 (perfect balance) for softmax routers
    assert float(stats.aux_loss) >= 0.99


def test_swiglu_experts_finite():
    d = 8
    cfg = MoEConfig(num_experts=4, top_k=2, d_ff=16)
    p = _params(d, cfg, kind="swiglu")
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, d))
    out, _ = apply_moe(p, x, cfg, mlp_kind="swiglu")
    assert out.shape == x.shape and bool(jnp.all(jnp.isfinite(out)))


def test_flops_counts_active_only():
    cfg = MoEConfig(num_experts=16, top_k=4, d_ff=100)
    f = moe_flops(10, 32, cfg, "gelu")
    assert f == 2.0 * 10 * 4 * 32 * 100 * 2
