"""Per-architecture smoke tests (assignment deliverable f).

For every assigned architecture: instantiate a REDUCED config of the same
family and run one forward + one train step + one decode step on CPU,
asserting output shapes and the absence of NaNs.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, RunConfig, scale_down
from repro.models.transformer import (
    forward_decode,
    forward_lm,
    init_decode_cache,
    init_params,
)
from repro.train.train_step import build_train_step, init_train_state

RUN = RunConfig(
    param_dtype="float32", block_q=16, block_kv=16, unroll=False,
    remat=False, sequence_parallel=False, causal_block_skip=False,
)
B, S = 2, 32


def _batch(cfg, with_labels=False):
    batch = {"tokens": jnp.ones((B, S), jnp.int32)}
    n_lab = S
    if cfg.family == "vlm":
        batch = {
            "tokens": jnp.ones((B, S - cfg.num_patches), jnp.int32),
            "patches": jnp.zeros((B, cfg.num_patches, cfg.patch_dim), jnp.float32),
        }
        n_lab = S - cfg.num_patches
    if cfg.encoder_layers:
        batch["frames"] = jnp.zeros((B, 16, cfg.d_model), jnp.float32)
    if with_labels:
        batch["labels"] = jnp.ones((B, n_lab), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finite(arch):
    cfg = scale_down(ARCHS[arch])
    params = init_params(cfg, jax.random.PRNGKey(0))
    logits = forward_lm(params, _batch(cfg), cfg, RUN, mode="train")
    exp_s = S if cfg.family != "vlm" else S
    assert logits.shape == (B, exp_s, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_step(arch):
    cfg = scale_down(ARCHS[arch])
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_decode_cache(cfg, B, 64, jnp.float32)
    logits, cache2 = forward_decode(
        params, jnp.zeros((B, 1), jnp.int32), cache, cfg, RUN
    )
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache2.pos) == 1


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_one_train_step(arch):
    cfg = scale_down(ARCHS[arch])
    params = init_params(cfg, jax.random.PRNGKey(0))
    step = build_train_step(cfg, RUN)
    state = init_train_state(params, RUN)
    state, metrics = step(state, _batch(cfg, with_labels=True))
    loss = float(metrics["loss"])
    assert jnp.isfinite(loss) and loss > 0
    assert int(state.step) == 1


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "granite-moe-1b-a400m", "mamba2-370m"])
def test_loss_decreases_over_steps(arch):
    """A few steps on a fixed batch must reduce the loss (learnability)."""
    cfg = scale_down(ARCHS[arch])
    params = init_params(cfg, jax.random.PRNGKey(0))
    import dataclasses

    run = dataclasses.replace(RUN, learning_rate=1e-2)
    step = jax.jit(build_train_step(cfg, run))
    state = init_train_state(params, run)
    batch = _batch(cfg, with_labels=True)
    losses = []
    steps = 12 if cfg.family == "ssm" else 5   # SSD warms up more slowly
    for _ in range(steps):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert min(losses[1:]) < losses[0], losses


def test_microbatched_grads_match_full():
    """k=4 gradient accumulation == single-batch gradients."""
    import dataclasses

    cfg = scale_down(ARCHS["phi3-medium-14b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0, cfg.vocab),
    }
    from repro.train.train_step import microbatch_grad

    run1 = RUN
    runk = dataclasses.replace(RUN, microbatches=4)
    l1, g1 = microbatch_grad(params, batch, cfg, run1, moe_groups=1)

    stepk = build_train_step(cfg, runk)
    # reach the internal accumulation through one step with zero LR
    runk0 = dataclasses.replace(runk, learning_rate=0.0, weight_decay=0.0)
    stepk0 = build_train_step(cfg, runk0)
    state = init_train_state(params, runk0)
    _, mk = stepk0(state, batch)
    import numpy as np

    np.testing.assert_allclose(float(mk["loss"]), float(l1), rtol=1e-5)
