"""Two-process persistence smoke: the snapshot survives the process.

Phase 1 runs a search with a writable index in a SUBPROCESS (a genuinely
separate interpreter — nothing survives but the disk snapshot), prints
its result counts, and exits.  Phase 2, in this process, rebuilds the
IDENTICAL deterministic world (same RepoSpec seed), reruns the identical
plan against the snapshot, and must replay it exactly: zero fresh
detector calls on seen frames, identical result count.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp

from repro.core import init_carry_multi, init_matcher, init_state
from repro.core.plan import Execution, IndexSpec, SearchPlan
from repro.sim import RepoSpec, generate
from repro.sim.oracle import oracle_detect

SPEC = dict(
    video_lengths=[5_000] * 3, num_instances=100, chunk_frames=500,
    locality=4.0, seed=7,
)
PLAN = dict(result_limit=10, max_steps=600, cohorts=4)

PHASE1 = textwrap.dedent(
    """
    import json, sys
    import jax, jax.numpy as jnp
    from repro.core import init_carry_multi, init_matcher, init_state
    from repro.core.plan import Execution, IndexSpec, SearchPlan
    from repro.sim import RepoSpec, generate
    from repro.sim.oracle import oracle_detect

    path = sys.argv[1]
    repo, chunks = generate(RepoSpec(**{spec}))
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    carry = init_carry_multi(
        init_state(chunks.length), init_matcher(max_results=512),
        jnp.stack([jax.random.PRNGKey(0)]),
    )
    res = SearchPlan(
        **{plan},
        execution=Execution(
            queries_axis=True, cache=-1, index=IndexSpec(path=path),
        ),
    ).run(carry, chunks, detector=det)
    print("PHASE1 " + json.dumps({{
        "results": res.results[0], "steps": res.steps[0],
        "detector_invocations": res.stats.detector_invocations,
        "persisted": res.stats.persisted_detections,
    }}))
    """
).format(spec=SPEC, plan=PLAN)


def test_snapshot_survives_process_restart(tmp_path):
    path = str(tmp_path / "idx")
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", PHASE1, path],
        capture_output=True, text=True, timeout=600, env=env,
    )
    line = next(
        (l for l in r.stdout.splitlines() if l.startswith("PHASE1 ")), None
    )
    assert line is not None, r.stdout[-3000:] + "\n" + r.stderr[-3000:]
    phase1 = json.loads(line[len("PHASE1 "):])
    assert phase1["persisted"] > 0
    assert os.path.exists(os.path.join(path, "manifest.json"))

    # phase 2: fresh interpreter state in THIS process, restart from disk
    repo, chunks = generate(RepoSpec(**SPEC))
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    carry = init_carry_multi(
        init_state(chunks.length), init_matcher(max_results=512),
        jnp.stack([jax.random.PRNGKey(0)]),
    )
    res = SearchPlan(
        **PLAN,
        execution=Execution(
            queries_axis=True, cache=-1, index=IndexSpec(path=path),
        ),
    ).run(carry, chunks, detector=det)
    assert res.results[0] == phase1["results"]
    assert res.steps[0] == phase1["steps"]
    assert res.stats.detector_invocations == 0, (
        "every frame of the deterministic replay was in the snapshot")
    assert res.stats.index_hits > 0
    assert phase1["detector_invocations"] >= 5 * max(
        res.stats.detector_invocations, 1
    )
