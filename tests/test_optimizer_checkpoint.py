"""Optimizer math, 8-bit state, checkpoint round-trip, schedules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train.optimizer import (
    AdamWConfig,
    AdamWState,
    apply_adamw,
    dequantize_blockwise,
    init_adamw,
    lr_schedule,
    quantize_blockwise,
    state_bytes,
)


def test_quantize_roundtrip_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3
    q = quantize_blockwise(x, 64)
    err = jnp.max(jnp.abs(dequantize_blockwise(q) - x))
    # error ≤ scale/2 per block = max|block|/254
    assert float(err) <= float(jnp.max(jnp.abs(x))) / 127.0


def test_quantize_preserves_shape_and_zeros():
    x = jnp.zeros((7, 13))
    q = quantize_blockwise(x, 32)
    out = dequantize_blockwise(q)
    assert out.shape == (7, 13) and float(jnp.abs(out).max()) == 0.0


def test_adamw_matches_reference_math():
    cfg = AdamWConfig(
        learning_rate=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
        grad_clip=1e9, warmup_steps=0, decay_steps=10**9,
    )
    params = {"w": jnp.asarray([1.0, -2.0])}
    grads = {"w": jnp.asarray([0.5, 0.25])}
    state = init_adamw(params, cfg)
    new, state, _ = apply_adamw(params, grads, state, cfg)
    m = 0.1 * np.asarray([0.5, 0.25])
    v = 0.01 * np.asarray([0.25, 0.0625])
    upd = (m / 0.1) / (np.sqrt(v / 0.01) + 1e-8)
    np.testing.assert_allclose(new["w"], np.asarray([1.0, -2.0]) - 0.1 * upd, rtol=1e-5)


def test_adamw_8bit_tracks_fp32():
    cfgs = [
        AdamWConfig(learning_rate=0.05, quantize_state=q, warmup_steps=0,
                    decay_steps=10**9, weight_decay=0.0)
        for q in (False, True)
    ]
    params0 = {"w": jax.random.normal(jax.random.PRNGKey(0), (256,))}
    trajs = []
    for cfg in cfgs:
        params = dict(params0)
        state = init_adamw(params, cfg)
        for i in range(10):
            grads = {"w": params["w"] * 0.1 + 0.01 * (i + 1)}
            params, state, _ = apply_adamw(params, grads, state, cfg)
        trajs.append(np.asarray(params["w"]))
    rel = np.abs(trajs[0] - trajs[1]).max() / (np.abs(trajs[0]).max() + 1e-9)
    assert rel < 0.02, rel


def test_8bit_state_is_smaller():
    params = {"w": jnp.zeros((4096,))}
    s32 = init_adamw(params, AdamWConfig(quantize_state=False))
    s8 = init_adamw(params, AdamWConfig(quantize_state=True))
    assert state_bytes(s8) < state_bytes(s32) / 3


def test_lr_schedule_shape():
    cfg = AdamWConfig(learning_rate=1.0, warmup_steps=10, decay_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 100, 1000)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] <= lrs[2]
    assert abs(lrs[4] - 0.1) < 1e-6


def test_checkpoint_roundtrip_bit_exact(tmp_path):
    cfg = AdamWConfig(quantize_state=True)
    params = {"a": jax.random.normal(jax.random.PRNGKey(0), (37,)),
              "nest": {"b": jnp.arange(5, dtype=jnp.int32)}}
    opt = init_adamw(params, cfg)
    tree = {"params": params, "opt": opt, "cursor": jnp.int32(17)}
    save_checkpoint(str(tmp_path), 3, tree, extra={"note": "x"})
    restored, extra = restore_checkpoint(str(tmp_path), 3, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert extra["note"] == "x"


def test_latest_step_skips_corrupt(tmp_path):
    tree = {"a": jnp.arange(4)}
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 2, tree)
    # corrupt step 2's shard
    with open(os.path.join(str(tmp_path), "step_2", "shard_0.npz"), "ab") as f:
        f.write(b"garbage")
    assert latest_step(str(tmp_path)) == 1


def test_manager_rotation(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(3)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(str(tmp_path)) if d.startswith("step_")
    )
    assert steps == [3, 4]
    got = mgr.restore_latest(tree)
    assert got is not None and got[0] == 4
