"""CostBudget ledger hardening (service admission correctness).

Pre-fix, ``settle`` unconditionally decremented ``committed_s`` — a
double-settle of the same tenant (or a settle that was never debited)
drove ``committed_s`` negative, which MINTS headroom: ``remaining_s =
total − committed − spent`` grows past what the operator granted and
later admissions overrun the budget.  These are the regression tests
that fail against the old unconditional arithmetic.
"""
import pytest

from repro.sim.costmodel import CostBudget


def test_settle_releases_and_credits():
    b = CostBudget(total_s=100.0)
    assert b.debit(30.0)
    assert b.remaining_s == pytest.approx(70.0)
    b.settle(30.0, 10.0)   # projection was an upper bound: credit back
    assert b.committed_s == pytest.approx(0.0)
    assert b.spent_s == pytest.approx(10.0)
    assert b.remaining_s == pytest.approx(90.0)


def test_double_settle_raises_instead_of_minting_headroom():
    b = CostBudget(total_s=100.0)
    assert b.debit(30.0)
    b.settle(30.0, 10.0)
    with pytest.raises(ValueError, match="double-settle|exceeds"):
        b.settle(30.0, 10.0)
    # the ledger is unchanged by the refused call
    assert b.committed_s == pytest.approx(0.0)
    assert b.spent_s == pytest.approx(10.0)
    assert b.remaining_s <= b.total_s - b.spent_s


def test_never_debited_settle_raises():
    b = CostBudget(total_s=50.0)
    with pytest.raises(ValueError):
        b.settle(5.0, 1.0)
    assert b.remaining_s == pytest.approx(50.0)


def test_over_credit_beyond_committed_raises():
    b = CostBudget(total_s=100.0)
    assert b.debit(10.0)
    assert b.debit(10.0)
    with pytest.raises(ValueError):
        b.settle(25.0, 5.0)   # more than the 20 committed
    assert b.committed_s == pytest.approx(20.0)


def test_negative_amounts_raise():
    b = CostBudget(total_s=100.0)
    assert b.debit(10.0)
    with pytest.raises(ValueError, match="non-negative"):
        b.settle(-1.0, 0.0)
    with pytest.raises(ValueError, match="non-negative"):
        b.settle(1.0, -0.5)


def test_float_accumulation_tolerance():
    """Many tiny settle cycles must not trip the guard on float dust."""
    b = CostBudget(total_s=10.0)
    for _ in range(1000):
        assert b.debit(0.001)
    for _ in range(1000):
        b.settle(0.001, 0.0005)
    assert b.committed_s == pytest.approx(0.0, abs=1e-6)
    assert b.spent_s == pytest.approx(0.5, abs=1e-6)
