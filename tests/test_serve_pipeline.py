"""Serving consistency + data pipeline determinism + sim invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, RunConfig, scale_down
from repro.data.pipeline import (
    DeterministicTokenPipeline,
    ShuffledFramePipeline,
    TrainBatchSpec,
)
from repro.models.transformer import (
    forward_decode,
    forward_lm,
    init_decode_cache,
    init_params,
)
from repro.sim import RepoSpec, chunk_hit_rates, generate
from repro.sim.oracle import oracle_detect
from repro.sim.repository import duration_probabilities, instances_visible

RUN = RunConfig(param_dtype="float32", block_q=16, block_kv=16, unroll=False,
                remat=False, sequence_parallel=False, causal_block_skip=False)


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "gemma-7b", "granite-20b"])
def test_decode_matches_teacher_forcing(arch):
    """Autoregressive decode logits at step t == full forward logits at t."""
    cfg = scale_down(ARCHS[arch])
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full = forward_lm(params, {"tokens": tokens}, cfg, RUN, mode="prefill")
    cache = init_decode_cache(cfg, B, 16, jnp.float32)
    for t in range(S):
        logits, cache = forward_decode(params, tokens[:, t : t + 1], cache, cfg, RUN)
        np.testing.assert_allclose(
            logits, full[:, t], rtol=2e-4, atol=2e-4
        )


def test_token_pipeline_deterministic_resume():
    spec = TrainBatchSpec(global_batch=8, seq_len=16, vocab=101)
    a = DeterministicTokenPipeline(spec, seed=0).batch_at(7)
    b = DeterministicTokenPipeline(spec, seed=0).batch_at(7)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = DeterministicTokenPipeline(spec, seed=1).batch_at(7)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_token_pipeline_shards_disjoint():
    spec = TrainBatchSpec(global_batch=8, seq_len=16, vocab=101)
    a = DeterministicTokenPipeline(spec, seed=0, data_shard=0, num_shards=2).batch_at(0)
    b = DeterministicTokenPipeline(spec, seed=0, data_shard=1, num_shards=2).batch_at(0)
    assert a["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_frame_pipeline_state_roundtrip():
    p = ShuffledFramePipeline(1000, batch=16, seed=0)
    p.next_ids()
    state = p.state_dict()
    ids1 = p.next_ids()
    q = ShuffledFramePipeline(1000, batch=16, seed=0)
    q.load_state_dict(state)
    np.testing.assert_array_equal(ids1, q.next_ids())


def test_sim_repo_invariants():
    spec = RepoSpec(video_lengths=[5000, 3000], num_instances=100,
                    chunk_frames=1000, seed=2)
    repo, chunks = generate(spec)
    # instances live inside their video
    starts = np.asarray(repo.inst_start)
    ends = np.asarray(repo.inst_end)
    vids = np.asarray(repo.inst_video)
    vstart = np.asarray([0, 5000])
    vlen = np.asarray([5000, 3000])
    assert (starts >= vstart[vids]).all()
    assert (ends <= vstart[vids] + vlen[vids]).all()
    assert (ends > starts).all()
    # p_i consistent with durations
    p = np.asarray(duration_probabilities(repo, chunks))
    np.testing.assert_allclose(p, (ends - starts) / 8000.0, rtol=1e-6)


def test_oracle_matches_visibility():
    spec = RepoSpec(video_lengths=[2000], num_instances=50, chunk_frames=500, seed=3)
    repo, chunks = generate(spec)
    frame = jnp.int32(777)
    dets = oracle_detect(repo, frame, query_class=0, max_dets=64)
    vis = np.asarray(instances_visible(repo, frame) & (repo.inst_class == 0))
    got = set(int(i) for i in np.asarray(dets.inst_id) if i >= 0)
    assert got == set(np.nonzero(vis)[0].tolist())


def test_chunk_hit_rates_positive_where_instances():
    spec = RepoSpec(video_lengths=[4000], num_instances=80, chunk_frames=1000,
                    locality=5.0, seed=4)
    repo, chunks = generate(spec)
    rates = np.asarray(chunk_hit_rates(repo, chunks))
    assert rates.sum() > 0
    assert rates.min() >= 0
