"""HTTP front e2e smoke: JSON POST → live SearchService → JSON response.

Drives :func:`repro.launch.serve_http.make_server` in-process on an
ephemeral port (bind to 0, read the port back): submit a tenant, poll
GET /stats, drain, and verify the transport-error contract (400 for
malformed JSON, 404 unknown path) — protocol-level failures (unknown op,
PlanError) stay HTTP 200 with ``{"ok": false}``.
"""
import json
import threading
import urllib.error
import urllib.request

import jax
import jax.numpy as jnp
import pytest

from repro.core import init_carry_multi, init_matcher, init_state
from repro.launch.serve_http import make_server
from repro.serve.service import SearchService
from repro.sim import RepoSpec, generate
from repro.sim.oracle import class_select, oracle_detect


@pytest.fixture(scope="module")
def front():
    spec = RepoSpec(
        video_lengths=[5_000] * 3, num_instances=100, chunk_frames=500,
        locality=4.0, seed=7,
    )
    repo, chunks = generate(spec)
    det = lambda key, frame: oracle_detect(repo, frame, query_class=None)
    proto = init_carry_multi(
        init_state(chunks.length), init_matcher(max_results=64),
        jnp.stack([jax.random.PRNGKey(0)]),
    )
    service = SearchService(
        proto, chunks, det, select=class_select(repo, [0, 1]),
        cohorts=2, num_workers=1, slots_per_batch=2,
        cache_frames=chunks.total_frames,
    )
    server = make_server(service, port=0)
    port = server.server_address[1]
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    service.start(pump=False)
    yield service, f"http://127.0.0.1:{port}"
    server.shutdown()
    server.server_close()
    service.stop()
    thread.join(timeout=5.0)


def _post(base, obj, raw=None):
    req = urllib.request.Request(
        base, data=raw if raw is not None else json.dumps(obj).encode(),
        headers={"Content-Type": "application/json"}, method="POST",
    )
    with urllib.request.urlopen(req, timeout=30) as resp:
        return json.loads(resp.read().decode())


def _get(base, path=""):
    with urllib.request.urlopen(base + path, timeout=30) as resp:
        return json.loads(resp.read().decode())


def test_http_submit_drain_stats(front):
    service, base = front
    resp = _post(base, {
        "op": "submit", "tenant": "t-http", "class": 0, "seed": 1,
        "plan": {
            "result_limit": 6, "max_steps": 1500, "cohorts": 2,
            "execution": {"queries_axis": True},
        },
    })
    assert resp["ok"] is True and resp["state"] == "running", resp
    resp = _post(base, {"op": "drain"})
    assert resp["ok"] is True
    tenant = resp["tenants"]["t-http"]
    assert tenant["state"] == "finished"
    assert tenant["results"] == 6
    assert tenant["detector_invocations"] > 0
    # GET /stats serves the same view without a body
    stats = _get(base, "/stats")
    assert stats["ok"] is True
    assert stats["tenants"]["t-http"]["state"] == "finished"


def test_http_protocol_error_is_200_ok_false(front):
    _, base = front
    resp = _post(base, {"op": "frobnicate"})
    assert resp["ok"] is False and "unknown op" in resp["error"]
    # a PlanError surfaces as ok:false with its typed field
    resp = _post(base, {
        "op": "submit", "tenant": "bad",
        "plan": {"result_limit": 5, "queries": 3,
                 "execution": {"queries_axis": True}},
    })
    assert resp["ok"] is False and resp.get("field") == "queries"


def test_http_transport_errors(front):
    _, base = front
    with pytest.raises(urllib.error.HTTPError) as e:
        _post(base, None, raw=b"{not json")
    assert e.value.code == 400
    assert json.loads(e.value.read().decode())["ok"] is False
    with pytest.raises(urllib.error.HTTPError) as e:
        _post(base, None, raw=b'["a", "list"]')
    assert e.value.code == 400
    with pytest.raises(urllib.error.HTTPError) as e:
        _get(base, "/nope")
    assert e.value.code == 404
