"""Matcher semantics: d0/d1 counting, dedup, cross-chunk, ring buffer."""
import jax.numpy as jnp
import numpy as np

from repro.core.matcher import (
    init_matcher,
    match_and_update,
    merge_matcher_checked,
    pairwise_iou,
)


def _box(x, y, w=0.1, h=0.1):
    return [x, y, x + w, y + h]


def _dets(boxes, valid=None):
    boxes = jnp.asarray(boxes, jnp.float32)
    d = boxes.shape[0]
    feats = jnp.zeros((d, 8), jnp.float32)
    if valid is None:
        valid = jnp.ones((d,), bool)
    return boxes, feats, jnp.asarray(valid)


def test_pairwise_iou_known_values():
    a = jnp.asarray([_box(0, 0, 0.2, 0.2)], jnp.float32)
    b = jnp.asarray([_box(0, 0, 0.2, 0.2), _box(0.1, 0.1, 0.2, 0.2), _box(0.5, 0.5)], jnp.float32)
    iou = np.asarray(pairwise_iou(a, b))
    assert abs(iou[0, 0] - 1.0) < 1e-6
    assert abs(iou[0, 1] - (0.01 / 0.07)) < 1e-5
    assert iou[0, 2] == 0.0


def test_new_then_repeat_then_third():
    m = init_matcher(max_results=16)
    b, f, v = _dets([_box(0.3, 0.3)])
    r1 = match_and_update(m, b, f, v, jnp.int32(0), jnp.int32(100), jnp.int32(0))
    assert int(r1.d0) == 1 and int(r1.d1) == 0
    r2 = match_and_update(r1.new_state, b, f, v, jnp.int32(0), jnp.int32(110), jnp.int32(0))
    assert int(r2.d0) == 0 and int(r2.d1) == 1          # seen-once → seen-twice
    r3 = match_and_update(r2.new_state, b, f, v, jnp.int32(0), jnp.int32(120), jnp.int32(0))
    assert int(r3.d0) == 0 and int(r3.d1) == 0          # third sighting: no change


def test_time_gate_separates_instances():
    m = init_matcher(max_results=16, time_gate=50)
    b, f, v = _dets([_box(0.3, 0.3)])
    r1 = match_and_update(m, b, f, v, jnp.int32(0), jnp.int32(0), jnp.int32(0))
    r2 = match_and_update(r1.new_state, b, f, v, jnp.int32(0), jnp.int32(1000), jnp.int32(0))
    assert int(r2.d0) == 1                               # beyond gate ⇒ new result


def test_different_video_is_new():
    m = init_matcher(max_results=16)
    b, f, v = _dets([_box(0.3, 0.3)])
    r1 = match_and_update(m, b, f, v, jnp.int32(0), jnp.int32(0), jnp.int32(0))
    r2 = match_and_update(r1.new_state, b, f, v, jnp.int32(1), jnp.int32(5), jnp.int32(0))
    assert int(r2.d0) == 1


def test_cross_chunk_repeat_decrements_home(case_frames=30):
    m = init_matcher(max_results=16)
    b, f, v = _dets([_box(0.3, 0.3)])
    r1 = match_and_update(m, b, f, v, jnp.int32(0), jnp.int32(0), jnp.int32(0))
    r2 = match_and_update(
        r1.new_state, b, f, v, jnp.int32(0), jnp.int32(case_frames), jnp.int32(1)
    )
    assert int(r2.d1) == 1 and int(r2.cross_chunk) == 1
    homes = np.asarray(r2.cross_home)
    assert (homes >= 0).sum() == 1 and homes.max() == 0  # home chunk is 0


def test_invalid_slots_ignored():
    m = init_matcher(max_results=16)
    b, f, _ = _dets([_box(0.3, 0.3), _box(0.6, 0.6)])
    v = jnp.asarray([True, False])
    r = match_and_update(m, b, f, v, jnp.int32(0), jnp.int32(0), jnp.int32(0))
    assert int(r.d0) == 1


def test_multiple_new_in_one_frame():
    m = init_matcher(max_results=16)
    b, f, v = _dets([_box(0.1, 0.1), _box(0.5, 0.5), _box(0.8, 0.1)])
    r = match_and_update(m, b, f, v, jnp.int32(0), jnp.int32(0), jnp.int32(0))
    assert int(r.d0) == 3
    assert int((r.new_state.times_seen > 0).sum()) == 3


def test_ring_buffer_wraps():
    m = init_matcher(max_results=2)
    for i in range(4):
        b, f, v = _dets([_box(0.05 + 0.22 * i, 0.05)])
        r = match_and_update(
            m, b, f, v, jnp.int32(0), jnp.int32(i * 2000), jnp.int32(0)
        )
        m = r.new_state
        assert int(r.d0) == 1
    assert int((m.times_seen > 0).sum()) == 2            # capacity bound holds
    assert int(m.total_inserted) == 4    # monotone, unlike the ring cursor


def _insert_n(m, n, *, start=0):
    """n distinct single-detection frames, far beyond the time gate."""
    for i in range(start, start + n):
        b, f, v = _dets([_box(0.05, 0.05)])
        m = match_and_update(
            m, b, f, v, jnp.int32(0), jnp.int32(i * 2000), jnp.int32(0)
        ).new_state
    return m


def test_merge_surfaces_high_water_insertions():
    snap = init_matcher(max_results=8)
    src = _insert_n(snap, 3)
    dst = _insert_n(snap, 2, start=100)
    merged, stats = merge_matcher_checked(dst, src, snap)
    assert int(stats.inserted) == 3
    assert not bool(stats.overflow)
    assert int(stats.clobbered) == 0
    assert int(merged.total_inserted) == 5
    assert int((merged.times_seen > 0).sum()) == 5


def test_merge_overflow_flagged_not_silently_wrapped():
    """Ring-wrap guard (ROADMAP, test-first): a worker inserting ≥ capacity
    results between snapshot and merge wraps its ring — the cursor delta
    aliases mod capacity and the old merge silently appended only
    ``inserted % capacity`` entries.  The monotone insertion counter makes
    the overflow observable so callers can raise/flag instead."""
    cap = 4
    snap = init_matcher(max_results=cap)
    src = _insert_n(snap, cap + 2)       # 6 insertions into a 4-ring
    merged, stats = merge_matcher_checked(init_matcher(max_results=cap), src, snap)
    assert int(stats.inserted) == cap + 2
    assert bool(stats.overflow)
    # the silent-wrap symptom the flag guards against: the merge window
    # aliased to 2 entries, 4 results are unrecoverable
    assert int((merged.times_seen > 0).sum()) == 2


def test_merge_clobber_counts_live_dst_overwrites():
    cap = 4
    snap = init_matcher(max_results=cap)
    src = _insert_n(snap, 3)             # appended at dst.cursor == 3
    dst = _insert_n(snap, 3, start=100)  # dst holds 3 live entries
    _, stats = merge_matcher_checked(dst, src, snap)
    assert not bool(stats.overflow)
    # slots [3, 0, 1): wraps onto dst's live entries 0 and 1
    assert int(stats.clobbered) == 2
