"""Minimal, dependency-free stand-in for the ``hypothesis`` API surface the
test-suite uses, loaded by ``conftest.py`` ONLY when the real package is
absent (environments where ``pip install`` is unavailable — the repo's
declared test extra in ``pyproject.toml`` still names real hypothesis).

Semantics: ``@given`` re-runs the test ``max_examples`` times with
deterministic pseudo-random draws (seeded per test name), always probing
the boundary values of each strategy first.  No shrinking, no database —
just enough property coverage to keep the suite meaningful offline.
"""
from __future__ import annotations

import inspect
import random
import types
from functools import wraps

__version__ = "0.0-stub"

_DEFAULT_MAX_EXAMPLES = 100


class Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng, boundary: int | None = None):
        """boundary: 0/1 pick the low/high edge where meaningful."""
        return self._draw(rng, boundary)

    def map(self, fn):
        return Strategy(lambda rng, b=None: fn(self._draw(rng, b)))


def integers(min_value, max_value):
    def draw(rng, boundary=None):
        if boundary == 0:
            return min_value
        if boundary == 1:
            return max_value
        return rng.randint(min_value, max_value)

    return Strategy(draw)


def floats(min_value, max_value, **_kw):
    def draw(rng, boundary=None):
        if boundary == 0:
            return float(min_value)
        if boundary == 1:
            return float(max_value)
        return rng.uniform(float(min_value), float(max_value))

    return Strategy(draw)


def lists(elements, *, min_size=0, max_size=10):
    def draw(rng, boundary=None):
        size = min_size if boundary == 0 else (
            max_size if boundary == 1 else rng.randint(min_size, max_size)
        )
        return [elements.example(rng) for _ in range(size)]

    return Strategy(draw)


def tuples(*strats):
    return Strategy(lambda rng, b=None: tuple(s.example(rng, b) for s in strats))


def sampled_from(seq):
    seq = list(seq)
    return Strategy(
        lambda rng, b=None: seq[0] if b == 0 else (seq[-1] if b == 1 else rng.choice(seq))
    )


def booleans():
    return sampled_from([False, True])


def just(value):
    return Strategy(lambda rng, b=None: value)


class settings:
    """Decorator form only (the suite never uses profiles)."""

    def __init__(self, max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._stub_settings = self
        return fn


def given(**strategy_kw):
    if not strategy_kw:
        raise TypeError("stub @given supports keyword strategies only")

    def decorate(fn):
        @wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_stub_settings", None) or getattr(
                fn, "_stub_settings", None
            )
            n = cfg.max_examples if cfg else _DEFAULT_MAX_EXAMPLES
            rng = random.Random(fn.__qualname__)
            for i in range(n):
                # first two examples hit every strategy's low/high boundary
                boundary = i if i < 2 else None
                drawn = {
                    k: s.example(rng, boundary) for k, s in strategy_kw.items()
                }
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example ({i + 1}/{n}): {drawn!r}"
                    ) from e

        # hide the strategy parameters from pytest's fixture resolution:
        # only non-strategy params (fixtures) remain visible
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        params = [
            p
            for name, p in inspect.signature(fn).parameters.items()
            if name not in strategy_kw
        ]
        wrapper.__signature__ = inspect.Signature(params)
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper

    return decorate


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.floats = floats
strategies.lists = lists
strategies.tuples = tuples
strategies.sampled_from = sampled_from
strategies.booleans = booleans
strategies.just = just
strategies.SearchStrategy = Strategy
