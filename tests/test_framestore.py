"""ShardedFrameStore: explicit locality masks at stripe boundaries.

``fetch`` used to silently zero remote payloads, making a remote frame
indistinguishable from a genuinely-zero local embedding.  It now returns
``(payload, local_mask)``; these tests pin the mask semantics exactly at
the stripe boundary when ``total_frames % num_hosts != 0`` — the last
host's short stripe is where an off-by-one silently mis-attributes
ownership.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.framestore import ShardedFrameStore, SimFrameStore
from repro.sim import RepoSpec, generate


@pytest.fixture(scope="module")
def store():
    spec = RepoSpec(
        video_lengths=[50], num_instances=5, chunk_frames=10, seed=3,
    )
    repo, _ = generate(spec)
    return SimFrameStore(repo=repo, embed_dim=8)


def _sharded(store, host_id, num_hosts):
    return ShardedFrameStore(
        inner=store, host_id=host_id, num_hosts=num_hosts
    )


def test_fetch_returns_payload_and_mask(store):
    s = _sharded(store, 0, 4)          # stripe = ceil(50/4) = 13: [0, 13)
    payload, mask = s.fetch(jnp.asarray([0, 12, 13], jnp.int32))
    np.testing.assert_array_equal(np.asarray(mask), [True, True, False])
    assert payload.shape == (3, 8)
    # remote lanes are zeroed; local lanes carry the inner embedding
    np.testing.assert_array_equal(np.asarray(payload[2]), np.zeros(8))
    inner = store.fetch(jnp.asarray([0], jnp.int32))
    np.testing.assert_array_equal(
        np.asarray(payload[0]), np.asarray(inner[0]))


def test_stripe_boundary_uneven_division(store):
    """50 frames over 4 hosts: stripes [0,13) [13,26) [26,39) [39,50) —
    the LAST stripe is short (11 frames) and must clamp at total."""
    frames = jnp.arange(50, dtype=jnp.int32)
    owners = np.zeros(50, bool)
    for h in range(4):
        mask = np.asarray(_sharded(store, h, 4).local_mask(frames))
        lo, hi = h * 13, min((h + 1) * 13, 50)
        np.testing.assert_array_equal(
            mask, (np.arange(50) >= lo) & (np.arange(50) < hi),
            err_msg=f"host {h}",
        )
        assert not np.any(owners & mask), "stripes must not overlap"
        owners |= mask
    assert owners.all(), "every frame has exactly one owner"


def test_ids_past_repository_end_local_to_no_host(store):
    probe = jnp.asarray([49, 50, 62], jnp.int32)
    for h in range(4):
        mask = np.asarray(_sharded(store, h, 4).local_mask(probe))
        assert not mask[1] and not mask[2], (
            f"host {h} claimed an id past total_frames")
    # frame 49 belongs to the short last stripe only
    assert bool(_sharded(store, 3, 4).local_mask(probe)[0])


def test_owner_of_agrees_with_local_mask(store):
    frames = jnp.arange(50, dtype=jnp.int32)
    owners = np.asarray(_sharded(store, 0, 4).owner_of(frames))
    for h in range(4):
        mask = np.asarray(_sharded(store, h, 4).local_mask(frames))
        np.testing.assert_array_equal(mask, owners == h)


def test_decode_cost_zero_for_remote(store):
    s = _sharded(store, 1, 4)          # owns [13, 26)
    cost = np.asarray(s.decode_cost(jnp.asarray([0, 13, 25, 26], jnp.int32)))
    assert cost[0] == 0.0 and cost[3] == 0.0
    assert cost[1] > 0.0 and cost[2] > 0.0
