"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode.kernel import flash_decode
from repro.kernels.flash_decode.ref import decode_ref
from repro.kernels.iou_match.kernel import iou_matrix
from repro.kernels.iou_match.ref import iou_ref
from repro.kernels.ssd_scan.kernel import ssd_scan_kernel
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.thompson.kernel import thompson_choose
from repro.kernels.thompson.ref import thompson_ref

KEY = jax.random.PRNGKey(0)


def rnd(i, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.fold_in(KEY, i), shape, dtype)


# ---------------------------------------------------------------- attention
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [
    (1, 64, 2, 2, 16),     # MHA-like
    (2, 128, 4, 2, 32),    # GQA 2:1
    (1, 96, 6, 1, 16),     # MQA, non-pow2 seq (divisible by 32)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(causal, shape, dtype):
    b, s, h, kv, d = shape
    q, k, v = rnd(1, (b, s, h, d), dtype), rnd(2, (b, s, kv, d), dtype), rnd(3, (b, s, kv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=32, block_kv=32,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), rtol=tol, atol=tol
    )


# ------------------------------------------------------------------ decode
@pytest.mark.parametrize("shape", [(2, 8, 2, 64, 256), (1, 4, 4, 32, 128)])
@pytest.mark.parametrize("partial_len", [True, False])
def test_flash_decode_sweep(shape, partial_len):
    b, h, kv, d, t = shape
    q = rnd(1, (b, h, d))
    kc, vc = rnd(2, (b, t, kv, d)), rnd(3, (b, t, kv, d))
    cl = (
        jnp.asarray([t // 3 + 1] * b, jnp.int32)
        if partial_len
        else jnp.full((b,), t, jnp.int32)
    )
    out = flash_decode(q, kc, vc, cl, block_kv=t // 4, interpret=True)
    ref = decode_ref(q, kc, vc, cl)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


# -------------------------------------------------------------------- ssd
@pytest.mark.parametrize("chunk", [16, 32])
@pytest.mark.parametrize("shape", [(2, 64, 8, 16), (3, 128, 16, 32)])
def test_ssd_scan_sweep(chunk, shape):
    bh, s, p, n = shape
    x = rnd(1, (bh, s, p))
    dt = jax.nn.softplus(rnd(2, (bh, s)))
    bm, cm = rnd(3, (bh, s, n)) * 0.3, rnd(4, (bh, s, n)) * 0.3
    a = -jnp.exp(rnd(5, (bh,)) * 0.3)
    out = ssd_scan_kernel(x, dt, bm, cm, a, chunk=chunk, interpret=True)
    ref = ssd_ref(x, dt, bm, cm, a, chunk=chunk)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------- thompson
@pytest.mark.parametrize("m,c,bm", [(100, 3, 32), (1000, 8, 256), (65, 2, 64)])
def test_thompson_kernel_sweep(m, c, bm):
    alpha = jnp.abs(rnd(1, (m,))) * 2 + 0.1
    alpha = alpha.at[m // 2].set(-1.0)        # exhausted sentinel
    beta = jnp.abs(rnd(2, (m,))) * 5 + 1
    z = rnd(3, (c, m))
    idx, val = thompson_choose(alpha, beta, z, block_m=bm, interpret=True)
    ridx, rval = thompson_ref(alpha, beta, z)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_allclose(val, rval, rtol=1e-6)
    assert int((idx == m // 2).sum()) == 0    # exhausted never chosen


# -------------------------------------------------------------------- iou
@pytest.mark.parametrize("d,r", [(5, 7), (37, 211), (128, 64)])
def test_iou_kernel_sweep(d, r):
    a = jax.random.uniform(jax.random.fold_in(KEY, 10), (d, 4))
    b = jax.random.uniform(jax.random.fold_in(KEY, 11), (r, 4))
    mk = lambda x: jnp.concatenate([x[:, :2], x[:, :2] + 0.2 * x[:, 2:] + 0.01], 1)
    a, b = mk(a), mk(b)
    out = iou_matrix(a, b, block_d=16, block_r=32, interpret=True)
    np.testing.assert_allclose(out, iou_ref(a, b), rtol=1e-5, atol=1e-6)


def test_iou_self_diagonal_is_one():
    a = jnp.asarray([[0.1, 0.1, 0.3, 0.3], [0.5, 0.5, 0.6, 0.7]])
    out = iou_matrix(a, a, interpret=True)
    np.testing.assert_allclose(jnp.diag(out), jnp.ones(2), rtol=1e-6)
