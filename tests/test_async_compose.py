"""Elastic slot scheduler: async workers × Q-axis carry (DESIGN.md §11).

The acceptance bar mirrors the solo drivers': with a deterministic
detector every query's (step, results, trace, sampler statistics, key)
trajectory through :class:`AsyncMultiSearchDriver` is bit-identical to
its own ``run_search_scan`` run at ANY worker count — per-query rounds
serialize (at most one slot in flight per query), so concurrency only
overlaps DIFFERENT queries' rounds.  Property tests pin the elastic
join/retire semantics (a query admitted at round r ≡ a solo run whose
frame budget was debited the frames it missed), the at-most-once merge
discipline under forced straggler re-issue, and the ring-spill contract:
a tiny device ring never raises ``MatcherRingOverflow`` on the composed
path and never loses a result — evicted entries land in the per-query
host ``ResultLog``.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    AsyncMultiSearchDriver,
    init_carry,
    init_carry_multi,
    init_matcher,
    init_state,
    run_search_scan,
    stack_carries,
)
from repro.core.plan import Execution, SearchPlan
from repro.sim import RepoSpec, generate
from repro.sim.oracle import oracle_detect

warnings.filterwarnings("ignore", message="run_search_scan")


@pytest.fixture(scope="module")
def world():
    spec = RepoSpec(
        video_lengths=[6_000] * 3, num_instances=120, chunk_frames=600,
        locality=4.0, seed=7,
    )
    repo, chunks = generate(spec)
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    return repo, chunks, det


def _qkey(q):
    return jax.random.fold_in(jax.random.PRNGKey(0), q)


def _fresh_multi(chunks, q_n, max_results=64):
    keys = jax.vmap(_qkey)(jnp.arange(q_n))
    return init_carry_multi(
        init_state(chunks.length), init_matcher(max_results=max_results), keys
    )


def _solo(chunks, det, q, *, result_limit, max_steps, cohorts=1,
          trace_every=0, max_results=64):
    carry = init_carry(
        init_state(chunks.length), init_matcher(max_results=max_results),
        _qkey(q),
    )
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return run_search_scan(
            carry, chunks, detector=det, result_limit=result_limit,
            max_steps=max_steps, cohorts=cohorts, trace_every=trace_every,
        )


def _assert_row_equals_solo(out, trace, q, solo_out, solo_trace):
    assert int(out.step[q]) == int(solo_out.step)
    assert int(out.results[q]) == int(solo_out.results)
    assert bool(jnp.all(out.key[q] == solo_out.key))
    np.testing.assert_array_equal(out.sampler.n[q], solo_out.sampler.n)
    np.testing.assert_array_equal(out.sampler.n1[q], solo_out.sampler.n1)
    np.testing.assert_array_equal(
        out.matcher.times_seen[q], solo_out.matcher.times_seen
    )
    assert trace == solo_trace


# ---------------------------------------------------------------------------
# Bit-parity vs solo run_search_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("workers", [1, 4])
def test_composed_bit_parity_vs_solo_scan(world, workers):
    """Each query through the slot scheduler ≡ its solo scanned run —
    at ANY worker count, since per-query rounds serialize."""
    _, chunks, det = world
    q_n = 3
    driver = AsyncMultiSearchDriver(
        _fresh_multi(chunks, q_n), chunks, det,
        cohorts=2, num_workers=workers, result_limits=8,
        max_steps=1500, trace_every=25,
    )
    out = driver.run()
    for q in range(q_n):
        solo_out, solo_trace = _solo(
            chunks, det, q, result_limit=8, max_steps=1500, cohorts=2,
            trace_every=25,
        )
        _assert_row_equals_solo(out, driver.traces[q], q, solo_out,
                                solo_trace)


def test_composed_parity_through_search_plan(world):
    """The async_multi lowering (async_workers>0 × queries>1) reaches the
    same per-query fixed points through the declarative SearchPlan, with
    uniform SearchStats populated."""
    _, chunks, det = world
    q_n = 4
    plan = SearchPlan(
        queries=q_n, cohorts=2, result_limit=8, max_steps=1500,
        trace_every=25,
        execution=Execution(queries_axis=True, async_workers=2, cache=-1),
    )
    assert plan.resolve() == ("async_multi", "exact")
    res = plan.run(_fresh_multi(chunks, q_n), chunks, detector=det)
    for q in range(q_n):
        solo_out, solo_trace = _solo(
            chunks, det, q, result_limit=8, max_steps=1500, cohorts=2,
            trace_every=25,
        )
        _assert_row_equals_solo(res.carry, res.traces[q], q, solo_out,
                                solo_trace)
    assert res.stats.merges == res.stats.rounds > 0
    assert res.stats.frames_sampled == int(np.asarray(res.carry.step).sum())
    assert res.stats.results_spilled == 0
    # the shared cache + per-batch dedup amortize detector invocations:
    # never more fresh calls than frames sampled
    assert res.stats.detector_invocations <= res.stats.frames_sampled


# ---------------------------------------------------------------------------
# Synchronous pump harness (no worker threads — deterministic scheduling)
# ---------------------------------------------------------------------------


def _drain(driver):
    items = []
    while True:
        try:
            item = driver._work.get_nowait()
        except Exception:
            break
        if item is not None:
            items.append(item)
    return items


def _pump_round(driver):
    """Issue every ready slot and merge it synchronously; returns the
    number of batches processed."""
    driver._issue_ready()
    batches = _drain(driver)
    for batch in batches:
        driver._merge(driver._process_batch(0, batch))
    return len(batches)


def _pump_to_completion(driver, max_pumps=10_000):
    for _ in range(max_pumps):
        if not _pump_round(driver) and not driver._inflight:
            if not any(r.active for r in driver.rows):
                return
    raise AssertionError("driver did not converge")


# ---------------------------------------------------------------------------
# Elastic join/retire property
# ---------------------------------------------------------------------------


@settings(max_examples=5, deadline=None)
@given(r=st.integers(1, 4))
def test_admitted_query_equals_reduced_budget_solo(world, r):
    """A query admitted after r pool rounds behaves exactly like one
    present from round 0 with its frame budget reduced by the frames it
    missed — i.e. a solo run at ``max_steps − cohorts × r``."""
    _, chunks, det = world
    cohorts = 2
    driver = AsyncMultiSearchDriver(
        _fresh_multi(chunks, 2), chunks, det,
        cohorts=cohorts, num_workers=1, result_limits=50,
        max_steps=200, slots_per_batch=2,
    )
    for _ in range(r):
        assert _pump_round(driver) == 1
    assert driver.pool_rounds() == r
    row_idx = driver.admit(_qkey(9), result_limit=8)
    budget = driver.rows[row_idx].budget
    assert budget == 200 - cohorts * r
    _pump_to_completion(driver)
    out = stack_carries([row.carry for row in driver.rows])
    solo_out, _ = _solo(chunks, det, 9, result_limit=8, max_steps=budget,
                        cohorts=cohorts)
    assert int(out.step[row_idx]) == int(solo_out.step)
    assert int(out.results[row_idx]) == int(solo_out.results)
    assert bool(jnp.all(out.key[row_idx] == solo_out.key))
    np.testing.assert_array_equal(out.sampler.n[row_idx], solo_out.sampler.n)
    np.testing.assert_array_equal(out.sampler.n1[row_idx],
                                  solo_out.sampler.n1)


def test_retired_rows_frozen_and_masked(world):
    """A finished query retires: its row stops issuing and its carry no
    longer changes while the rest of the pool keeps running."""
    _, chunks, det = world
    driver = AsyncMultiSearchDriver(
        _fresh_multi(chunks, 2), chunks, det,
        cohorts=1, num_workers=1,
        result_limits=[1, 30],       # q0 finishes almost immediately
        max_steps=400, slots_per_batch=1,
    )
    while driver.rows[0].active:
        assert _pump_round(driver)
    frozen = driver.rows[0].carry
    for _ in range(5):
        _pump_round(driver)
    assert int(driver.rows[0].carry.step) == int(frozen.step)
    assert bool(jnp.all(driver.rows[0].carry.key == frozen.key))
    # retire closed the trace with the unconditional final checkpoint
    assert driver.rows[0].trace[-1] == (
        int(frozen.step), int(frozen.results)
    )
    _pump_to_completion(driver)
    assert not any(row.active for row in driver.rows)


# ---------------------------------------------------------------------------
# Straggler re-issue: at-most-once merge
# ---------------------------------------------------------------------------


def test_forced_reissue_merges_at_most_once(world):
    """A re-issued slot batch reprocesses the identical work item; the
    second completion is dropped by the pending set and the committed
    state equals a single merge."""
    _, chunks, det = world
    driver = AsyncMultiSearchDriver(
        _fresh_multi(chunks, 2), chunks, det,
        cohorts=1, num_workers=1, result_limits=20,
        max_steps=300, slots_per_batch=2,
    )
    driver._issue_ready()
    (batch,) = _drain(driver)
    res_first = driver._process_batch(0, batch)
    driver._reissue(batch.batch_id)
    (dup,) = _drain(driver)
    assert dup.batch_id == batch.batch_id and dup.issue_count == 1
    res_dup = driver._process_batch(1, dup)
    driver._merge(res_first)
    snapshot = [jax.tree.map(np.asarray, row.carry) for row in driver.rows]
    merges_after_first = driver.stats["merges"]
    driver._merge(res_dup)
    assert driver.stats["duplicate_drops"] == 1
    assert driver.stats["reissues"] == 1
    assert driver.stats["merges"] == merges_after_first
    for row, snap in zip(driver.rows, snapshot):
        assert int(row.carry.step) == int(snap.step)
        np.testing.assert_array_equal(
            np.asarray(row.carry.sampler.n), snap.sampler.n
        )
    _pump_to_completion(driver)


# ---------------------------------------------------------------------------
# Ring-spill contract: overflow-free, zero result loss
# ---------------------------------------------------------------------------


def test_tiny_ring_spills_without_loss(world):
    """With a ring far smaller than the result count the composed path
    never raises MatcherRingOverflow and never loses a result: every
    distinct insertion is live on-device or in the host ResultLog."""
    repo, chunks, _ = world
    det = lambda key, frame: oracle_detect(
        repo, frame, query_class=0, max_dets=4
    )
    q_n = 2
    driver = AsyncMultiSearchDriver(
        _fresh_multi(chunks, q_n, max_results=8), chunks, det,
        cohorts=1, num_workers=2, result_limits=40, max_steps=3000,
    )
    out = driver.run()    # must not raise
    assert driver.stats["spilled"] > 0
    total_logged = 0
    for q in range(q_n):
        live = int(np.sum(np.asarray(out.matcher.times_seen[q]) > 0))
        logged = len(driver.logs[q])
        assert int(out.results[q]) == live + logged
        assert int(out.matcher.total_inserted[q]) == int(out.results[q])
        total_logged += logged
    assert driver.stats["spilled"] == total_logged
    # the log carries real result payloads, not placeholders
    arrs = driver.logs[0].as_arrays()
    assert arrs["frame"].shape[0] == len(driver.logs[0])
    assert np.all(arrs["times_seen"] >= 1)


def test_overflow_impossible_by_construction(world):
    """Configurations whose one-round insertion bound reaches the ring
    capacity are rejected up front — the only way the composed path
    could wrap a source ring inside a merge window."""
    repo, chunks, _ = world
    det = lambda key, frame: oracle_detect(
        repo, frame, query_class=0, max_dets=8
    )
    with pytest.raises(ValueError, match="capacity"):
        AsyncMultiSearchDriver(
            _fresh_multi(chunks, 2, max_results=8), chunks, det,
            cohorts=1, num_workers=1, result_limits=4, max_steps=100,
        )


def test_stats_keys_exist_at_construction(world):
    """LoweredPlan.run() packages SearchStats straight from the stats
    dict — every counter must exist from construction, not first merge."""
    _, chunks, det = world
    driver = AsyncMultiSearchDriver(
        _fresh_multi(chunks, 2), chunks, det, num_workers=1,
    )
    assert driver.stats == {
        "slots": 0, "merges": 0, "reissues": 0, "duplicate_drops": 0,
        "merge_high_water": 0, "rounds": 0, "spilled": 0,
        "detector_invocations": 0, "cache_hits": 0, "index_hits": 0,
        "lanes_issued": 0, "lanes_padded": 0,
    }
