"""Persistent cross-query repository index (DESIGN.md §13).

Four layers, acceptance-ordered:

* **DetectionCache aliasing** — hypothesis properties for the
  direct-mapped device tier at SMALL capacities, where ``frame %
  capacity`` collisions actually happen: an eviction overwrites the tag
  (stale frame must MISS, not phantom-hit), within-batch collisions are
  first-write-wins, and sentinel ids (−1) never hit nor insert.
* **RepositoryIndex tiers** — host-tier publish/lookup with
  ``detector_version`` isolation, disk snapshot round-trip (manifest
  written last), read-only discipline, deterministic ``warm()`` fill.
* **ChunkPriors** — ``prior_weight == 0`` returns the INPUT state object
  (cold path bit-identical by construction), injection touches ``n1``
  ONLY, geometry mismatches refuse to warm.
* **End-to-end contracts** — a COLD index with ``prior_weight = 0`` is
  bit-identical to no index at all; a WARM index replays detections
  exactly (identical results, ~0 fresh detector calls, index_hits > 0);
  a second service constructed over a warm shared index shows the saving
  in per-tenant attributed detector economics.
"""
import dataclasses

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import init_carry_multi, init_matcher, init_state
from repro.core.plan import Execution, IndexSpec, PlanError, SearchPlan
from repro.index import ChunkPriors, RepositoryIndex
from repro.serve.batcher import (
    DetectionCache,
    cache_insert,
    cache_lookup,
    init_detection_cache,
)
from repro.sim import RepoSpec, generate
from repro.sim.oracle import oracle_detect


@pytest.fixture(scope="module")
def world():
    spec = RepoSpec(
        video_lengths=[5_000] * 3, num_instances=100, chunk_frames=500,
        locality=4.0, seed=7,
    )
    repo, chunks = generate(spec)
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    return repo, chunks, det


def _fresh_multi(chunks, q_n=1, max_results=512):
    keys = jnp.stack([
        jax.random.fold_in(jax.random.PRNGKey(0), q) for q in range(q_n)
    ])
    return init_carry_multi(
        init_state(chunks.length), init_matcher(max_results=max_results),
        keys,
    )


def _plan(index=None, limit=10, max_steps=600, cohorts=4):
    return SearchPlan(
        result_limit=limit, max_steps=max_steps, cohorts=cohorts,
        execution=Execution(queries_axis=True, cache=-1, index=index),
    )


def _same_carry(a, b):
    np.testing.assert_array_equal(np.asarray(a.step), np.asarray(b.step))
    np.testing.assert_array_equal(
        np.asarray(a.results), np.asarray(b.results))
    for field in ("n", "n1"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a.sampler, field)),
            np.asarray(getattr(b.sampler, field)),
        )
    np.testing.assert_array_equal(np.asarray(a.key), np.asarray(b.key))


# ---------------------------------------------------------------------------
# DetectionCache direct-mapped aliasing at small capacities (satellite 4)
# ---------------------------------------------------------------------------


def _toy_cache(capacity):
    """Cache over a scalar-leaf 'detector' whose output for frame f is f
    as f32 — collisions are detectable by value."""
    struct = jax.eval_shape(lambda f: jnp.float32(0.0), 0)
    return init_detection_cache(struct, capacity)


def _ref_model(capacity, batches):
    """Reference direct-mapped semantics: per batch, the FIRST valid
    occupant of each slot wins; later batches overwrite the tag."""
    tag = {}
    for frames, mask in batches:
        taken = set()
        for f, m in zip(frames, mask):
            slot = f % capacity
            if not m or f < 0 or slot in taken:
                continue
            taken.add(slot)
            tag[slot] = f
    return tag


@hypothesis.given(
    capacity=st.integers(min_value=1, max_value=6),
    batches=st.lists(
        st.lists(
            st.tuples(
                st.integers(min_value=-1, max_value=23), st.booleans()
            ),
            min_size=1, max_size=6,
        ),
        min_size=1, max_size=4,
    ),
)
@hypothesis.settings(deadline=None, max_examples=60)
def test_cache_alias_property(capacity, batches):
    """After ANY insert sequence, lookup(f) hits iff f is the current
    occupant of its slot in the reference model — evicted frames MISS
    (stale-tag correctness) and hits gather the occupant's own value."""
    cache = _toy_cache(capacity)
    ref_batches = []
    for batch in batches:
        frames = jnp.asarray([f for f, _ in batch], jnp.int32)
        mask = jnp.asarray([m for _, m in batch])
        cache = cache_insert(
            cache, frames, frames.astype(jnp.float32), mask
        )
        ref_batches.append(([f for f, _ in batch], [m for _, m in batch]))
    ref = _ref_model(capacity, ref_batches)
    probes = sorted({f for fs, _ in ref_batches for f in fs} | {-1})
    hit, vals = cache_lookup(cache, jnp.asarray(probes, jnp.int32))
    for i, f in enumerate(probes):
        expected = f >= 0 and ref.get(f % capacity) == f
        assert bool(hit[i]) == expected, (f, capacity, ref)
        if expected:
            assert float(vals[i]) == float(f)


def test_cache_eviction_overwrites_tag_stale_miss():
    cache = _toy_cache(4)
    f1, f2 = 3, 7          # same slot: 3 % 4 == 7 % 4
    ins = lambda c, f: cache_insert(
        c, jnp.asarray([f], jnp.int32), jnp.asarray([float(f)], jnp.float32),
        jnp.asarray([True]),
    )
    cache = ins(cache, f1)
    cache = ins(cache, f2)   # later batch overwrites: eviction
    hit, vals = cache_lookup(cache, jnp.asarray([f1, f2], jnp.int32))
    assert not bool(hit[0]), "evicted frame must go stale, not phantom-hit"
    assert bool(hit[1]) and float(vals[1]) == 7.0


def test_cache_within_batch_first_write_wins():
    cache = _toy_cache(4)
    frames = jnp.asarray([3, 7], jnp.int32)   # colliding in ONE batch
    cache = cache_insert(
        cache, frames, frames.astype(jnp.float32), jnp.asarray([True, True])
    )
    hit, vals = cache_lookup(cache, frames)
    assert bool(hit[0]) and float(vals[0]) == 3.0
    assert not bool(hit[1]), "second colliding write must lose, not race"


def test_shard_cache_layout_roundtrip_and_divisibility():
    from repro.serve.batcher import shard_cache_layout, unshard_cache_layout

    cache = _toy_cache(12)
    frames = jnp.asarray([0, 5, 7, 11, 17], jnp.int32)
    cache = cache_insert(
        cache, frames, frames.astype(jnp.float32), jnp.ones(5, bool)
    )
    for s in (1, 2, 3, 4, 6):
        back = unshard_cache_layout(shard_cache_layout(cache, s), s)
        np.testing.assert_array_equal(
            np.asarray(back.tag), np.asarray(cache.tag))
        np.testing.assert_array_equal(
            np.asarray(back.store), np.asarray(cache.store))
    with pytest.raises(ValueError, match="multiple"):
        shard_cache_layout(cache, 5)


@hypothesis.given(
    capacity_l=st.integers(min_value=1, max_value=4),
    num_shards=st.sampled_from([1, 2, 3, 4]),
    batches=st.lists(
        st.lists(
            st.tuples(
                st.integers(min_value=-1, max_value=40), st.booleans()
            ),
            min_size=1, max_size=6,
        ),
        min_size=1, max_size=3,
    ),
)
@hypothesis.settings(deadline=None, max_examples=60)
def test_sharded_cache_bit_identical_to_direct_mapped(
    capacity_l, num_shards, batches
):
    """The §14 contract: hash-sharding is a pure re-placement.  Running
    every insert batch through the per-shard halves (each shard filters
    the batch to its homed frames) and re-assembling must reproduce the
    direct-mapped cache bit for bit, and the OR of per-shard lookups must
    equal the direct-mapped lookup — hits, values, evictions, and
    within-batch collision winners included."""
    from repro.serve.batcher import (
        shard_cache_layout,
        sharded_cache_insert,
        sharded_cache_lookup,
        unshard_cache_layout,
    )

    capacity = capacity_l * num_shards
    direct = _toy_cache(capacity)
    locals_ = [
        jax.tree.map(
            lambda x: x[s * capacity_l:(s + 1) * capacity_l],
            shard_cache_layout(_toy_cache(capacity), num_shards),
        )
        for s in range(num_shards)
    ]
    for batch in batches:
        frames = jnp.asarray([f for f, _ in batch], jnp.int32)
        mask = jnp.asarray([m for _, m in batch])
        vals = frames.astype(jnp.float32)
        direct = cache_insert(direct, frames, vals, mask)
        locals_ = [
            sharded_cache_insert(c, frames, vals, mask, s, num_shards)
            for s, c in enumerate(locals_)
        ]
    assembled = unshard_cache_layout(
        jax.tree.map(lambda *xs: jnp.concatenate(xs), *locals_), num_shards
    )
    np.testing.assert_array_equal(
        np.asarray(assembled.tag), np.asarray(direct.tag))
    np.testing.assert_array_equal(
        np.asarray(assembled.store), np.asarray(direct.store))
    probes = jnp.asarray(
        sorted({f for b in batches for f, _ in b} | {-1}), jnp.int32
    )
    d_hit, d_vals = cache_lookup(direct, probes)
    s_hits, s_vals = zip(*[
        sharded_cache_lookup(c, probes, s, num_shards)
        for s, c in enumerate(locals_)
    ])
    or_hit = np.logical_or.reduce([np.asarray(h) for h in s_hits])
    np.testing.assert_array_equal(or_hit, np.asarray(d_hit))
    for i in range(len(probes)):
        if bool(d_hit[i]):
            s = int(probes[i]) % num_shards
            assert float(s_vals[s][i]) == float(d_vals[i])


def test_cache_sentinel_never_hits_nor_inserts():
    cache = _toy_cache(4)
    # a masked-True sentinel must still not insert: it would tag slot
    # capacity-1 with -1 and poison later lookups there
    cache = cache_insert(
        cache, jnp.asarray([-1], jnp.int32),
        jnp.asarray([99.0], jnp.float32), jnp.asarray([True]),
    )
    np.testing.assert_array_equal(np.asarray(cache.tag), [-1, -1, -1, -1])
    hit, _ = cache_lookup(cache, jnp.asarray([-1], jnp.int32))
    assert not bool(hit[0])
    # and a real frame in the aliasing slot is unaffected
    cache = cache_insert(
        cache, jnp.asarray([3], jnp.int32),
        jnp.asarray([3.0], jnp.float32), jnp.asarray([True]),
    )
    hit, vals = cache_lookup(cache, jnp.asarray([3, -1], jnp.int32))
    assert bool(hit[0]) and float(vals[0]) == 3.0
    assert not bool(hit[1])


# ---------------------------------------------------------------------------
# RepositoryIndex: host tier, versions, snapshot, warm()
# ---------------------------------------------------------------------------


def _toy_struct():
    return jax.eval_shape(lambda f: jnp.float32(0.0), 0)


def _publish_frames(index, frames):
    f = jnp.asarray(frames, jnp.int32)
    return index.publish(f, f.astype(jnp.float32))


def test_index_publish_lookup_and_duplicates():
    idx = RepositoryIndex(detector_version="v1")
    assert _publish_frames(idx, [4, 9, -1, 4]) == 2   # sentinel + dup skip
    assert idx.stats["duplicates"] == 1
    assert len(idx) == 2
    assert float(idx.lookup(4)[0]) == 4.0
    assert idx.lookup(5) is None
    assert idx.lookup(4, version="v2") is None, "version mismatch = miss"


def test_index_detector_version_isolation():
    idx = RepositoryIndex(detector_version="v1")
    _publish_frames(idx, [1, 2, 3])
    idx.detector_version = "v2"           # model upgrade
    assert len(idx) == 0, "new version reads an empty tier"
    _publish_frames(idx, [1])
    assert idx.entries("v1") == 3 and idx.entries("v2") == 1
    cache, warm = idx.warm(_toy_struct(), 16)
    assert warm == {1}, "warm() serves only the CURRENT version"


def test_index_snapshot_roundtrip(tmp_path):
    path = str(tmp_path / "idx")
    idx = RepositoryIndex(path, detector_version="v1")
    _publish_frames(idx, [2, 11, 7])
    idx.priors.record(0, np.asarray([1.0, 0.0]), np.asarray([4.0, 2.0]))
    idx.save()
    idx2 = RepositoryIndex(path, detector_version="v1")
    assert idx2.stats["loaded"] == 3
    assert sorted(
        f for f in (2, 7, 11) if idx2.lookup(f) is not None
    ) == [2, 7, 11]
    assert float(idx2.lookup(11)[0]) == 11.0
    np.testing.assert_array_equal(
        idx2.priors.warm_alphas(0, 2, 4.0),
        idx.priors.warm_alphas(0, 2, 4.0),
    )
    # a different detector_version over the SAME snapshot is a clean miss
    idx3 = RepositoryIndex(path, detector_version="v2")
    assert len(idx3) == 0 and idx3.entries("v1") == 3


def test_index_read_only_discipline(tmp_path):
    idx = RepositoryIndex(
        str(tmp_path / "ro"), detector_version="v1", read_only=True
    )
    assert _publish_frames(idx, [1, 2]) == 0
    assert len(idx) == 0
    with pytest.raises(ValueError, match="read_only"):
        idx.save()


def test_index_warm_empty_bitidentical_to_init():
    idx = RepositoryIndex()
    struct = _toy_struct()
    warm_cache, warm = idx.warm(struct, 8)
    cold = init_detection_cache(struct, 8)
    assert warm == frozenset()
    np.testing.assert_array_equal(
        np.asarray(warm_cache.tag), np.asarray(cold.tag))
    np.testing.assert_array_equal(
        np.asarray(warm_cache.store), np.asarray(cold.store))
    assert warm_cache.tag.dtype == cold.tag.dtype
    assert warm_cache.store.dtype == cold.store.dtype


def test_index_warm_collision_deterministic():
    idx = RepositoryIndex()
    _publish_frames(idx, [7, 3, 11])     # 3, 7, 11 all map to slot 3 % 4
    cache, warm = idx.warm(_toy_struct(), 4)
    assert warm == {3}, "ascending frame order, first occupant wins"
    hit, vals = cache_lookup(cache, jnp.asarray([3, 7, 11], jnp.int32))
    assert [bool(h) for h in hit] == [True, False, False]
    assert float(vals[0]) == 3.0


def test_index_snapshot_orphan_cleanup(tmp_path):
    """Regression: shrinking the version set between snapshots used to
    orphan the higher-numbered ``detections_<i>.npz`` forever.  After the
    second save the directory must hold exactly the manifest + files it
    references, and the torn-intermediate state (old manifest + extra
    files, before cleanup) must still load."""
    import os

    path = str(tmp_path / "idx")
    idx = RepositoryIndex(path, detector_version="v1")
    _publish_frames(idx, [1, 2])
    idx.detector_version = "v2"
    _publish_frames(idx, [3])
    idx.save()                                  # 2 versions → 2 npz files
    assert sorted(os.listdir(path)) == [
        "detections_0.npz", "detections_1.npz", "manifest.json", "priors.npz",
    ]
    # simulate the torn intermediate: extra unreferenced npz on disk
    with open(os.path.join(path, "detections_7.npz"), "wb") as fh:
        fh.write(b"torn")
    assert RepositoryIndex(path).stats["loaded"] == 3, (
        "unreferenced stray files must not break _load"
    )
    idx2 = RepositoryIndex(path, detector_version="v2")
    idx2._tiers.pop("v1")                       # version set shrinks
    idx2.save()                                 # 1 version → 1 npz file
    assert sorted(os.listdir(path)) == [
        "detections_0.npz", "manifest.json", "priors.npz",
    ], "orphans (incl. the stray) deleted after the manifest lands"
    idx3 = RepositoryIndex(path, detector_version="v2")
    assert idx3.stats["loaded"] == 1 and idx3.lookup(3) is not None


def test_index_rejects_incompatible_snapshot(tmp_path):
    path = tmp_path / "bad"
    path.mkdir()
    (path / "manifest.json").write_text('{"format": 99, "versions": {}}')
    with pytest.raises(ValueError, match="format"):
        RepositoryIndex(str(path))


# ---------------------------------------------------------------------------
# ChunkPriors: identity cold path, n1-only injection, geometry guard
# ---------------------------------------------------------------------------


def test_priors_zero_weight_returns_input_object():
    p = ChunkPriors()
    p.record(None, np.ones(4), np.full(4, 2.0))
    state = init_state(np.full(4, 100))
    out, equiv = p.warm_sampler(state, None, 0.0)
    assert out is state and equiv == 0.0
    out, equiv = p.warm_sampler(state, 5, 1.0)   # unknown class
    assert out is state and equiv == 0.0
    empty = ChunkPriors()
    out, equiv = empty.warm_sampler(state, None, 1.0)  # no evidence at all
    assert out is state and equiv == 0.0


def test_priors_inject_n1_only():
    p = ChunkPriors()
    p.record(0, np.asarray([3.0, 0.0, 1.0]), np.asarray([6.0, 0.0, 4.0]))
    state = init_state(np.full(3, 100))
    out, equiv = p.warm_sampler(state, 0, 8.0)
    assert out is not state and equiv > 0
    np.testing.assert_array_equal(np.asarray(out.n), np.asarray(state.n))
    boost = np.asarray(out.n1) - np.asarray(state.n1)
    # rate = [0.5, 0 (no evidence), 0.25] × weight 8
    np.testing.assert_allclose(boost, [4.0, 0.0, 2.0])


def test_priors_geometry_mismatch_refuses():
    p = ChunkPriors()
    p.record(0, np.ones(4), np.ones(4))
    assert p.warm_alphas(0, 5, 1.0) is None
    state = init_state(np.full(5, 100))
    out, _ = p.warm_sampler(state, 0, 1.0)
    assert out is state
    with pytest.raises(ValueError, match="chunk-count"):
        p.record(0, np.ones(3), np.ones(3))


def test_priors_record_batched_and_ingest_and_serde():
    p = ChunkPriors()
    p.record(None, np.ones((2, 3)), np.full((2, 3), 2.0))  # [Q, M] sums
    np.testing.assert_array_equal(p._n1[-1], [2.0, 2.0, 2.0])
    p.ingest(1, np.asarray([0.5, 2.0, -1.0]), weight=4.0)  # scores clip
    np.testing.assert_array_equal(p._n1[1], [2.0, 4.0, 0.0])
    np.testing.assert_array_equal(p._n[1], [4.0, 4.0, 4.0])
    assert p.classes() == [None, 1]
    q = ChunkPriors.from_arrays(p.to_arrays())
    assert q.classes() == p.classes()
    np.testing.assert_array_equal(q._n1[1], p._n1[1])
    np.testing.assert_array_equal(q._n[-1], p._n[-1])


# ---------------------------------------------------------------------------
# IndexSpec: serde round-trip + typed validation
# ---------------------------------------------------------------------------


def test_index_spec_serde_roundtrip():
    plan = _plan(index=IndexSpec(
        path="/tmp/x", detector_version="v3", read_only=True,
        prior_weight=2.5,
    ))
    back = SearchPlan.from_dict(plan.to_dict())
    assert back == plan
    assert back.execution.index.detector_version == "v3"
    assert back.execution.index.read_only is True


def test_index_spec_validation():
    with pytest.raises(PlanError, match="unknown") as e:
        IndexSpec.from_dict({"path": None, "sharding": 4})
    assert e.value.field == "sharding"
    with pytest.raises(PlanError) as e:
        _plan(index=IndexSpec(detector_version="")).resolve()
    assert e.value.field == "detector_version"
    with pytest.raises(PlanError) as e:
        _plan(index=IndexSpec(prior_weight=-1.0)).resolve()
    assert e.value.field == "prior_weight"
    with pytest.raises(PlanError) as e:
        _plan(index=IndexSpec(path=7)).resolve()
    assert e.value.field == "path"


# ---------------------------------------------------------------------------
# End-to-end: cold parity, warm replay, persisted economics
# ---------------------------------------------------------------------------


def test_cold_index_bitidentical_to_no_index(world, tmp_path):
    """A cold index with prior_weight=0 must change NOTHING: same carry,
    same traces, same detector economics as running without one."""
    _, chunks, det = world
    base = _plan().run(_fresh_multi(chunks), chunks, detector=det)
    spec = IndexSpec(path=str(tmp_path / "cold"), prior_weight=0.0)
    res = _plan(index=spec).run(_fresh_multi(chunks), chunks, detector=det)
    _same_carry(base.carry, res.carry)
    assert base.traces == res.traces
    assert base.stats.detector_invocations == res.stats.detector_invocations
    assert res.stats.index_hits == 0
    assert res.stats.persisted_detections > 0   # write-back still happened


def test_warm_index_replays_exactly(world, tmp_path):
    """Second identical run over the saved snapshot: bit-identical
    results, index hits cover the sampled frames, (near-)zero fresh
    detector calls — the ≥5× reuse economics of the headline bench."""
    _, chunks, det = world
    spec = IndexSpec(path=str(tmp_path / "warm"), prior_weight=0.0)
    r1 = _plan(index=spec).run(_fresh_multi(chunks), chunks, detector=det)
    assert r1.stats.persisted_detections > 0
    r2 = _plan(index=spec).run(_fresh_multi(chunks), chunks, detector=det)
    _same_carry(r1.carry, r2.carry)
    assert r1.traces == r2.traces
    assert r2.stats.index_hits > 0
    assert r2.stats.detector_invocations == 0, (
        "every frame of the identical trajectory was persisted by run 1")
    assert r2.stats.persisted_detections == 0   # nothing new to publish


def test_warm_start_priors_through_plan(world, tmp_path):
    """prior_weight > 0 over accumulated evidence injects Thompson
    pseudo-successes: warm_rounds_saved is reported and the query still
    terminates at its result limit."""
    _, chunks, det = world
    spec = IndexSpec(path=str(tmp_path / "pri"), prior_weight=0.0)
    _plan(index=spec).run(_fresh_multi(chunks), chunks, detector=det)
    warm_spec = dataclasses.replace(spec, prior_weight=50.0)
    res = _plan(index=warm_spec).run(
        _fresh_multi(chunks), chunks, detector=det
    )
    assert res.stats.warm_rounds_saved > 0
    assert res.results[0] == 10


def test_executor_version_mismatch_raises(world, tmp_path):
    _, chunks, det = world
    live = RepositoryIndex(detector_version="v1")
    with pytest.raises(PlanError) as e:
        _plan(index=IndexSpec(detector_version="v2")).run(
            _fresh_multi(chunks), chunks, detector=det, index=live
        )
    assert e.value.field == "detector_version"


def test_second_service_over_warm_index(world):
    """The multi-tenant saving: service #1's tenant publishes into the
    shared index at retirement; service #2 (fresh process stand-in) warms
    its device cache from it, and ITS tenant's attributed economics show
    index hits and fewer fresh detector calls."""
    from repro.serve.service import SearchService

    _, chunks, det = world

    def _svc(index):
        proto = init_carry_multi(
            init_state(chunks.length), init_matcher(max_results=64),
            jnp.stack([jax.random.PRNGKey(0)]),
        )
        return SearchService(
            proto, chunks, det, cohorts=2, num_workers=1,
            slots_per_batch=2, cache_frames=chunks.total_frames,
            index=index,
        )

    index = RepositoryIndex(detector_version="v0")
    plan = SearchPlan(
        result_limit=8, max_steps=1500, cohorts=2,
        execution=Execution(queries_axis=True),
    )
    svc1 = _svc(index)
    t1 = svc1.submit("a", plan, seed=1)
    svc1.start(pump=False)
    svc1.drain()
    svc1.stop()
    assert t1.state == "finished"
    assert len(index) > 0, "retirement published detections"
    assert np.sum(index.priors._n[-1 if t1.select_id is None else
                                  t1.select_id]) > 0

    svc2 = _svc(index)     # fresh driver warms from the shared index
    t2 = svc2.submit("b", plan, seed=1)   # same key ⇒ same trajectory
    svc2.start(pump=False)
    svc2.drain()
    svc2.stop()
    d1, d2 = t1.to_dict(), t2.to_dict()
    assert d2["results"] == d1["results"]
    assert d2["index_hits"] > 0
    assert d2["detector_invocations"] < d1["detector_invocations"]
    assert d1["detector_invocations"] >= 5 * max(
        d2["detector_invocations"], 1
    ) or d2["detector_invocations"] == 0


def test_service_rejects_warm_plan_without_index(world):
    from repro.serve.service import SearchService

    _, chunks, det = world
    proto = init_carry_multi(
        init_state(chunks.length), init_matcher(max_results=64),
        jnp.stack([jax.random.PRNGKey(0)]),
    )
    svc = SearchService(proto, chunks, det, cohorts=2, num_workers=1)
    plan = SearchPlan(
        result_limit=4, max_steps=500,
        execution=Execution(
            queries_axis=True, index=IndexSpec(prior_weight=2.0)
        ),
    )
    with pytest.raises(PlanError) as e:
        svc.submit("a", plan)
    assert e.value.field == "index"
    svc.driver.stop()
