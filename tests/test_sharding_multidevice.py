"""Multi-device behaviours, run in a subprocess with 8 host devices.

Covers: distributed Thompson choice, delta merging, compressed cross-pod
all-reduce, and a tiny-mesh lower+compile of a train cell — the unit-scale
version of the production dry-run.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_test_mesh
    from repro.core.state import init_state, apply_update
    from repro.core.distributed import (
        distributed_choose, merge_deltas, pad_chunks, shard_sampler_state)

    mesh = make_test_mesh((4, 2), ("data", "model"))

    # --- distributed Thompson choice matches rich-chunk expectation -------
    s = init_state(jnp.full((16,), 100, jnp.int32))
    for _ in range(12):
        s = apply_update(s, 5, 1, 0)          # chunk 5 is rich
    for c in (0, 1, 2, 3):
        for _ in range(12):
            s = apply_update(s, c, 0, 0)
    s = pad_chunks(s, 4)
    picks = []
    for i in range(50):
        c = distributed_choose(jax.random.PRNGKey(i), s, mesh=mesh, cohorts=4)
        picks += list(np.asarray(c))
    frac = (np.asarray(picks) == 5).mean()
    assert frac > 0.5, frac
    print("choose ok", frac)

    # --- delta merge == sum over workers ------------------------------------
    base = init_state(jnp.full((16,), 100, jnp.int32))
    d1 = jnp.zeros((4, 16)).at[:, 3].set(2.0)     # 4 workers, same chunk
    dn = jnp.zeros((4, 16)).at[:, 3].set(1.0)
    merged = merge_deltas(base, d1, dn)
    assert float(merged.n1[3]) == 8.0, merged.n1
    assert float(merged.n[3]) == 4.0
    print("merge ok")

    # --- tiny-mesh train cell lower+compile --------------------------------
    import dataclasses
    from repro.configs import ARCHS, scale_down
    from repro.configs.base import RunConfig, ShapeConfig
    from repro.launch.specs import build_cell
    from repro.distributed.sharding import ShardingRules, use_rules

    cfg = scale_down(ARCHS["qwen2.5-32b"], layers=2, d_model=64, heads=4,
                     kv_heads=2, d_ff=128, vocab=256)
    shape = ShapeConfig("tiny_train", 64, 8, "train")
    run = RunConfig(param_dtype="float32", unroll=True, block_q=32, block_kv=32,
                    causal_block_skip=False, sequence_parallel=False,
                    remat=True, microbatches=2)
    cell = build_cell(cfg, shape, mesh, run=run)
    with mesh, use_rules(ShardingRules.for_mesh(mesh)):
        compiled = jax.jit(cell.step_fn, in_shardings=cell.in_shardings) \\
            .lower(*cell.args).compile()
    print("tiny dryrun ok", compiled.memory_analysis().temp_size_in_bytes)

    # --- compressed cross-pod allreduce ------------------------------------
    mesh3 = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
    from repro.distributed.compression import (
        make_cross_pod_allreduce, init_error_feedback)
    grads = {"w": jnp.arange(32.0).reshape(4, 8) / 31.0}
    ef = init_error_feedback(grads)
    fn = make_cross_pod_allreduce(mesh3, compress=True)
    out, ef2 = fn(grads, ef)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(grads["w"]),
                               atol=2e-2)
    print("compressed allreduce ok")
    print("ALL_OK")
    """
)


@pytest.mark.slow
def test_multidevice_suite():
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert "ALL_OK" in r.stdout, r.stdout[-3000:] + "\n" + r.stderr[-3000:]
