"""Chunking + random+ (bit-reversal) stratification properties."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.chunks import (
    bit_reverse,
    build_chunks,
    global_randomplus_order,
    randomplus_frame,
    randomplus_offset,
)


def test_build_chunks_geometry():
    idx = build_chunks([100, 250], chunk_frames=100)
    assert idx.num_chunks == 4                       # 100 | 100+100+50
    assert idx.total_frames == 350
    assert list(np.asarray(idx.video_id)) == [0, 1, 1, 1]
    assert list(np.asarray(idx.length)) == [100, 100, 100, 50]
    assert list(np.asarray(idx.start)) == [0, 100, 200, 300]


def test_bit_reverse_is_permutation():
    bits = 6
    vals = np.asarray(bit_reverse(jnp.arange(64), bits))
    assert sorted(vals.tolist()) == list(range(64))


@settings(max_examples=30, deadline=None)
@given(length=st.integers(2, 5000), seed=st.integers(0, 20))
def test_randomplus_offsets_in_range(length, seed):
    idx = build_chunks([length], chunk_frames=length, seed=seed)
    ks = jnp.arange(min(length, 64))
    offs = np.asarray(
        jnp.stack([randomplus_offset(idx, jnp.int32(0), k) for k in ks])
    )
    assert offs.min() >= 0 and offs.max() < length


@settings(max_examples=40, deadline=None)
@given(length=st.integers(1, 512), seed=st.integers(0, 10))
def test_randomplus_first_length_ranks_are_permutation(length, seed):
    """§3.7.2: the first `length` random+ ranks must visit every offset
    exactly once — ``exhausted()`` fires after `length` samples, so any
    collision means some frame is never sampled while another is visited
    twice (the rescaling bug: a length-3 chunk yielded (0, 1, 0))."""
    idx = build_chunks([length], chunk_frames=length, seed=seed)
    offs = np.asarray(randomplus_offset(idx, jnp.int32(0), jnp.arange(length)))
    assert sorted(offs.tolist()) == list(range(length))


def test_randomplus_is_stratified():
    """After k samples the max gap between visited offsets is O(length/k) —
    the defining property of §3.7.2 (vs O(length log k / k) for uniform)."""
    length = 4096
    idx = build_chunks([length], chunk_frames=length, seed=3)
    for k in (8, 32, 128):
        offs = np.sort(
            np.asarray(
                jnp.stack(
                    [randomplus_offset(idx, jnp.int32(0), jnp.int32(i)) for i in range(k)]
                )
            )
        )
        gaps = np.diff(np.concatenate([offs, [offs[0] + length]]))
        assert gaps.max() <= 4 * length / k, (k, gaps.max())


def test_global_randomplus_is_permutation():
    order = global_randomplus_order(1000, seed=1)
    assert sorted(order.tolist()) == list(range(1000))


def test_global_randomplus_prefix_coverage():
    order = global_randomplus_order(8192, seed=0)
    prefix = np.sort(order[:64])
    gaps = np.diff(np.concatenate([prefix, [prefix[0] + 8192]]))
    assert gaps.max() <= 4 * 8192 / 64


def test_randomplus_frame_offsets_by_chunk_start():
    idx = build_chunks([100, 100], chunk_frames=100, seed=0)
    f = int(randomplus_frame(idx, jnp.int32(1), jnp.int32(0)))
    assert 100 <= f < 200
