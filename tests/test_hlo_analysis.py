"""HLO collective parsing + roofline arithmetic."""
import textwrap

from repro.analysis.hlo import collective_bytes, collective_bytes_scaled
from repro.analysis.roofline import Roofline

HLO = textwrap.dedent(
    """\
    HloModule test

    %body.1 (p: (s32[], f32[16,128])) -> (s32[], f32[16,128]) {
      %ar = f32[16,128]{1,0} all-reduce(%x), replica_groups={}
      ROOT %t = (s32[], f32[16,128]) tuple(%i, %ar)
    }

    %cond.1 (p: (s32[], f32[16,128])) -> pred[] {
      %c = s32[] constant(12)
      ROOT %cmp = pred[] compare(%i, %c), direction=LT
    }

    ENTRY %main.2 (a: f32[16,128]) -> f32[16,128] {
      %ag = f32[64,128]{1,0} all-gather(%a), dimensions={0}
      %w = (s32[], f32[16,128]) while(%init), condition=%cond.1, body=%body.1
      ROOT %out = f32[16,128]{1,0} get-tuple-element(%w), index=1
    }
    """
)


def test_flat_collective_bytes():
    got = collective_bytes(HLO)
    assert got["bytes_by_op"]["all-gather"] == 64 * 128 * 4
    assert got["bytes_by_op"]["all-reduce"] == 16 * 128 * 4
    assert got["counts_by_op"] == {"all-gather": 1, "all-reduce": 1}


def test_trip_scaled_collective_bytes():
    got = collective_bytes_scaled(HLO)
    assert got["bytes_by_op"]["all-gather"] == 64 * 128 * 4
    assert got["bytes_by_op"]["all-reduce"] == 12 * 16 * 128 * 4   # ×12 trips


def test_roofline_terms_and_bottleneck():
    r = Roofline(
        name="x", mesh="m", chips=256,
        hlo_flops=197e12,            # exactly 1 s of compute
        hlo_bytes=819e9 * 0.5,       # 0.5 s of HBM
        collective={"total_bytes": 50e9 * 2},   # 2 s of ICI
        model_flops=197e12 * 256 * 0.5,
        arg_bytes=1.0, temp_bytes=1.0, out_bytes=1.0,
    )
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 0.5) < 1e-9
    assert abs(r.t_collective - 2.0) < 1e-9
    assert r.bottleneck == "collective"
    assert abs(r.step_time - 2.0) < 1e-9
    assert abs(r.useful_ratio - 0.5) < 1e-9
    assert abs(r.mfu - 0.25) < 1e-9
