"""Blocked attention vs naive reference (shapes × flags × GQA)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import blocked_attention, decode_attention, repeat_kv


def naive(q, k, v, causal):
    d = q.shape[-1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / math.sqrt(d)
    if causal:
        t = k.shape[1]
        mask = jnp.tril(jnp.ones((q.shape[1], t), bool), k=t - q.shape[1])
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("unroll", [True, False])
@pytest.mark.parametrize("skip", [True, False])
@pytest.mark.parametrize("bq,bk", [(16, 16), (32, 64), (64, 32)])
def test_blocked_matches_naive(causal, unroll, skip, bq, bk):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 64, 4, 16), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, 4, 16), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 64, 4, 16), jnp.float32)
    out = blocked_attention(
        q, k, v, causal=causal, block_q=bq, block_kv=bk,
        causal_skip=skip, unroll=unroll,
    )
    np.testing.assert_allclose(out, naive(q, k, v, causal), rtol=2e-5, atol=2e-5)


def test_gqa_repeat_consistency():
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 32, 8, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 32, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 32, 2, 16))
    kr, vr = repeat_kv(k, 8), repeat_kv(v, 8)
    out = blocked_attention(q, kr, vr, causal=True, block_q=16, block_kv=16)
    np.testing.assert_allclose(out, naive(q, kr, vr, True), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtype_sweep(dtype):
    key = jax.random.PRNGKey(4)
    mk = lambda i, s: jax.random.normal(jax.random.fold_in(key, i), s, dtype)
    q, k, v = mk(0, (1, 32, 2, 16)), mk(1, (1, 32, 2, 16)), mk(2, (1, 32, 2, 16))
    out = blocked_attention(q, k, v, causal=True, block_q=16, block_kv=16)
    ref = naive(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref, rtol=tol, atol=tol
    )


def test_q_offset_chunked_prefill():
    """Chunked prefill: attending from positions [32, 64) over 64 kv."""
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (1, 64, 2, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 64, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 64, 2, 16))
    full = blocked_attention(q, k, v, causal=True, block_q=16, block_kv=16)
    part = blocked_attention(
        q[:, 32:], k, v, causal=True, block_q=16, block_kv=16, q_offset=32
    )
    np.testing.assert_allclose(part, full[:, 32:], rtol=2e-5, atol=2e-5)


def test_decode_matches_last_position():
    """Decode at position t == teacher-forced attention at row t."""
    key = jax.random.PRNGKey(6)
    B, S, H, KV, D = 2, 40, 8, 2, 16
    q_all = jax.random.normal(key, (B, S, H, D))
    k_all = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, D))
    v_all = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, D))
    full = naive(q_all, repeat_kv(k_all, H), repeat_kv(v_all, H), causal=True)
    t = 24
    cache_k = jnp.zeros((B, 64, KV, D)).at[:, :t + 1].set(k_all[:, : t + 1])
    cache_v = jnp.zeros((B, 64, KV, D)).at[:, :t + 1].set(v_all[:, : t + 1])
    out = decode_attention(
        q_all[:, t : t + 1], cache_k, cache_v,
        cache_len=jnp.full((B,), t + 1, jnp.int32),
    )
    np.testing.assert_allclose(out[:, 0], full[:, t], rtol=2e-5, atol=2e-5)
