"""End-to-end search behaviour on the simulated repository (paper §4)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import init_carry, init_matcher, init_state, run_search
from repro.core.baselines import FrameSchedule, run_greedy, run_schedule
from repro.sim import RepoSpec, generate
from repro.sim.oracle import noisy_detect, oracle_detect


@pytest.fixture(scope="module")
def world():
    spec = RepoSpec(
        video_lengths=[20_000] * 5,
        num_instances=200,
        chunk_frames=2_000,
        locality=4.0,
        seed=1,
    )
    repo, chunks = generate(spec)
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    return repo, chunks, det


def _fresh(chunks, seed=0):
    return init_carry(
        init_state(chunks.length), init_matcher(max_results=512),
        jax.random.PRNGKey(seed),
    )


def test_exsample_beats_random_on_localized_data(world):
    repo, chunks, det = world
    ex, _ = run_search(
        _fresh(chunks), chunks, detector=det, result_limit=20, max_steps=2000
    )
    rnd, _ = run_schedule(
        _fresh(chunks), chunks,
        FrameSchedule.randomplus(chunks.total_frames, 2000, seed=0),
        detector=det, result_limit=20,
    )
    assert int(ex.results) >= 20
    assert int(ex.step) < int(rnd.step), (int(ex.step), int(rnd.step))


def test_batched_cohorts_find_results(world):
    repo, chunks, det = world
    ex, _ = run_search(
        _fresh(chunks), chunks, detector=det, result_limit=20,
        max_steps=2000, cohorts=8,
    )
    assert int(ex.results) >= 20


def test_greedy_runs_and_terminates(world):
    repo, chunks, det = world
    g, _ = run_greedy(
        _fresh(chunks), chunks, detector=det, result_limit=10, max_steps=1500
    )
    assert int(g.results) >= 10 or int(g.step) == 1500


def test_noisy_detector_still_converges(world):
    repo, chunks, _ = world
    det = lambda key, frame: noisy_detect(
        key, repo, frame, query_class=0, miss_rate=0.2, fp_rate=0.05
    )
    ex, _ = run_search(
        _fresh(chunks), chunks, detector=det, result_limit=15, max_steps=2500
    )
    assert int(ex.results) >= 15


def test_sampler_counters_consistent(world):
    repo, chunks, det = world
    ex, _ = run_search(
        _fresh(chunks), chunks, detector=det, result_limit=10, max_steps=500
    )
    assert int(jnp.sum(ex.sampler.n)) == int(ex.step)
    assert int(jnp.sum(ex.sampler.n1)) >= 0
