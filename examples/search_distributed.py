"""Distributed ExSample: mesh-sharded and Q×shards-composed search plans.

Runs the §8 mesh-resident lowering for real on an 8-device host mesh
(this script re-execs itself with the XLA device-count flag): one
``SearchPlan`` with ``Execution(shards=8)`` places chunk statistics over
the ``data`` axis, every round each shard processes its slice of the
globally-consistent Thompson cohort, and per-shard matcher states merge
every ``sync_every`` rounds — the whole search is ONE device call with a
single host sync at the end.  A single-device plan of the same query
shows the sharded statistics land on the same answer, and a composed
``queries_axis × shards`` plan (DESIGN.md §10) runs four concurrent
queries through the same mesh while sharing one deduplicated + cached
detector pass per round per shard.

  PYTHONPATH=src python examples/search_distributed.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import ensure_host_devices

ensure_host_devices(8)

from repro.core import (
    Execution,
    SearchPlan,
    init_carry,
    init_carry_multi,
    init_matcher,
    init_state,
)
from repro.sim import RepoSpec, generate
from repro.sim.oracle import oracle_detect


def main():
    spec = RepoSpec(video_lengths=[20_000] * 4, num_instances=200,
                    chunk_frames=2_000, locality=4.0, seed=1)
    repo, chunks = generate(spec)
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    fresh = lambda k: init_carry(
        init_state(chunks.length), init_matcher(max_results=1024), k,
    )

    shards, sync_every, limit, budget = 8, 4, 120, 4_000
    t0 = time.time()
    sharded = SearchPlan(
        result_limit=limit, max_steps=budget, cohorts=shards,
        execution=Execution(shards=shards, sync_every=sync_every),
    ).run(fresh(jax.random.PRNGKey(0)), chunks, detector=det)
    wall = time.time() - t0
    st = sharded.stats
    print(f"sharded({shards}x, sync_every={sync_every}): "
          f"{sharded.results[0]} distinct results in {sharded.steps[0]} "
          f"frames / {st.merges} merges (ring high-water "
          f"{st.merge_high_water}) ({wall:.1f}s incl. compile)")
    n = np.asarray(sharded.carry.sampler.n)
    top = np.argsort(-n)[:5]
    print("most-sampled chunks:", top.tolist(),
          "samples:", n[top].astype(int).tolist())

    scan = SearchPlan(
        result_limit=limit, max_steps=budget, cohorts=shards,
        method="wilson_hilferty",
    ).run(fresh(jax.random.PRNGKey(0)), chunks, detector=det)
    print(f"single-device scan: {scan.results[0]} results "
          f"in {scan.steps[0]} frames")
    sn = np.asarray(scan.carry.sampler.n)
    overlap = len(set(top.tolist()) & set(np.argsort(-sn)[:5].tolist()))
    print(f"top-5 hot-chunk overlap with scan: {overlap}/5")

    # ---- composed lowering: 4 concurrent queries × the same 8-way mesh,
    # one deduplicated + cached detector pass per round per shard ----
    q_n = 4
    keys = jnp.stack([
        jax.random.fold_in(jax.random.PRNGKey(0), q) for q in range(q_n)
    ])
    carries = init_carry_multi(
        init_state(chunks.length), init_matcher(max_results=1024), keys,
    )
    t0 = time.time()
    comp = SearchPlan(
        queries=q_n, result_limit=limit // q_n, max_steps=budget,
        cohorts=shards,
        execution=Execution(queries_axis=True, shards=shards,
                            sync_every=sync_every, cache=-1),
    ).run(carries, chunks, detector=det)
    wall = time.time() - t0
    st = comp.stats
    print(f"composed({q_n} queries x {shards} shards): "
          f"{sum(comp.results)} results / {st.frames_sampled} frames "
          f"sampled / {st.detector_invocations} detector invocations "
          f"({st.amortization:.2f}x amortization, cache hit rate "
          f"{st.cache_hit_rate:.2f}) ({wall:.1f}s incl. compile)")


if __name__ == "__main__":
    main()
