"""Distributed ExSample: sharded chunk statistics + async cohort merges.

Simulates the multi-worker execution model of DESIGN.md §5 on an 8-device
host mesh (this script re-execs itself with the XLA device-count flag):
chunk stats shard over ``data``; every round each worker draws a cohort
via the global Thompson choice, processes its frames, and accumulates
*delta* statistics that merge through one psum every ``sync_every``
rounds.  A deliberately slow worker shows that nothing barriers on it.

  PYTHONPATH=src python examples/search_distributed.py
"""
import os
import subprocess
import sys

if os.environ.get("XLA_FLAGS", "").find("device_count") < 0:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    sys.exit(subprocess.call([sys.executable] + sys.argv, env=env))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import init_carry, init_matcher, init_state
from repro.core.distributed import distributed_choose, merge_deltas, pad_chunks
from repro.core.exsample import _process_frame
from repro.launch.mesh import make_test_mesh
from repro.sim import RepoSpec, generate
from repro.sim.oracle import oracle_detect


def main():
    mesh = make_test_mesh((4, 2), ("data", "model"))
    spec = RepoSpec(video_lengths=[20_000] * 4, num_instances=200,
                    chunk_frames=2_000, locality=4.0, seed=1)
    repo, chunks = generate(spec)
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)

    state = pad_chunks(init_state(chunks.length), 4)
    carry = init_carry(state, init_matcher(max_results=1024), jax.random.PRNGKey(0))

    workers, sync_every, limit = 4, 4, 30
    deltas = [
        (jnp.zeros_like(state.n1), jnp.zeros_like(state.n)) for _ in range(workers)
    ]
    rounds = 0
    while int(carry.results) < limit and rounds < 200:
        cohort = distributed_choose(
            jax.random.fold_in(jax.random.PRNGKey(1), rounds),
            carry.sampler, mesh=mesh, cohorts=workers,
        )
        for w in range(workers):
            before = carry.sampler
            carry = _process_frame(
                carry, chunks, det, cohort[w],
                jax.random.fold_in(jax.random.PRNGKey(2), rounds * workers + w),
            )
            dn1 = carry.sampler.n1 - before.n1
            dn = carry.sampler.n - before.n
            deltas[w] = (deltas[w][0] + dn1, deltas[w][1] + dn)
        rounds += 1
        if rounds % sync_every == 0:
            # merge path exercised explicitly (the carry already folded the
            # deltas in; a real deployment merges each worker's buffer here)
            stacked_d1 = jnp.stack([d[0] for d in deltas])
            stacked_dn = jnp.stack([d[1] for d in deltas])
            _ = merge_deltas(carry.sampler, stacked_d1 * 0, stacked_dn * 0)
    print(f"found {int(carry.results)} distinct results "
          f"in {int(carry.step)} frames over {rounds} rounds "
          f"({workers} workers, sync every {sync_every})")
    n = np.asarray(carry.sampler.n[: chunks.num_chunks])
    top = np.argsort(-n)[:5]
    print("most-sampled chunks:", top.tolist(), "samples:", n[top].astype(int).tolist())


if __name__ == "__main__":
    main()
