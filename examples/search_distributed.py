"""Distributed ExSample: the sharded device-resident search driver.

Runs ``run_search_sharded`` (DESIGN.md §8) for real on an 8-device host
mesh (this script re-execs itself with the XLA device-count flag): chunk
statistics shard over ``data``, every round each shard processes its
slice of the globally-consistent Thompson cohort, and per-shard matcher
states merge through ``merge_matcher`` every ``sync_every`` rounds — the
whole search is ONE device call with a single host sync at the end.  A
single-device ``run_search_scan`` of the same query shows the sharded
statistics land on the same answer.

  PYTHONPATH=src python examples/search_distributed.py
"""
import time

import jax
import numpy as np

from repro.launch.mesh import ensure_host_devices

ensure_host_devices(8)

from repro.core import (
    init_carry,
    init_matcher,
    init_state,
    run_search_scan,
    run_search_sharded,
)
from repro.launch.mesh import make_data_mesh
from repro.sim import RepoSpec, generate
from repro.sim.oracle import oracle_detect


def main():
    spec = RepoSpec(video_lengths=[20_000] * 4, num_instances=200,
                    chunk_frames=2_000, locality=4.0, seed=1)
    repo, chunks = generate(spec)
    det = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    fresh = lambda: init_carry(
        init_state(chunks.length), init_matcher(max_results=1024),
        jax.random.PRNGKey(0),
    )

    shards, sync_every, limit, budget = 8, 4, 120, 4_000
    mesh = make_data_mesh(shards)
    t0 = time.time()
    carry, trace = run_search_sharded(
        fresh(), chunks, mesh=mesh, detector=det, result_limit=limit,
        max_steps=budget, cohorts=shards, sync_every=sync_every,
    )
    wall = time.time() - t0
    print(f"sharded({shards}x, sync_every={sync_every}): "
          f"{int(carry.results)} distinct results in {int(carry.step)} frames "
          f"/ {len(trace)} merges ({wall:.1f}s incl. compile)")
    n = np.asarray(carry.sampler.n)
    top = np.argsort(-n)[:5]
    print("most-sampled chunks:", top.tolist(),
          "samples:", n[top].astype(int).tolist())

    scan, _ = run_search_scan(
        fresh(), chunks, detector=det, result_limit=limit,
        max_steps=budget, cohorts=shards, method="wilson_hilferty",
    )
    print(f"single-device scan: {int(scan.results)} results "
          f"in {int(scan.step)} frames")
    sn = np.asarray(scan.sampler.n)
    overlap = len(set(top.tolist()) & set(np.argsort(-sn)[:5].tolist()))
    print(f"top-5 hot-chunk overlap with scan: {overlap}/5")


if __name__ == "__main__":
    main()
