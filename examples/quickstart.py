"""Quickstart: a distinct-object query over a simulated video repository.

Runs the paper's core experiment end-to-end in ~a minute on CPU:
generate a 10-video repository with localized instances, then answer
"find 40 distinct class-0 objects" with ExSample and with random+, and
compare frames processed (the paper's cost metric).

This is the canonical ``SearchPlan`` snippet (DESIGN.md §10): declare
WHAT to search on the plan, let ``run()`` lower it to the right
device-resident driver, and read the structured ``SearchResult`` —
swapping in a mesh, more queries, a detection cache or async workers is
an ``Execution(...)`` change, not a different API.

  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.exsample_paper import dashcam
from repro.core import (
    SearchPlan,
    init_carry,
    init_carry_multi,
    init_matcher,
    init_state,
)
from repro.core.baselines import FrameSchedule, run_schedule
from repro.core.plan import Execution, IndexSpec
from repro.sim import generate
from repro.sim.oracle import oracle_detect
from repro.sim.costmodel import CostRates, sampling_cost


def main():
    setup = dashcam(scale=0.15)
    repo, chunks = generate(setup.repo)
    print(f"repository: {chunks.total_frames:,} frames, "
          f"{chunks.num_chunks} chunks, {repo.num_instances} instances")

    detector = lambda key, frame: oracle_detect(repo, frame, query_class=0)

    fresh = lambda: init_carry(
        init_state(chunks.length), init_matcher(max_results=1024),
        jax.random.PRNGKey(0),
    )

    # ONE declarative plan; the default lowering is the device-resident
    # scanned driver (DESIGN.md §7) — the whole search is one device call
    # and the recall trace comes back in a single host sync at the end.
    # Scaling up is an Execution(...) tweak on the same plan, e.g.
    #   execution=Execution(shards=8, cache=-1, queries_axis=True)
    plan = SearchPlan(
        result_limit=40, max_steps=20_000, cohorts=8, trace_every=200,
    )
    res = plan.run(fresh(), chunks, detector=detector)

    rp, _ = run_schedule(
        fresh(), chunks,
        FrameSchedule.randomplus(chunks.total_frames, 20_000),
        detector=detector, result_limit=40,
    )
    rates = CostRates()
    ex_steps = res.stats.frames_sampled
    print(f"\nExSample : {res.results[0]} results in {ex_steps:,} frames "
          f"(~{sampling_cost(ex_steps, rates).total_s:.0f} gpu·s, "
          f"lowering={res.kind})")
    print(f"random+  : {int(rp.results)} results in {int(rp.step):,} frames "
          f"(~{sampling_cost(int(rp.step), rates).total_s:.0f} gpu·s)")
    print(f"savings  : {int(rp.step) / max(ex_steps, 1):.2f}x fewer frames")
    print("\nrecall trace (frames, results):", res.trace[:8], "...")

    # Warm restart (DESIGN.md §13): point the plan at a persistent index
    # and detections survive the run — the second, identical search
    # preloads its detection cache from the snapshot and answers from
    # disk instead of re-paying the detector for frames the repository
    # has already scored.
    fresh_multi = lambda: init_carry_multi(
        init_state(chunks.length), init_matcher(max_results=1024),
        jnp.stack([jax.random.PRNGKey(0)]),
    )
    with tempfile.TemporaryDirectory() as tmp:
        warm_plan = SearchPlan(
            result_limit=40, max_steps=20_000, cohorts=8,
            execution=Execution(
                queries_axis=True, cache=-1, index=IndexSpec(path=tmp),
            ),
        )
        cold = warm_plan.run(fresh_multi(), chunks, detector=detector)
        warm = warm_plan.run(fresh_multi(), chunks, detector=detector)
        print(f"\nwarm restart: {cold.stats.detector_invocations:,} detector "
              f"invocations cold -> {warm.stats.detector_invocations:,} warm "
              f"({warm.stats.index_hits:,} index hits, "
              f"{cold.stats.persisted_detections:,} persisted)")


if __name__ == "__main__":
    main()
