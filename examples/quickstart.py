"""Quickstart: a distinct-object query over a simulated video repository.

Runs the paper's core experiment end-to-end in ~a minute on CPU:
generate a 10-video repository with localized instances, then answer
"find 40 distinct class-0 objects" with ExSample and with random+, and
compare frames processed (the paper's cost metric).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.exsample_paper import dashcam
from repro.core import init_carry, init_matcher, init_state, run_search_scan
from repro.core.baselines import FrameSchedule, run_schedule
from repro.sim import generate
from repro.sim.oracle import oracle_detect
from repro.sim.costmodel import CostRates, sampling_cost


def main():
    setup = dashcam(scale=0.15)
    repo, chunks = generate(setup.repo)
    print(f"repository: {chunks.total_frames:,} frames, "
          f"{chunks.num_chunks} chunks, {repo.num_instances} instances")

    detector = lambda key, frame: oracle_detect(repo, frame, query_class=0)
    limit = 40

    fresh = lambda: init_carry(
        init_state(chunks.length), init_matcher(max_results=1024),
        jax.random.PRNGKey(0),
    )

    # device-resident driver (DESIGN.md §7): whole search is one device
    # call; the recall trace comes back in a single host sync at the end
    ex, trace = run_search_scan(
        fresh(), chunks, detector=detector, result_limit=limit,
        max_steps=20_000, cohorts=8, trace_every=200,
    )
    rp, _ = run_schedule(
        fresh(), chunks,
        FrameSchedule.randomplus(chunks.total_frames, 20_000),
        detector=detector, result_limit=limit,
    )
    rates = CostRates()
    print(f"\nExSample : {int(ex.results)} results in {int(ex.step):,} frames "
          f"(~{sampling_cost(int(ex.step), rates).total_s:.0f} gpu·s)")
    print(f"random+  : {int(rp.results)} results in {int(rp.step):,} frames "
          f"(~{sampling_cost(int(rp.step), rates).total_s:.0f} gpu·s)")
    print(f"savings  : {int(rp.step) / max(int(ex.step), 1):.2f}x fewer frames")
    print("\nrecall trace (frames, results):", trace[:8], "...")


if __name__ == "__main__":
    main()
