"""End-to-end training driver: finetune a ~small backbone for a few hundred
steps with the production train loop (AdamW, µbatching, checkpointing,
deterministic resume).

This is the substrate the BlazeIt-style surrogate baseline (and detector
finetuning) runs on.  On CPU it uses a reduced granite-moe config; on a
real pod the same driver takes ``--arch granite-moe-1b-a400m`` unreduced.

  PYTHONPATH=src python examples/train_surrogate.py --steps 300
"""
import argparse
import time

import jax

from repro.configs import ARCHS, RunConfig, scale_down
from repro.data.pipeline import DeterministicTokenPipeline, TrainBatchSpec
from repro.models.transformer import init_params
from repro.train.checkpoint import CheckpointManager
from repro.train.train_step import build_train_step, init_train_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = scale_down(ARCHS[args.arch], layers=4, d_model=128, heads=4,
                     d_ff=256, vocab=512)
    run = RunConfig(param_dtype="float32", block_q=32, block_kv=32,
                    unroll=False, remat=False, sequence_parallel=False,
                    learning_rate=1e-3, microbatches=2)
    pipe = DeterministicTokenPipeline(
        TrainBatchSpec(args.batch, args.seq, cfg.vocab), seed=0
    )
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    params = init_params(cfg, jax.random.PRNGKey(0))
    state = init_train_state(params, run)
    start = 0
    resumed = mgr.restore_latest(state)
    if resumed:
        start, state, extra = resumed
        print(f"resumed from step {start}")

    step_fn = jax.jit(build_train_step(cfg, run))
    t0 = time.time()
    for step in range(start, args.steps):
        state, metrics = step_fn(state, pipe.batch_at(step))
        if step % 25 == 0 or step == args.steps - 1:
            print(
                f"step {step:4d} loss={float(metrics['loss']):.4f} "
                f"lr={float(metrics['lr']):.2e} "
                f"gnorm={float(metrics['grad_norm']):.3f} "
                f"({(time.time() - t0) / max(step - start + 1, 1):.2f}s/step)"
            )
        if step and step % args.ckpt_every == 0:
            mgr.save(step, state, extra={"arch": cfg.name})
    print("final loss:", float(metrics["loss"]))


if __name__ == "__main__":
    main()
