"""Batched detector serving: a backbone + detection head behind the
request batcher — the production path the ExSample loop calls.

Frames come from the simulated store as embedding sequences; the reduced
phi-3-vision backbone plays the detector.  Shows batching occupancy and
detections per frame.

  PYTHONPATH=src python examples/serve_detector.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, RunConfig, scale_down
from repro.models.detection import head_schema
from repro.models.layers import materialize
from repro.models.transformer import init_params
from repro.serve.batcher import RequestBatcher
from repro.serve.serve_step import build_detect_step
from repro.sim import RepoSpec, generate
from repro.sim.oracle import frame_embedding


def main():
    cfg = scale_down(ARCHS["phi-3-vision-4.2b"], layers=2, d_model=64,
                     heads=4, d_ff=128, vocab=256)
    run = RunConfig(param_dtype="float32", block_q=16, block_kv=16,
                    unroll=False, remat=False, sequence_parallel=False)
    max_dets, num_classes, feat_dim = 8, 4, 8
    params = init_params(cfg, jax.random.PRNGKey(0))
    head = materialize(
        head_schema(cfg.d_model, max_dets=max_dets, num_classes=num_classes,
                    feat_dim=feat_dim),
        jax.random.PRNGKey(1), jnp.float32,
    )
    detect = jax.jit(build_detect_step(
        cfg, run, max_dets=max_dets, num_classes=num_classes, feat_dim=feat_dim
    ))

    spec = RepoSpec(video_lengths=[5000], num_instances=60, chunk_frames=1000)
    repo, chunks = generate(spec)

    B = 4
    batcher = RequestBatcher(batch_size=B)
    batcher.submit([10, 500, 990, 2400, 3100], [0, 0, 0, 2, 3], cohort=0)
    rounds = 0
    while batcher.ready():
        batch = batcher.next_batch()
        frames = jnp.stack([
            frame_embedding(repo, jnp.int32(max(f, 0)), dim=cfg.patch_dim,
                            patches=cfg.num_patches)
            for f in batch.frame_ids
        ])
        tokens = jnp.ones((B, 16 - cfg.num_patches), jnp.int32)
        out = detect(params, head, {"tokens": tokens, "patches": frames})
        rounds += 1
        for i in range(B):
            if not batch.valid[i]:
                continue
            scores = np.asarray(out.scores[i])
            print(f"frame {int(batch.frame_ids[i]):5d}: "
                  f"{int((scores > 0.5).sum())} detections "
                  f"(max score {scores.max():.2f})")
    print(f"\nbatches={rounds} occupancy={batcher.occupancy:.2f}")


if __name__ == "__main__":
    main()
